"""Mesh-native ignorance interchange (DESIGN.md §2).

The paper's chain 1→2→…→M→1 is a ring: on a TPU mesh with an ``agent`` axis
(device groups per agent) and a ``data`` axis (the length-n score sharded
like the batch), one interchange hop is

  * the fused local update  w ← w·exp(α(1−r)) / Z   (Pallas kernel, with
    the normalizer Z made global by a psum over the data axis), then
  * a pure neighbor ``ppermute`` along the agent ring — zero resharding,
    exactly one ICI hop of n/|data| floats per device.

`interchange_step` is the shard_map-ready building block;
`make_ring_interchange` wires it for a mesh.  The byte-metered
`core/transport.py` is the faithful single-host counterpart used by the
paper-figure benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.sharding.context import shard_map


def interchange_step(w_shard: jnp.ndarray, r_shard: jnp.ndarray,
                     alpha: jnp.ndarray, *, agent_axis: str,
                     data_axis: str | None,
                     agent_size: int | None = None) -> jnp.ndarray:
    """One hop of Algorithm 1 (eqs. 10/12) on a sharded score vector.

    w_shard/r_shard: this device's slice of the length-n score/reward.
    Returns the slice this device holds *for the next agent* (ring permute).
    ``agent_size`` is the ring length; required on JAX versions without
    ``jax.lax.axis_size`` (the perm list must be static).
    """
    w_new = ops.ignorance_update(w_shard, r_shard, alpha,
                                 axis_name=data_axis)
    if agent_size is None:
        agent_size = jax.lax.axis_size(agent_axis)
    perm = [(i, (i + 1) % agent_size) for i in range(agent_size)]
    return jax.lax.ppermute(w_new, agent_axis, perm)


def make_ring_interchange(mesh, *, agent_axis: str = "agent",
                          data_axis: str = "data"):
    """shard_map-wrapped ring interchange over `mesh`.

    Inputs: w [M, n] (per-agent score replicas, agent-axis sharded, n
    data-sharded), r [M, n] (per-agent rewards), alpha [M].
    Output: w' [M, n] where agent (m+1) now holds agent m's updated score.
    """

    size = mesh.shape[agent_axis]

    def step(w, r, alpha):
        out = interchange_step(w[0], r[0], alpha[0], agent_axis=agent_axis,
                               data_axis=data_axis, agent_size=size)
        return out[None]

    return shard_map(
        step, mesh=mesh,
        in_specs=(P(agent_axis, data_axis), P(agent_axis, data_axis),
                  P(agent_axis)),
        out_specs=P(agent_axis, data_axis))
