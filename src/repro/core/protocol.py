"""ASCII protocol: Algorithm 1 (two-agent), its M-agent extension
(Section IV), and the Section-V variants.

The round loop is a host-side Python loop (rounds are inherently sequential
and few); each agent's WST fit and all score math are jitted JAX.  Agents
are heterogeneous (arbitrary Learner per agent), exactly as the paper
allows.  A TransportLog can be attached to meter every interchanged message
(Fig. 4); the mesh-native runtime lives in core/collectives.py.

Variants:
  * ``ascii``        — the paper's method: assistant alphas use the upstream
                       factor (model-level side information, eqs. 11/13).
  * ``simple``       — ASCII-Simple: alpha from the agent's own loss only.
  * ``random``       — ASCII-Random: random agent order each round.
  * ``async``        — beyond-paper: answers the paper's open problem on
                       asynchronous interchange.  All agents train
                       concurrently on the *same* round-t ignorance score
                       (stale reads), updates are merged multiplicatively at
                       the round barrier.  This removes the serial chain so
                       the M WST fits parallelize across the mesh.
  Ensemble-AdaBoost (Method 3) is `fit_ensemble_adaboost` below.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scores
from repro.core.encoding import encode_labels
from repro.core.transport import TransportLog
from repro.learners.base import Learner

PyTree = Any


@dataclass(frozen=True)
class ASCIIConfig:
    num_classes: int
    max_rounds: int = 20
    variant: str = "ascii"              # ascii | simple | random | async
    stop_on_negative_alpha: bool = True
    # The paper's second stop criterion (Section III-C): hold out a fraction
    # of the collated rows, stop when A's out-sample error stops improving
    # for `cv_patience` consecutive rounds.  0.0 disables (paper's default
    # for its experiments, which use the alpha<=0 criterion).
    cv_fraction: float = 0.0
    cv_patience: int = 2
    alpha_cap: float = 20.0
    exact_reweight: bool = False        # beyond-paper exact exp-loss reweight
    seed: int = 0


@dataclass
class Component:
    agent: int
    round: int
    alpha: float
    params: PyTree


@dataclass
class FittedASCII:
    components: list[Component]
    learners: Sequence[Learner]
    num_classes: int
    history: list[dict] = field(default_factory=list)

    def decision_scores(self, Xs: Sequence[jnp.ndarray],
                        max_round: int | None = None) -> jnp.ndarray:
        """Line 12 of Algorithm 1: sum_t sum_m alpha * g (coded scores).

        Each agent evaluates only its own components on its own features and
        ships a [n, K] score block — O(nK) communication, not raw data.
        """
        n = Xs[0].shape[0]
        k = self.num_classes
        total = jnp.zeros((n, k), jnp.float32)
        for comp in self.components:
            if max_round is not None and comp.round > max_round:
                continue
            pred = self.learners[comp.agent].predict(comp.params, Xs[comp.agent])
            total = total + comp.alpha * encode_labels(pred, k)
        return total

    def predict(self, Xs: Sequence[jnp.ndarray],
                max_round: int | None = None) -> jnp.ndarray:
        return jnp.argmax(self.decision_scores(Xs, max_round), axis=-1)

    @property
    def num_rounds(self) -> int:
        return max((c.round for c in self.components), default=-1) + 1


def _meter_setup(transport: TransportLog | None, n: int, num_agents: int) -> None:
    if transport is None:
        return
    for m in range(1, num_agents):
        transport.send("agent0", f"agent{m}", "labels", n)      # numeric labels
        transport.send("agent0", f"agent{m}", "sample_ids", n)  # collation IDs


def _meter_hop(transport: TransportLog | None, src: int, dst: int, n: int) -> None:
    if transport is None:
        return
    transport.send(f"agent{src}", f"agent{dst}", "ignorance", n)
    transport.send(f"agent{src}", f"agent{dst}", "model_weight", 1)


def fit(key: jax.Array, Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
        learners: Sequence[Learner], cfg: ASCIIConfig,
        transport: TransportLog | None = None) -> FittedASCII:
    """Run the ASCII training protocol (Algorithm 1 / Section IV)."""
    num_agents = len(Xs)
    assert len(learners) == num_agents
    # Paper's CV stop criterion: reserve the trailing rows (aligned by
    # sample ID) for validation; learning uses the leading rows only.
    Xs_val, c_val = None, None
    if cfg.cv_fraction > 0.0:
        cut = int(round((1.0 - cfg.cv_fraction) * Xs[0].shape[0]))
        Xs_val = [x[cut:] for x in Xs]
        c_val = classes[cut:]
        Xs = [x[:cut] for x in Xs]
        classes = classes[:cut]
    n = Xs[0].shape[0]
    k = cfg.num_classes
    w = scores.init_ignorance(n)
    rng = np.random.default_rng(cfg.seed)
    result = FittedASCII([], learners, k)
    _meter_setup(transport, n, num_agents)
    best_val, stale = -1.0, 0

    reweight = (
        (lambda w, r, a: scores.ignorance_update_exact(w, r, a, k))
        if cfg.exact_reweight else scores.ignorance_update)

    stop = False
    for t in range(cfg.max_rounds):
        if cfg.variant == "random":
            order = list(rng.permutation(num_agents))
        else:
            order = list(range(num_agents))

        round_rec: dict = {"round": t, "alphas": [], "accs": []}

        if cfg.variant == "async":
            # Beyond-paper: stale-read parallel round (see module docstring).
            fits = []
            for m in order:
                key, sub = jax.random.split(key)
                params = learners[m].fit(sub, Xs[m], classes, w, k)
                r = learners[m].reward(params, Xs[m], classes)
                a, rbar = scores.model_weight(w, r, k, alpha_cap=cfg.alpha_cap)
                fits.append((m, params, r, a, rbar))
            w_next = w
            any_pos = False
            for m, params, r, a, rbar in fits:
                round_rec["alphas"].append(float(a))
                round_rec["accs"].append(float(rbar))
                if float(a) <= 0:
                    continue
                any_pos = True
                result.components.append(Component(m, t, float(a), params))
                # damp the stale multiplicative updates by 1/M: the naive
                # product of M per-agent reweights diverges for large M
                # (measured: chance-level at M=20); damping restores the
                # per-round weight movement of the sequential chain.
                w_next = w_next * jnp.exp((a / num_agents) * (1.0 - r))
                _meter_hop(transport, m, (m + 1) % num_agents, n)
            w = w_next / jnp.maximum(jnp.sum(w_next), 1e-12)
            if not any_pos and cfg.stop_on_negative_alpha:
                stop = True
        else:
            u = jnp.ones((n,), jnp.float32)
            for j, m in enumerate(order):
                key, sub = jax.random.split(key)
                params = learners[m].fit(sub, Xs[m], classes, w, k)
                r = learners[m].reward(params, Xs[m], classes)
                if cfg.variant == "simple" or j == 0:
                    a, rbar = scores.model_weight(w, r, k, alpha_cap=cfg.alpha_cap)
                else:
                    a, rbar = scores.model_weight(w, r, k, u=u,
                                                  alpha_cap=cfg.alpha_cap)
                round_rec["alphas"].append(float(a))
                round_rec["accs"].append(float(rbar))
                if cfg.stop_on_negative_alpha and float(a) <= 0:
                    stop = True   # Algorithm 1, line 8: break if alpha < 0
                    break
                result.components.append(Component(m, t, float(a), params))
                u = scores.upstream_factor_update(u, a, r, k)
                w = reweight(w, r, a)
                nxt = order[(j + 1) % num_agents]
                _meter_hop(transport, m, nxt, n)

        if Xs_val is not None:
            val_acc = float(jnp.mean(result.predict(Xs_val) == c_val))
            round_rec["val_acc"] = val_acc
            if val_acc > best_val + 1e-9:
                best_val, stale = val_acc, 0
            else:
                stale += 1
                if stale >= cfg.cv_patience:
                    stop = True   # out-sample error no longer decreasing
        result.history.append(round_rec)
        if stop:
            break
    return result


def fit_single_agent_adaboost(key, X: jnp.ndarray, classes: jnp.ndarray,
                              learner: Learner, cfg: ASCIIConfig) -> FittedASCII:
    """SAMME on one agent's data: ASCII degenerates to multi-class AdaBoost
    when M = 1 (the paper's 'Single' baseline in Fig. 3)."""
    return fit(key, [X], classes, [learner], cfg)


def fit_ensemble_adaboost(key, Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
                          learners: Sequence[Learner],
                          cfg: ASCIIConfig) -> "EnsembleAdaBoost":
    """Method 3 (Ensemble-AdaBoost): no interchange; each agent runs its own
    AdaBoost and prediction is a majority vote across agents."""
    fitted = []
    for m, (X, learner) in enumerate(zip(Xs, learners)):
        key, sub = jax.random.split(key)
        fitted.append(fit_single_agent_adaboost(sub, X, classes, learner, cfg))
    return EnsembleAdaBoost(fitted, cfg.num_classes)


@dataclass
class EnsembleAdaBoost:
    members: list[FittedASCII]
    num_classes: int

    def predict(self, Xs: Sequence[jnp.ndarray],
                max_round: int | None = None) -> jnp.ndarray:
        votes = [m.predict([X], max_round) for m, X in zip(self.members, Xs)]
        hist = sum(jax.nn.one_hot(v, self.num_classes) for v in votes)
        return jnp.argmax(hist, axis=-1)
