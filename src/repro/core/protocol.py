"""ASCII protocol: Algorithm 1 (two-agent), its M-agent extension
(Section IV), and the Section-V variants — back-compat front door.

The round loop itself now lives in the agent-session engine
(:mod:`repro.core.engine`): endpoints exchange typed messages through a
pluggable Transport, round order is a pluggable Scheduler, and protocol
state is an explicit checkpointable SessionState.  ``fit`` here is a thin
wrapper that maps the legacy ``ASCIIConfig`` (variant strings, cv_fraction,
a raw ``TransportLog``) onto that engine and returns the same
``FittedASCII`` as before — every pre-engine call site keeps working and
produces bit-identical results (tests/test_engine_golden.py).

Variants (now scheduler + alpha-policy pairs, see ``engine.variant_setup``):
  * ``ascii``        — the paper's method: assistant alphas use the upstream
                       factor (model-level side information, eqs. 11/13).
  * ``simple``       — ASCII-Simple: alpha from the agent's own loss only.
  * ``random``       — ASCII-Random: random agent order each round.
  * ``async``        — beyond-paper: answers the paper's open problem on
                       asynchronous interchange (stale reads, damped merge).
  Ensemble-AdaBoost (Method 3) is `fit_ensemble_adaboost` below.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.engine import (Component, FittedASCII, InProcessTransport,
                               MeteredTransport, Protocol, SessionConfig,
                               Transport, endpoints_for, holdout_split,
                               variant_setup)
from repro.core.transport import TransportLog
from repro.learners.base import Learner

__all__ = ["ASCIIConfig", "Component", "FittedASCII", "EnsembleAdaBoost",
           "fit", "fit_single_agent_adaboost", "fit_ensemble_adaboost"]


@dataclass(frozen=True)
class ASCIIConfig:
    num_classes: int
    max_rounds: int = 20
    variant: str = "ascii"              # ascii | simple | random | async
    stop_on_negative_alpha: bool = True
    # The paper's second stop criterion (Section III-C): hold out a fraction
    # of the collated rows, stop when A's out-sample error stops improving
    # for `cv_patience` consecutive rounds.  0.0 disables (paper's default
    # for its experiments, which use the alpha<=0 criterion).
    cv_fraction: float = 0.0
    cv_patience: int = 2
    alpha_cap: float = 20.0
    exact_reweight: bool = False        # beyond-paper exact exp-loss reweight
    seed: int = 0

    def session_config(self, upstream: bool) -> SessionConfig:
        return SessionConfig(num_classes=self.num_classes,
                             max_rounds=self.max_rounds,
                             upstream=upstream,
                             stop_on_negative_alpha=self.stop_on_negative_alpha,
                             cv_patience=self.cv_patience,
                             alpha_cap=self.alpha_cap,
                             exact_reweight=self.exact_reweight)


def fit(key: jax.Array, Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
        learners: Sequence[Learner], cfg: ASCIIConfig,
        transport: TransportLog | Transport | None = None) -> FittedASCII:
    """Run the ASCII training protocol (Algorithm 1 / Section IV).

    Back-compat wrapper over ``engine.Protocol``: accepts a raw
    ``TransportLog`` (wrapped into a MeteredTransport) or any engine
    ``Transport``; ``cfg.variant`` picks the scheduler.
    """
    num_agents = len(Xs)
    assert len(learners) == num_agents
    validation = None
    if cfg.cv_fraction > 0.0:
        Xs, classes, Xs_val, c_val = holdout_split(Xs, classes,
                                                   cfg.cv_fraction)
        validation = (Xs_val, c_val)
    scheduler, upstream = variant_setup(cfg.variant, cfg.seed)
    if transport is None:
        engine_transport: Transport = InProcessTransport()
    elif isinstance(transport, TransportLog):
        engine_transport = MeteredTransport(log=transport)
    else:
        engine_transport = transport
    engine = Protocol(cfg.session_config(upstream), scheduler=scheduler,
                      transport=engine_transport)
    return engine.fit(key, endpoints_for(learners, Xs), classes,
                      validation=validation)


def fit_single_agent_adaboost(key, X: jnp.ndarray, classes: jnp.ndarray,
                              learner: Learner, cfg: ASCIIConfig) -> FittedASCII:
    """SAMME on one agent's data: ASCII degenerates to multi-class AdaBoost
    when M = 1 (the paper's 'Single' baseline in Fig. 3)."""
    return fit(key, [X], classes, [learner], cfg)


def fit_ensemble_adaboost(key, Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
                          learners: Sequence[Learner],
                          cfg: ASCIIConfig) -> "EnsembleAdaBoost":
    """Method 3 (Ensemble-AdaBoost): no interchange; each agent runs its own
    AdaBoost and prediction is a majority vote across agents."""
    fitted = []
    for m, (X, learner) in enumerate(zip(Xs, learners)):
        key, sub = jax.random.split(key)
        fitted.append(fit_single_agent_adaboost(sub, X, classes, learner, cfg))
    return EnsembleAdaBoost(fitted, cfg.num_classes)


@dataclass
class EnsembleAdaBoost:
    members: list[FittedASCII]
    num_classes: int

    def predict(self, Xs: Sequence[jnp.ndarray],
                max_round: int | None = None) -> jnp.ndarray:
        votes = [m.predict([X], max_round) for m, X in zip(self.members, Xs)]
        hist = sum(jax.nn.one_hot(v, self.num_classes) for v in votes)
        return jnp.argmax(hist, axis=-1)
