"""Compiled interchange rounds: an entire ASCII session as one XLA program.

The eager engine (:mod:`repro.core.engine`) drives Algorithm 1 as a Python
host loop — one dispatch per weighted fit, per reward, per ignorance hop.
That is the right shape for heterogeneous eager learners (trees, forests)
and for transports that must observe every message, but it leaves the
hardware idle between dispatches.  The paper's round recurrence, however,
is fixed-shape:

    Algorithm 1, lines 3-11 (and its Section-IV M-agent chain):
      line 4/9  params_m = WST(X_m, y, w_t)            -> LearnerCore.fit
      line 5/9  r_i      = I{g_m(x_i) = y_i}           -> LearnerCore.predict
      line 5    alpha    = model_weight(w, r[, u])     -> scores.head_agent_
                                                          alpha / assistant_
                                                          alpha (eqs. 9/11/13)
      line 6/10 w_{t+1}  = reweight(w, r, alpha)       -> the fused Pallas
                                                          kernel (eqs. 10/12)

    so ``session_program`` lowers all rounds x all agents of that recurrence
    into a single ``lax.scan`` over rounds (agents unrolled inside the round
    body — their feature widths and learner cores differ, the round shape
    does not), and ``fleet_run`` vmaps the whole program over per-session
    PRNG keys (and optionally per-cohort data) so one compiled program
    serves many concurrent sessions.

The scan replicates the eager engine's semantics exactly — including the
alpha <= 0 early stop (Algorithm 1, line 8), which becomes a ``stopped``
mask that freezes the carried ignorance score — so ``backend="compiled"``
on :class:`repro.core.engine.Protocol` is pinned bit-for-bit against the
eager loop under sequential scheduling (tests/test_compiled.py).

Quickstart::

    cores = tuple(lr.core(num_classes) for lr in learners)
    plan = SessionPlan(cores=cores, num_classes=k, max_rounds=6)
    result = compiled_session(plan, jax.random.key(0), Xs, classes)
    fitted = fitted_from_result(plan, result, learners)    # FittedASCII

    keys = jax.random.split(jax.random.key(0), 32)         # 32 sessions,
    fleet = fleet_run(plan, keys, Xs, classes)             # one program
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scores
from repro.kernels.ignorance import tiles_evenly

PyTree = Any


# ========================================================================= plan
@dataclass(frozen=True)
class SessionPlan:
    """The static half of a session: everything XLA needs at trace time.

    ``cores`` are the agents' :class:`~repro.learners.base.LearnerCore`
    contracts in chain order (hashable frozen dataclasses, so a plan is a
    valid jit static argument and programs cache per plan).  The remaining
    fields mirror :class:`repro.core.engine.SessionConfig`.
    """
    cores: tuple
    num_classes: int
    max_rounds: int = 20
    upstream: bool = True
    stop_on_negative_alpha: bool = True
    alpha_cap: float = 20.0
    exact_reweight: bool = False
    # Run eqs. (10)/(12) through the fused Pallas kernel
    # (kernels.ignorance.ignorance_update_unnormalized) when the score
    # length tiles evenly; False forces the plain jnp formula everywhere.
    # The two are bit-identical for n <= the kernel tile (1024); above it
    # the tiled partial-sum reduction can differ in the last ulp, which is
    # why Protocol._fit_compiled derives this flag from the transport
    # (kernel iff MeshRingTransport) instead of taking the default.
    use_kernel: bool = True
    # Pallas interpret-mode override for the kernel (None = resolve by
    # backend, like kernels.ops does) — threaded through from
    # MeshRingTransport.interpret so compiled runs execute the same kernel
    # mode the eager transport would.
    kernel_interpret: bool | None = None
    # The wire channel (repro.comm), all hashable frozen dataclasses:
    # ``codec`` encodes/decodes every shipped ignorance vector (the scan
    # carries per-link error-feedback residuals for stateful codecs),
    # ``privacy`` adds the DP Gaussian mechanism before encoding, and
    # ``budget`` replaces ``codec`` with its degradation ladder plus
    # spent-bit counters carried through the scan — the same
    # degrade-then-skip decision rule the eager BudgetedTransport applies,
    # so both backends pick identical codecs hop for hop.
    codec: Any = None
    privacy: Any = None
    budget: Any = None
    # Serve-path codec override: prediction-time ScoreBlockMsg traffic
    # (the traced serve step below) encodes with this codec when set, else
    # with ``codec`` — mirroring Transport.serve_codec.
    serve_codec: Any = None
    # Adaptive codec controller (repro.control.adaptive): a branchless
    # rung-index policy over its ladder, computed per hop from the carried
    # ignorance vector's entropy EMA (the EMA scalar rides the scan carry).
    # With a budget too, the controller's rung is a floor on the ladder
    # walk — the same composition rule the eager BudgetedTransport applies.
    controller: Any = None
    # Serve-path adaptive policy (repro.control.adaptive.ServeController):
    # picks the serve rung per [n, K] block from its observed statistic
    # (per-row margin or normalized entropy).  Stateless — serve hops are
    # independent, so no EMA rides the carry.  With a budget too, the
    # policy's rung floors the same ladder walk, mirroring the eager
    # BudgetedTransport.serve_block composition.
    serve_controller: Any = None
    # Round-ordering policy (repro.control.scheduler.BudgetAwarePlan): the
    # scan then re-permutes the agents each round by the carried
    # (spent bits, -reward EMA, id) key — the in-program twin of the eager
    # BudgetAwareScheduler.  Homogeneous fleets only (the permutation
    # gathers over stacked agent data).  None = fixed sequential chain.
    # An AsyncStalePlan here instead selects the stale-read barrier
    # lowering (make_async_session_fn).
    scheduler: Any = None

    @property
    def num_agents(self) -> int:
        return len(self.cores)

    @property
    def ladder(self) -> tuple:
        """The codec rungs the scan must evaluate: the budget (or adaptive
        controller) ladder, or the single configured codec (None rung =
        privacy-only channel)."""
        if self.budget is not None:
            return self.budget.ladder
        if self.controller is not None:
            return self.controller.ladder
        return (self.codec,)

    @property
    def serve_ladder(self) -> tuple:
        """The rungs the traced serve step evaluates for [n, K] score
        blocks: the budget ladder (== the serve controller's, when both are
        set), the serve controller's ladder, or the single serve codec
        (falling back to the training codec; a None rung ships raw
        fp32)."""
        if self.budget is not None:
            return self.budget.ladder
        if self.serve_controller is not None:
            return self.serve_controller.ladder
        return (self.serve_codec if self.serve_codec is not None
                else self.codec,)

    @property
    def has_channel(self) -> bool:
        return (self.codec is not None or self.privacy is not None
                or self.budget is not None or self.controller is not None)


class SessionResult(NamedTuple):
    """Fixed-shape output of one compiled session (vmap-friendly).

    ``alphas``/``accs`` are [T, M]; ``executed`` marks (round, agent) slots
    the eager loop would have reached, ``valid`` the subset that produced a
    boosting component (executed and not the alpha<=0 stop trigger);
    ``params`` is a length-M tuple of per-agent param pytrees with a
    leading round axis [T, ...]; ``w_trace`` is the post-hop ignorance
    score per slot [T, M, n] (what each IgnoranceMsg carried); ``w`` is the
    final ignorance score.

    Wire-channel bookkeeping (trivial when the plan has no channel):
    ``sent`` [T, M] marks hops whose score actually crossed the wire
    (``valid`` minus budget skips), ``codec_idx`` [T, M] the ladder rung it
    shipped with (-1 = not sent), and ``exhausted`` whether the session bit
    budget ran dry — together they let ``Protocol._fit_compiled`` replay the
    exact encoded-bit ledger the eager transport would have booked.

    Every per-slot array is *slot*-major: index j is the j-th agent visited
    that round.  ``order`` [T, M] maps slot back to agent id — identity
    rows under sequential plans, the in-scan budget-aware permutation
    otherwise (``agent_major_result`` re-collects a permuted result into
    agent-major order for the serve path).
    """
    alphas: jnp.ndarray
    accs: jnp.ndarray
    executed: jnp.ndarray
    valid: jnp.ndarray
    params: tuple
    w_trace: jnp.ndarray
    w: jnp.ndarray
    sent: jnp.ndarray
    codec_idx: jnp.ndarray
    exhausted: jnp.ndarray
    order: jnp.ndarray = None


def plan_for(learners: Sequence, num_classes: int, *, max_rounds: int = 20,
             upstream: bool = True, stop_on_negative_alpha: bool = True,
             alpha_cap: float = 20.0, exact_reweight: bool = False,
             use_kernel: bool = True,
             kernel_interpret: bool | None = None,
             codec=None, privacy=None, budget=None,
             serve_codec=None, controller=None,
             serve_controller=None, scheduler=None) -> SessionPlan:
    """Build a SessionPlan from eager Learners (they must all be
    ``functional`` — have a LearnerCore)."""
    cores = []
    for m, lr in enumerate(learners):
        core = lr.core(num_classes)
        if core is None:
            raise ValueError(
                f"agent {m}: {type(lr).__name__} has no LearnerCore "
                f"(functional=False) — eager-only learners (tree/forest) "
                f"cannot ride the compiled backend")
        cores.append(core)
    if budget is not None or controller is not None:
        codec = None       # the budget/controller ladder drives codec choice
    if (budget is not None and serve_controller is not None
            and tuple(serve_controller.ladder) != tuple(budget.ladder)):
        raise ValueError(
            "a serve controller on a budgeted plan must share the budget's "
            f"ladder, got {serve_controller.ladder} vs {budget.ladder}")
    return SessionPlan(cores=tuple(cores), num_classes=num_classes,
                       max_rounds=max_rounds, upstream=upstream,
                       stop_on_negative_alpha=stop_on_negative_alpha,
                       alpha_cap=alpha_cap, exact_reweight=exact_reweight,
                       use_kernel=use_kernel,
                       kernel_interpret=kernel_interpret,
                       codec=codec, privacy=privacy, budget=budget,
                       serve_codec=serve_codec, controller=controller,
                       serve_controller=serve_controller,
                       scheduler=scheduler)


# ==================================================================== lowering
def _make_reweight(plan: SessionPlan, n: int):
    """Pick the eqs.-(10)/(12) implementation for score length n: the fused
    Pallas kernel when the tiling divides evenly (interpret mode off-TPU),
    else the pure-jnp formula — both bit-identical reductions for n <= bn."""
    if plan.exact_reweight:
        k = plan.num_classes
        return lambda w, r, a: scores.ignorance_update_exact(w, r, a, k)
    if plan.use_kernel and tiles_evenly(n):
        from repro.kernels import ops
        return lambda w, r, a: ops.ignorance_update(
            w, r, a, interpret=plan.kernel_interpret)
    return scores.ignorance_update


_INT32_MAX = np.iinfo(np.int32).max


def ladder_walk(costs, rem, floor=None):
    """Branchless degrade-then-skip ladder walk: the traced twin of
    :meth:`repro.comm.budget.BudgetSpec.choose_costs`.  ``costs`` are the
    static per-rung bit costs (ints or int32 scalars, best rung first),
    ``rem`` the remaining-budget int32 scalar, ``floor`` an optional
    controller rung the walk never goes finer than.  Returns the chosen
    rung as int32, -1 = skip.  Shared by the ASCII round body, the traced
    serve step, and the FedAvg lowering (repro.scenarios.compiled) so every
    budgeted program walks the one rule."""
    rung = jnp.asarray(-1, jnp.int32)
    for i in reversed(range(len(costs))):
        ok = jnp.asarray(costs[i], jnp.int32) <= rem
        if floor is not None:
            ok = ok & (jnp.asarray(i, jnp.int32) >= floor)
        rung = jnp.where(ok, jnp.asarray(i, jnp.int32), rung)
    return rung


def rung_select(rung, values, default):
    """Pick ``values[rung]`` (with ``default`` at rung -1) — the payload
    half of the ladder walk, single-rung ladders short-circuiting exactly
    like the inlined originals."""
    if len(values) == 1:
        return values[0]
    return jnp.select([rung == i for i in range(len(values))], values,
                      default)


def make_session_fn(plan: SessionPlan, feature_shapes: tuple,
                    qmax_arg: bool = False, control_arg: bool = False,
                    live: bool = False):
    """Lower ``plan`` for per-agent feature shapes into a pure callable

        session_fn(key, Xs, classes) -> SessionResult

    — a single ``lax.scan`` over interchange rounds, agents unrolled in the
    round body.  The callable is pure and fixed-shape, so it jits, vmaps
    (``fleet_run``) and shards like any other program.

    With a wire channel on the plan the scan additionally carries the
    per-link codec residuals and (under a budget) the spent-bit counters,
    reproducing the eager transports' channel hop for hop.  With a
    budget-aware ``plan.scheduler`` the scan also carries the per-agent
    spent-bit signal and reward EMAs and re-permutes the agents each round
    in-program (homogeneous fleets only) — the order the eager
    ``BudgetAwareScheduler`` would pick, bit for bit.

    ``qmax_arg`` re-parameterizes a QuantCodec plan's clipping level as a
    *traced* trailing argument ``session_fn(key, Xs, classes, qmax)`` so
    codec sweeps vmap into one program (:func:`quant_sweep_run`).
    ``control_arg`` instead re-parameterizes the *control plane* — adaptive
    controller thresholds/beta and budget session/link caps — as traced
    trailing arguments ``(cuts, beta, session_cap, link_cap)`` so
    controller/budget hyperparameter sweeps vmap into one program too
    (:func:`control_sweep_run`; ``_INT32_MAX`` caps mean "uncapped").

    ``live`` adds one :func:`repro.telemetry.live.emit_round` tap per scan
    step — round index, per-round priced bits (the same formulas the
    post-run replay books), sent/skipped hop counts, an exhaustion edge —
    with an ``active`` flag the host sink uses to drop post-stop rounds
    (`lax.cond` gating would break under vmap).  The tap has no data flow
    back into the program, so live programs stay bit-identical to dark
    ones; dark programs are byte-unchanged (the flag is a cache key).
    """
    if len(feature_shapes) != plan.num_agents:
        raise ValueError(f"{plan.num_agents} cores but "
                         f"{len(feature_shapes)} feature shapes")
    k = plan.num_classes
    cores = plan.cores
    codec, privacy, budget = plan.codec, plan.privacy, plan.budget
    controller = plan.controller
    ladder = plan.ladder
    has_channel = plan.has_channel
    stateful = codec is not None and codec.stateful
    if qmax_arg:
        from repro.comm.codecs import QuantCodec
        if budget is not None or controller is not None \
                or not isinstance(codec, QuantCodec):
            raise ValueError("qmax_arg sweeps need a plain QuantCodec plan")
    if control_arg:
        if qmax_arg:
            raise ValueError("qmax_arg and control_arg are separate sweep "
                             "modes; pick one")
        if budget is None and controller is None:
            raise ValueError("control_arg sweeps trace controller cuts/beta "
                             "and budget caps; the plan has neither")
    scheduler = plan.scheduler
    if scheduler is not None:
        from repro.control.scheduler import BudgetAwarePlan
        if not isinstance(scheduler, BudgetAwarePlan):
            raise ValueError(
                f"SessionPlan.scheduler must be a BudgetAwarePlan for the "
                f"sequential-scan lowering, got {type(scheduler).__name__} "
                f"(stale/async plans lower via make_async_session_fn)")
        if len(set(cores)) != 1 or len(set(feature_shapes)) != 1:
            raise ValueError(
                "budget-aware scheduling lowers into the scan only for "
                "homogeneous fleets (equal learner cores and feature "
                "shapes — the in-program round permutation gathers over "
                f"stacked agent data); got {len(set(cores))} distinct "
                f"cores and shapes {sorted(set(feature_shapes))}")
        if scheduler.spend_signal == "link" and budget is None:
            raise ValueError("spend_signal='link' orders by budgeted link "
                             "spend, but the plan has no budget")
    if budget is not None and not control_arg:
        for cap in (budget.session_bits, budget.link_bits):
            if cap is not None and cap >= _INT32_MAX:
                raise ValueError(f"budget caps must fit int32 (the scan's "
                                 f"spent-bit counters), got {cap}")
    num = plan.num_agents

    def session_fn(key: jax.Array, Xs: tuple, classes: jnp.ndarray,
                   qmax=None, cuts=None, beta=None, session_cap=None,
                   link_cap=None) -> SessionResult:
        from repro.comm.codecs import channel_apply
        classes = classes.astype(jnp.int32)
        n = classes.shape[0]
        onehot = jax.nn.one_hot(classes, k)
        reweight = _make_reweight(plan, n)
        w0 = scores.init_ignorance(n)
        ones = jnp.ones((n,), jnp.float32)
        if scheduler is not None:
            from repro.control.scheduler import (reward_ema_update,
                                                 traced_round_order)
            Xstack = jnp.stack(Xs)
            if scheduler.spend_signal == "wire":
                # the plain-metered ordering signal: each shipped hop's
                # ignorance wire bits plus the 32-bit ModelWeightMsg —
                # exactly what TransportLog.bits_by_src tallies per sender
                wire_costs = tuple(
                    (int(c.wire_bits(n)) if c is not None else n * 32) + 32
                    for c in ladder)
        if budget is not None:
            costs = tuple(jnp.asarray(c, jnp.int32)
                          for c in budget.hop_costs(n))
            min_cost = min(budget.hop_costs(n))
            # setup spend priced by the Message classes themselves, so the
            # scan's counter can never drift from the eager metered ledger
            from repro.core.engine import LabelsMsg, SampleIdsMsg
            setup_bits = (num - 1) * (LabelsMsg("", "", n).bits
                                      + SampleIdsMsg("", "", n).bits)
        if live:
            from repro.core.engine import LabelsMsg, SampleIdsMsg
            from repro.telemetry.live import emit_round, key_salt
            live_setup = (num - 1) * (LabelsMsg("", "", n).bits
                                      + SampleIdsMsg("", "", n).bits)
            # per-hop priced bits by final rung (-1 = unsent -> 0): the
            # replay's IgnoranceMsg wire/raw bits plus the 32-bit alpha
            # message — identical formulas, so the live counters land
            # exactly on the replay-booked ledger
            if budget is not None:
                live_hop_costs = tuple(int(c) for c in budget.hop_costs(n))
            elif has_channel:
                live_hop_costs = tuple(
                    (int(c.wire_bits(n)) if c is not None else n * 32) + 32
                    for c in ladder)
            else:
                live_hop_costs = (n * 32 + 32,)

        def round_body(carry, t_idx):
            w, key, stopped = carry["w"], carry["key"], carry["stopped"]
            u = ones
            outs = []
            if live:
                live_active = jnp.logical_not(stopped)
                live_entry_exh = carry.get("exhausted",
                                           jnp.zeros((), bool))
                live_bits = jnp.asarray(0, jnp.int32)
                live_sent = jnp.asarray(0, jnp.int32)
                live_skip = jnp.asarray(0, jnp.int32)
            if scheduler is not None:
                # the round permutation, from the carried signal — computed
                # at round entry exactly when the eager scheduler's
                # round_order reads its live transport state
                if scheduler.spend_signal == "link":
                    spent_sig = carry["link"].sum(axis=1, dtype=jnp.int32)
                elif scheduler.spend_signal == "wire":
                    spent_sig = carry["wire"]
                else:
                    spent_sig = jnp.zeros((num,), jnp.int32)
                ema_sig = (carry["ema"] if scheduler.use_reward
                           else jnp.zeros((num,), jnp.float32))
                perm = traced_round_order(spent_sig, ema_sig)
            # Agents unrolled: heterogeneous feature widths / cores, but a
            # fixed chain shape — exactly Algorithm 1's inner lines 3-11.
            # named_scope tags the HLO so profiler traces group ops by hop
            # (metadata only — the lowered computation is unchanged).
            for j, core in enumerate(cores):
                if scheduler is None:
                    src = j                       # slot j == agent j
                    X_j, shape_j = Xs[j], feature_shapes[j]
                else:
                    src = perm[j]                 # slot j's agent this round
                    dst_agent = perm[(j + 1) % num]
                    X_j, shape_j = Xstack[src], feature_shapes[0]
                with jax.named_scope(f"ascii_hop_{j}"):
                    key, sub = jax.random.split(key)
                    params = core.fit(core.init(sub, shape_j), sub,
                                      X_j, onehot, w)
                    r = (core.predict(params, X_j) == classes
                         ).astype(jnp.float32)
                u_in = ones if (j == 0 or not plan.upstream) else u
                a, rbar = scores.model_weight(w, r, k, u=u_in,
                                              alpha_cap=plan.alpha_cap)
                executed = jnp.logical_not(stopped)
                if plan.stop_on_negative_alpha:
                    trigger = executed & (a <= 0)   # Algorithm 1, line 8
                else:
                    trigger = jnp.zeros((), bool)
                valid = executed & jnp.logical_not(trigger)
                if scheduler is not None and scheduler.use_reward:
                    # the observed-reward EMA advances on every slot the
                    # eager loop reaches (observe runs before the stop
                    # check), through the shared f32 update
                    prev = carry["ema"][src]
                    upd = reward_ema_update(scheduler.reward_smoothing,
                                            prev, rbar,
                                            ~carry["seen"][src])
                    carry["ema"] = carry["ema"].at[src].set(
                        jnp.where(executed, upd, prev))
                    carry["seen"] = carry["seen"].at[src].set(
                        carry["seen"][src] | executed)
                # Only a component-producing slot advances u and w — the
                # eager loop breaks before touching them on a stop trigger,
                # and never reaches them once stopped.
                u = jnp.where(valid,
                              scores.upstream_factor_update(u, a, r, k), u)
                w_upd = reweight(w, r, a)

                if not has_channel:
                    sent = valid
                    rung = jnp.where(sent, 0, -1).astype(jnp.int32)
                    w = jnp.where(valid, w_upd, w)
                else:
                    # ---- the wire: controller/budget rung choice, DP
                    # noise, codec — the same decision rule and traced
                    # channel the eager transports run
                    # (Transport._controller_rung / BudgetSpec.choose /
                    # channel_apply)
                    if controller is not None:
                        # branchless adaptive rung from (receiver's stale
                        # vector, outgoing vector); the EMA advances on
                        # every slot the eager loop reaches an interchange
                        # for.  cuts/beta are None outside control_arg
                        # sweeps — the controller then uses its static
                        # thresholds, unchanged bit for bit.
                        c_rung, ctrl_new = controller.step(w, w_upd,
                                                           carry["ctrl"],
                                                           cuts=cuts,
                                                           beta=beta)
                        carry["ctrl"] = jnp.where(valid, ctrl_new,
                                                  carry["ctrl"])
                    if budget is not None:
                        cap_session = (session_cap if control_arg
                                       else budget.session_bits)
                        cap_link = (link_cap if control_arg
                                    else budget.link_bits)
                        rem = jnp.asarray(_INT32_MAX, jnp.int32)
                        if cap_session is not None:
                            rem_s = (jnp.asarray(cap_session,
                                                 jnp.int32) - carry["spent"])
                            rem = jnp.minimum(rem, rem_s)
                        if cap_link is not None:
                            link_spent_j = (carry["link"][src, dst_agent]
                                            if scheduler is not None
                                            else carry["link"][j])
                            rem = jnp.minimum(
                                rem, jnp.asarray(cap_link, jnp.int32)
                                - link_spent_j)
                        # the controller rung is a floor on the walk:
                        # never finer, budget may go coarser
                        rung = ladder_walk(
                            costs, rem,
                            floor=c_rung if controller is not None else None)
                        sendable = rung >= 0
                    elif controller is not None:
                        rung = c_rung
                        sendable = jnp.ones((), bool)
                    else:
                        rung = jnp.asarray(0, jnp.int32)
                        sendable = jnp.ones((), bool)
                    state_j = carry["resid"][src] if stateful else None
                    # privacy noise is rung-independent (same key, same
                    # input): apply it once, then codec-only roundtrips per
                    # rung — the per-stage key folds inside channel_apply
                    # depend only on `sub`, so this decomposition is
                    # bit-identical to the eager fused channel
                    w_noised, _ = channel_apply(None, privacy, w_upd, sub,
                                                None)
                    pairs = [channel_apply(c, None, w_noised, sub, state_j,
                                           qmax=qmax) for c in ladder]
                    w_chan = rung_select(rung, [p[0] for p in pairs], w_upd)
                    sent = valid & sendable
                    w = jnp.where(sent, w_chan, w)
                    if stateful:
                        # error-feedback residuals are per *sender* (the
                        # eager engine keys codec_state by src name)
                        carry["resid"] = carry["resid"].at[src].set(
                            jnp.where(sent, pairs[0][1], state_j))
                    if budget is not None:
                        cost = jnp.select(
                            [rung == i for i in range(len(ladder))],
                            list(costs), jnp.asarray(0, jnp.int32))
                        add = jnp.where(sent, cost, 0)
                        carry["spent"] = carry["spent"] + add
                        if scheduler is not None:
                            carry["link"] = carry["link"].at[
                                src, dst_agent].add(add)
                        else:
                            carry["link"] = carry["link"].at[j].add(add)
                        if cap_session is not None:
                            carry["exhausted"] = carry["exhausted"] | (
                                valid & (rem_s < min_cost))
                    rung = jnp.where(sent, rung, -1)
                if scheduler is not None \
                        and scheduler.spend_signal == "wire":
                    # per-sender metered-ledger tally (ignorance wire bits
                    # + the 32-bit alpha message) for next round's ordering
                    wcost = jnp.select(
                        [rung == i for i in range(len(wire_costs))],
                        [jnp.asarray(c, jnp.int32) for c in wire_costs],
                        jnp.asarray(0, jnp.int32))
                    carry["wire"] = carry["wire"].at[src].add(
                        jnp.where(sent, wcost, 0))
                if live:
                    live_sent = live_sent + jnp.where(sent, 1, 0)
                    live_skip = live_skip + jnp.where(
                        valid & jnp.logical_not(sent), 1, 0)
                    live_bits = live_bits + jnp.select(
                        [rung == i for i in range(len(live_hop_costs))],
                        [jnp.asarray(c, jnp.int32)
                         for c in live_hop_costs],
                        jnp.asarray(0, jnp.int32))
                stopped = stopped | trigger
                outs.append((params, a, rbar, executed, valid, w, sent,
                             rung, jnp.asarray(src, jnp.int32)))
            if budget is not None \
                    and (control_arg or budget.session_bits is not None):
                # the eager engine notices exhaustion at the *next* round's
                # entry: the current round finishes, later ones never start
                stopped = stopped | carry["exhausted"]
            if live:
                new_exh = jnp.where(
                    carry.get("exhausted", jnp.zeros((), bool))
                    & jnp.logical_not(live_entry_exh), 1, 0)
                emit_round(t_idx, live_active,
                           live_bits + jnp.where(t_idx == 0,
                                                 live_setup, 0)
                           + key_salt(key),
                           live_sent, live_skip, new_exh)
            carry = dict(carry, w=w, key=key, stopped=stopped)
            return carry, tuple(outs)

        init = {"w": w0, "key": key, "stopped": jnp.zeros((), bool)}
        if stateful:
            init["resid"] = jnp.zeros((num, n), jnp.float32)
        if controller is not None:
            init["ctrl"] = controller.init_state()
        if budget is not None:
            init["spent"] = jnp.asarray(setup_bits, jnp.int32)
            # per directed link under a permuting scheduler (any src->dst
            # pair can carry a hop), per chain slot otherwise
            init["link"] = (jnp.zeros((num, num), jnp.int32)
                            if scheduler is not None
                            else jnp.zeros((num,), jnp.int32))
            init["exhausted"] = jnp.zeros((), bool)
        if scheduler is not None:
            if scheduler.use_reward:
                init["ema"] = jnp.zeros((num,), jnp.float32)
                init["seen"] = jnp.zeros((num,), bool)
            if scheduler.spend_signal == "wire":
                init["wire"] = jnp.zeros((num,), jnp.int32)
        if live:
            # round indices as scan xs feed the taps; the dark program
            # keeps its byte-identical no-xs scan
            fin, ys = jax.lax.scan(round_body, init,
                                   jnp.arange(plan.max_rounds))
        else:
            fin, ys = jax.lax.scan(round_body, init, None,
                                   length=plan.max_rounds)
        return SessionResult(
            alphas=jnp.stack([y[1] for y in ys], axis=1),
            accs=jnp.stack([y[2] for y in ys], axis=1),
            executed=jnp.stack([y[3] for y in ys], axis=1),
            valid=jnp.stack([y[4] for y in ys], axis=1),
            params=tuple(y[0] for y in ys),
            w_trace=jnp.stack([y[5] for y in ys], axis=1),
            w=fin["w"],
            sent=jnp.stack([y[6] for y in ys], axis=1),
            codec_idx=jnp.stack([y[7] for y in ys], axis=1),
            exhausted=fin.get("exhausted", jnp.zeros((), bool)),
            order=jnp.stack([y[8] for y in ys], axis=1))

    if control_arg:
        return (lambda key, Xs, classes, cuts, beta, session_cap, link_cap:
                session_fn(key, Xs, classes, None, cuts, beta, session_cap,
                           link_cap))
    if not qmax_arg:
        return lambda key, Xs, classes: session_fn(key, Xs, classes)
    return session_fn


@functools.lru_cache(maxsize=64)
def _session_program(plan: SessionPlan, feature_shapes: tuple,
                     live: bool = False):
    return jax.jit(make_session_fn(plan, feature_shapes, live=live))


def compiled_session(plan: SessionPlan, key: jax.Array,
                     Xs: Sequence[jnp.ndarray],
                     classes: jnp.ndarray, *,
                     live: bool = False) -> SessionResult:
    """Run one ASCII session as a single compiled program (cached per
    (plan, feature shapes, live))."""
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = tuple(x.shape[1:] for x in Xs)
    return _session_program(plan, shapes, live)(key, Xs, classes)


# ================================================================ async barrier
@dataclass(frozen=True)
class AsyncStalePlan:
    """Static (hashable) marker selecting the stale-read asynchronous
    lowering: rides ``SessionPlan.scheduler`` the way
    :class:`repro.control.scheduler.BudgetAwarePlan` does, and routes
    ``make_async_session_fn`` instead of the sequential scan.  Carries no
    knobs — clock skew comes from scenarios, which the compiled backend
    rejects."""


class AsyncSessionResult(NamedTuple):
    """Fixed-shape output of one compiled *asynchronous* session.

    ``alphas``/``accs``/``executed``/``valid``/``params`` are the async
    twins of :class:`SessionResult`'s fields, in agent-id order (the async
    barrier has no chain order; ``executed`` rows are all-True or
    all-False).  ``w_trace`` [T, M, n] holds the mid-merge snapshots the
    channel-less barrier's per-agent IgnoranceMsgs carry; ``w_bar`` [T, n]
    the per-round barrier release *as published* (post DP noise + codec —
    what the single barrier IgnoranceMsg ships when the plan has a
    channel); ``sent`` [T] whether the barrier actually released (budget
    skips False), ``codec_idx`` [T] the ladder rung it shipped at (-1 =
    raw / skipped), ``exhausted`` whether the session bit budget ran dry.
    """
    alphas: jnp.ndarray
    accs: jnp.ndarray
    executed: jnp.ndarray
    valid: jnp.ndarray
    params: tuple
    w_trace: jnp.ndarray
    w_bar: jnp.ndarray
    w: jnp.ndarray
    sent: jnp.ndarray
    codec_idx: jnp.ndarray
    exhausted: jnp.ndarray


def make_async_session_fn(plan: SessionPlan, feature_shapes: tuple,
                          live: bool = False):
    """Lower the stale-read asynchronous barrier (``AsyncStaleScheduler``)
    into a pure callable ``session_fn(key, Xs, classes) ->
    AsyncSessionResult`` — one ``lax.scan`` over barrier rounds.

    Each round replicates ``Session._step_stale`` exactly: every agent
    fits against the same round-t score (per-agent PRNG splits in id
    order), positive updates merge multiplicatively with 1/M damping in id
    order, and the merged score normalizes at the barrier.  With a wire
    channel the *release* is the channel point: one DP noise draw + codec
    encode per barrier (key split after the per-agent splits), and under a
    budget one session-level ladder walk over the bare payload costs —
    per-barrier metering, one ledger, instead of the per-hop fiction the
    eager path used to reject.  A skipped release leaves the published
    score stale, exactly like a skipped sequential hop.
    """
    if len(feature_shapes) != plan.num_agents:
        raise ValueError(f"{plan.num_agents} cores but "
                         f"{len(feature_shapes)} feature shapes")
    if plan.controller is not None:
        raise ValueError("adaptive controllers do not apply to the async "
                         "barrier (its EMA statistic is defined on per-hop "
                         "interchange, which the barrier path has none of)")
    k = plan.num_classes
    cores = plan.cores
    codec, privacy, budget = plan.codec, plan.privacy, plan.budget
    ladder = plan.ladder
    has_channel = plan.has_channel
    stateful = codec is not None and codec.stateful
    if budget is not None:
        for cap in (budget.session_bits, budget.link_bits):
            if cap is not None and cap >= _INT32_MAX:
                raise ValueError(f"budget caps must fit int32 (the scan's "
                                 f"spent-bit counters), got {cap}")
    num = plan.num_agents

    def session_fn(key: jax.Array, Xs: tuple,
                   classes: jnp.ndarray) -> AsyncSessionResult:
        from repro.comm.codecs import channel_apply
        classes = classes.astype(jnp.int32)
        n = classes.shape[0]
        onehot = jax.nn.one_hot(classes, k)
        w0 = scores.init_ignorance(n)
        if budget is not None:
            costs = tuple(jnp.asarray(c, jnp.int32)
                          for c in budget.payload_costs(n))
            min_cost = min(budget.payload_costs(n))
            from repro.core.engine import LabelsMsg, SampleIdsMsg
            setup_bits = (num - 1) * (LabelsMsg("", "", n).bits
                                      + SampleIdsMsg("", "", n).bits)
        if live:
            from repro.core.engine import LabelsMsg, SampleIdsMsg
            from repro.telemetry.live import emit_round, key_salt
            live_setup = (num - 1) * (LabelsMsg("", "", n).bits
                                      + SampleIdsMsg("", "", n).bits)
            if has_channel:
                # the barrier release's priced bits per rung: what the
                # async replay books for the single barrier IgnoranceMsg
                live_bar_costs = (tuple(int(c) for c
                                        in budget.payload_costs(n))
                                  if budget is not None else
                                  tuple(int(c.wire_bits(n))
                                        if c is not None else n * 32
                                        for c in ladder))

        def round_body(carry, t_idx):
            w, key, stopped = carry["w"], carry["key"], carry["stopped"]
            executed = jnp.logical_not(stopped)
            if live:
                live_entry_exh = carry.get("exhausted",
                                           jnp.zeros((), bool))
            fits = []
            # stale reads: every agent fits against the same round-t score,
            # per-agent key splits in id order (the eager fits loop)
            for j, core in enumerate(cores):
                with jax.named_scope(f"ascii_async_fit_{j}"):
                    key, sub = jax.random.split(key)
                    params = core.fit(core.init(sub, feature_shapes[j]),
                                      sub, Xs[j], onehot, w)
                    r = (core.predict(params, Xs[j]) == classes
                         ).astype(jnp.float32)
                a, rbar = scores.model_weight(w, r, k,
                                              alpha_cap=plan.alpha_cap)
                fits.append((params, r, a, rbar))
            # damped multiplicative merge at the barrier, agent-id order
            w_next = w
            any_pos = jnp.zeros((), bool)
            pos_count = jnp.asarray(0, jnp.int32)
            snaps = []
            for params, r, a, rbar in fits:
                use = executed & (a > 0)
                any_pos = any_pos | use
                pos_count = pos_count + jnp.where(use, 1, 0)
                w_next = jnp.where(use,
                                   w_next * jnp.exp((a / num) * (1.0 - r)),
                                   w_next)
                snaps.append(w_next)
            w_bar = w_next / jnp.maximum(jnp.sum(w_next), 1e-12)
            if not has_channel:
                released = w_bar
                sent = executed
                rung = jnp.asarray(-1, jnp.int32)
                w = jnp.where(executed, w_bar, w)
            else:
                # per-barrier release: DP noise + codec encode happen at
                # merge time, once per round — key split *after* the
                # per-agent fit splits, like the eager barrier
                key, kbar = jax.random.split(key)
                if budget is not None:
                    # the raw alpha messages book before the walk reads
                    # the ledger (the eager merge loop sends them first);
                    # link caps don't apply — the barrier is session-level
                    carry["spent"] = carry["spent"] + 32 * pos_count
                    rem_s = jnp.asarray(_INT32_MAX, jnp.int32)
                    if budget.session_bits is not None:
                        rem_s = (jnp.asarray(budget.session_bits, jnp.int32)
                                 - carry["spent"])
                    rung = ladder_walk(costs, rem_s)
                    sendable = rung >= 0
                    if budget.session_bits is not None:
                        carry["exhausted"] = carry["exhausted"] | (
                            executed & (rem_s < min_cost))
                else:
                    rung = jnp.asarray(0, jnp.int32)
                    sendable = jnp.ones((), bool)
                state = carry["resid"] if stateful else None
                # noise once (rung-independent), then codec-only
                # roundtrips per rung — bit-identical to the eager fused
                # channel (see the sequential round_body note)
                noised, _ = channel_apply(None, privacy, w_bar, kbar, None)
                pairs = [channel_apply(c, None, noised, kbar, state)
                         for c in ladder]
                released = rung_select(rung, [p[0] for p in pairs], w_bar)
                sent = executed & sendable
                w = jnp.where(sent, released, w)
                if stateful:
                    carry["resid"] = jnp.where(sent, pairs[0][1], state)
                if budget is not None:
                    cost = jnp.select(
                        [rung == i for i in range(len(ladder))],
                        list(costs), jnp.asarray(0, jnp.int32))
                    carry["spent"] = carry["spent"] + jnp.where(sent, cost,
                                                                0)
                rung = jnp.where(sent, rung, -1)
            if plan.stop_on_negative_alpha:
                stopped = stopped | (executed & jnp.logical_not(any_pos))
            if budget is not None and budget.session_bits is not None:
                stopped = stopped | carry["exhausted"]
            if live:
                if not has_channel:
                    # per positive agent: raw IgnoranceMsg + alpha message
                    live_bits = pos_count * jnp.asarray(n * 32 + 32,
                                                        jnp.int32)
                    live_ign = pos_count
                    live_skip = jnp.asarray(0, jnp.int32)
                else:
                    # raw alpha messages per positive agent + the single
                    # barrier release at its priced rung
                    live_bits = 32 * pos_count + jnp.select(
                        [rung == i for i in range(len(live_bar_costs))],
                        [jnp.asarray(c, jnp.int32)
                         for c in live_bar_costs],
                        jnp.asarray(0, jnp.int32))
                    live_ign = jnp.where(sent, 1, 0)
                    live_skip = (jnp.where(executed
                                           & jnp.logical_not(sent), 1, 0)
                                 if budget is not None
                                 else jnp.asarray(0, jnp.int32))
                new_exh = jnp.where(
                    carry.get("exhausted", jnp.zeros((), bool))
                    & jnp.logical_not(live_entry_exh), 1, 0)
                emit_round(t_idx, executed,
                           live_bits + jnp.where(t_idx == 0,
                                                 live_setup, 0)
                           + key_salt(key),
                           live_ign, live_skip, new_exh)
            carry = dict(carry, w=w, key=key, stopped=stopped)
            outs = tuple(
                (params, a, rbar, executed, executed & (a > 0), snaps[j])
                for j, (params, r, a, rbar) in enumerate(fits))
            return carry, (outs, released, sent, rung)

        init = {"w": w0, "key": key, "stopped": jnp.zeros((), bool)}
        if stateful:
            init["resid"] = jnp.zeros((n,), jnp.float32)
        if budget is not None:
            init["spent"] = jnp.asarray(setup_bits, jnp.int32)
            init["exhausted"] = jnp.zeros((), bool)
        if live:
            fin, (ys, w_bars, sents, rungs) = jax.lax.scan(
                round_body, init, jnp.arange(plan.max_rounds))
        else:
            fin, (ys, w_bars, sents, rungs) = jax.lax.scan(
                round_body, init, None, length=plan.max_rounds)
        return AsyncSessionResult(
            alphas=jnp.stack([y[1] for y in ys], axis=1),
            accs=jnp.stack([y[2] for y in ys], axis=1),
            executed=jnp.stack([y[3] for y in ys], axis=1),
            valid=jnp.stack([y[4] for y in ys], axis=1),
            params=tuple(y[0] for y in ys),
            w_trace=jnp.stack([y[5] for y in ys], axis=1),
            w_bar=w_bars,
            w=fin["w"],
            sent=sents,
            codec_idx=rungs,
            exhausted=fin.get("exhausted", jnp.zeros((), bool)))

    return session_fn


@functools.lru_cache(maxsize=64)
def _async_session_program(plan: SessionPlan, feature_shapes: tuple,
                           live: bool = False):
    return jax.jit(make_async_session_fn(plan, feature_shapes, live=live))


def async_session(plan: SessionPlan, key: jax.Array,
                  Xs: Sequence[jnp.ndarray],
                  classes: jnp.ndarray, *,
                  live: bool = False) -> AsyncSessionResult:
    """Run one stale-read asynchronous session as a single compiled program
    (cached per (plan, feature shapes, live))."""
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = tuple(x.shape[1:] for x in Xs)
    return _async_session_program(plan, shapes, live)(key, Xs, classes)


def fitted_from_async_result(plan: SessionPlan, result: AsyncSessionResult,
                             learners: Sequence):
    """Rebuild the eager engine's result objects from a compiled async run
    — byte-compatible with the eager ``_step_stale`` session's
    ``fitted()``.  Agent-major throughout (the barrier has no chain order);
    every executed round records all M alphas/accs, components come from
    the positive-alpha subset in id order."""
    from repro.core.engine import Component, FittedASCII

    alphas = np.asarray(result.alphas)
    accs = np.asarray(result.accs)
    executed = np.asarray(result.executed)
    valid = np.asarray(result.valid)
    components, history = [], []
    for t in range(plan.max_rounds):
        if not executed[t].any():
            break                        # the eager loop stopped before t
        rec = {"round": t,
               "alphas": [float(a) for a in alphas[t]],
               "accs": [float(a) for a in accs[t]]}
        for m in range(plan.num_agents):
            if valid[t, m]:
                params_tm = jax.tree.map(lambda x, _t=t: x[_t],
                                         result.params[m])
                components.append(Component(m, t, float(alphas[t, m]),
                                            params_tm))
        history.append(rec)
    return FittedASCII(components, list(learners), plan.num_classes, history)


# ======================================================================== fleet
@functools.lru_cache(maxsize=64)
def _fleet_program(plan: SessionPlan, feature_shapes: tuple,
                   data_batched: bool, axis_name: str | None,
                   live: bool = False):
    fn = make_session_fn(plan, feature_shapes, live=live)
    data_ax = 0 if data_batched else None
    vf = jax.vmap(fn, in_axes=(0, data_ax, data_ax))
    if axis_name is None:
        return jax.jit(vf)

    from repro.sharding.context import shard_map  # version shim
    P = jax.sharding.PartitionSpec

    def sharded(keys, Xs, classes):
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), (axis_name,))
        spec_b = P(axis_name)
        spec_data = spec_b if data_batched else P()
        in_specs = (spec_b, tuple(spec_data for _ in Xs), spec_data)
        out_specs = jax.tree.map(lambda _: spec_b,
                                 jax.eval_shape(vf, keys, Xs, classes))
        return shard_map(vf, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs)(keys, Xs, classes)

    return jax.jit(sharded)


def fleet_run(plan: SessionPlan, keys: jax.Array, Xs: Sequence[jnp.ndarray],
              classes: jnp.ndarray, *, data_batched: bool = False,
              shard_axis: str | None = None,
              live: bool = False) -> SessionResult:
    """Run a whole fleet of sessions as one vmapped compiled program.

    ``keys`` is [S] session PRNG keys.  With ``data_batched=False`` every
    session sees the same (Xs, classes) cohort (seed fleets, e.g. paper
    replication sweeps); with True, ``Xs[m]`` is [S, n, p_m] and ``classes``
    [S, n] — one cohort per session.  ``shard_axis`` optionally shard_maps
    the session axis across all local devices (the engine mesh's data axis)
    so fleets scale past one chip; the device count must then divide S
    evenly.  Returns a SessionResult with a leading session axis.

    ``live`` streams one progress tap per (session, round) to the
    installed :class:`~repro.telemetry.live.LiveSink` while the fleet
    executes — the vmap unrolls the callback per session, each tap
    carrying that session's unbatched scalars.  Local fleets only
    (``shard_axis`` callbacks are not supported).
    """
    if live and shard_axis is not None:
        raise ValueError("live emission does not compose with shard_map "
                         "fleets — run --watch fleets unsharded")
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = tuple(x.shape[2:] if data_batched else x.shape[1:] for x in Xs)
    return _fleet_program(plan, shapes, data_batched, shard_axis, live)(
        keys, Xs, classes)


# =================================================================== serve step
class ServeResult(NamedTuple):
    """Fixed-shape output of the traced distributed-prediction step.

    ``preds`` [n] is the head agent's argmax; ``blocks`` [M, n, K] the
    decoded per-agent score blocks as shipped (slot 0 = the head's own raw
    block, which never crosses the wire); ``sent`` [M] marks blocks that
    actually shipped (head False; budget skips False), ``codec_idx`` [M]
    the serve-ladder rung each shipped with (-1 = raw / not sent), and
    ``exhausted`` whether the session bit budget died mid-predict —
    together they let ``Protocol._replay_serve`` book a byte-identical
    serve ledger.
    """
    preds: jnp.ndarray
    blocks: jnp.ndarray
    sent: jnp.ndarray
    codec_idx: jnp.ndarray
    exhausted: jnp.ndarray


def make_serve_fn(plan: SessionPlan, feature_shapes: tuple,
                  qmax_arg: bool = False, live: bool = False):
    """Lower ``plan``'s serve path into a pure callable

        serve_fn(key, Xs, params, alphas, valid, rem_session, rem_link,
                 deliver) -> ServeResult

    — the traced twin of ``Session.predict_distributed``.  Each agent's
    [n, K] block is its alpha-weighted coded votes over its own components,
    accumulated by a ``lax.scan`` over rounds so float addition order
    matches the eager ``AgentEndpoint.score_block`` bit for bit; non-head
    blocks then cross the serve channel — DP noise, adaptive/budget rung
    choice via the same rules the eager transports apply, codec roundtrip —
    before the head sums and argmaxes.  ``rem_session`` / ``rem_link`` [M]
    are the remaining-budget counters (int32) the walk starts from; ignored
    by unbudgeted plans.  ``deliver`` [M] bool gates which non-head blocks
    cross the wire at all: a False slot contributes nothing, books no bits
    and records no release — the serve engine's degrade-to-head-only
    admission outcome (``deliver = [True, False, ...]``); all-True is a
    normal serve.  ``qmax_arg`` re-parameterizes a QuantCodec serve
    channel's clipping level as a traced trailing argument for codec sweeps
    (:func:`quant_sweep_run`).
    """
    if len(feature_shapes) != plan.num_agents:
        raise ValueError(f"{plan.num_agents} cores but "
                         f"{len(feature_shapes)} feature shapes")
    from repro.core.encoding import encode_labels
    k = plan.num_classes
    cores = plan.cores
    privacy, budget = plan.privacy, plan.budget
    serve_controller = plan.serve_controller
    ladder = plan.serve_ladder
    if qmax_arg:
        from repro.comm.codecs import QuantCodec
        if budget is not None or serve_controller is not None \
                or not isinstance(ladder[0], QuantCodec):
            raise ValueError("qmax_arg sweeps need a plain QuantCodec plan")

    def serve_fn(key, Xs, params, alphas, valid, rem_session, rem_link,
                 deliver, qmax=None) -> ServeResult:
        from repro.comm.codecs import channel_apply
        n = int(Xs[0].shape[0])
        shape = (n, k)
        if budget is not None:
            costs = budget.serve_costs(shape)
            if max(costs) >= _INT32_MAX:
                raise ValueError(f"serve block costs must fit int32 (the "
                                 f"budget counters), got {max(costs)}")
            min_cost = min(costs)
            rem_s = jnp.asarray(rem_session, jnp.int32)
        deliver = jnp.asarray(deliver, bool)
        if live:
            from repro.telemetry.live import emit_serve, key_salt
            # per-block priced bits: what _replay_serve books for each
            # shipped ScoreBlockMsg (encoded wire bits, raw 32*n*K when
            # the serve rung is the identity)
            live_costs = (tuple(int(c) for c in budget.serve_costs(shape))
                          if budget is not None else
                          tuple(int(c.wire_bits(shape)) if c is not None
                                else 32 * n * k for c in ladder))
            live_bits = jnp.asarray(0, jnp.int32)
            live_sent = jnp.asarray(0, jnp.int32)
            live_skip = jnp.asarray(0, jnp.int32)
        total = None
        blocks, sent_l, rung_l = [], [], []
        exhausted = jnp.zeros((), bool)
        for j, core in enumerate(cores):
            X = Xs[j]
            a_j = alphas[:, j].astype(jnp.float32)
            v_j = valid[:, j]

            def body(acc, sl, _core=core, _X=X):
                p, a, v = sl
                pred = _core.predict(p, _X)
                return acc + jnp.where(v, a, 0.0) * encode_labels(pred, k), None

            # named_scope tags the HLO per serve block for profiler traces
            # (metadata only — the lowered computation is unchanged)
            with jax.named_scope(f"serve_block_{j}"):
                block, _ = jax.lax.scan(
                    body, jnp.zeros((n, k), jnp.float32),
                    (params[j], a_j, v_j))
            if j == 0:
                # the head agent's own block never crosses the wire
                blocks.append(block)
                sent_l.append(jnp.zeros((), bool))
                rung_l.append(jnp.asarray(-1, jnp.int32))
                total = block
                continue
            d_j = deliver[j]
            sub = jax.random.fold_in(key, j)
            if serve_controller is not None:
                # the policy reads the *raw* pre-noise block, exactly like
                # the eager transports (serve_block observes before the
                # channel applies)
                c_rung = serve_controller.rung_for(block)
            if budget is not None:
                # privacy noise is rung-independent: apply once, then
                # codec-only roundtrips per rung — bit-identical to the
                # eager fused channel (see the round_body note above)
                noised, _ = channel_apply(None, privacy, block, sub, None)
                rem = jnp.minimum(rem_s, rem_link[j])
                # the policy rung floors the walk (budget may still degrade
                # coarser, never finer) — same composition as
                # BudgetedTransport.serve_block
                rung = ladder_walk(
                    costs, rem,
                    floor=c_rung if serve_controller is not None else None)
                sendable = (rung >= 0) & d_j
                # an undelivered block never consults the budget, so it
                # cannot flip exhaustion (eager head-only degrade skips the
                # serve hop entirely)
                exhausted = exhausted | (d_j & (rung < 0)
                                         & (rem_s < min_cost))
                pairs = [channel_apply(c, None, noised, sub, None)[0]
                         for c in ladder]
                blk = rung_select(rung, pairs, block)
                cost = jnp.select([rung == i for i in range(len(ladder))],
                                  [jnp.asarray(c, jnp.int32) for c in costs],
                                  jnp.asarray(0, jnp.int32))
                rem_s = rem_s - jnp.where(sendable, cost, 0)
                contrib = jnp.where(sendable, blk, jnp.zeros_like(blk))
            elif serve_controller is not None:
                # unbudgeted adaptive serve: noise once, per-rung
                # codec-only roundtrips, select by the policy rung — the
                # decomposition the eager fused channel matches bit for bit
                noised, _ = channel_apply(None, privacy, block, sub, None)
                pairs = [channel_apply(c, None, noised, sub, None)[0]
                         for c in ladder]
                blk = rung_select(c_rung, pairs, noised)
                sendable = d_j
                rung = c_rung
                contrib = jnp.where(d_j, blk, jnp.zeros_like(blk))
            else:
                blk, _ = channel_apply(ladder[0], privacy, block, sub, None,
                                       qmax=qmax)
                sendable = d_j
                rung = jnp.asarray(0 if ladder[0] is not None else -1,
                                   jnp.int32)
                contrib = jnp.where(d_j, blk, jnp.zeros_like(blk))
            if live:
                live_sent = live_sent + jnp.where(sendable, 1, 0)
                if budget is not None:
                    # only budgeted serves record skips, and only for
                    # blocks admission actually asked to deliver
                    live_skip = live_skip + jnp.where(
                        d_j & jnp.logical_not(sendable), 1, 0)
                if budget is None and serve_controller is None:
                    hop_cost = jnp.asarray(live_costs[0], jnp.int32)
                else:
                    hop_cost = jnp.select(
                        [rung == i for i in range(len(live_costs))],
                        [jnp.asarray(c, jnp.int32) for c in live_costs],
                        jnp.asarray(0, jnp.int32))
                live_bits = live_bits + jnp.where(sendable, hop_cost, 0)
            blocks.append(blk)
            sent_l.append(sendable)
            rung_l.append(jnp.where(sendable, rung, -1))
            total = total + contrib
        if live:
            # one tap per request; batch-pad filler slots carry deliver
            # all-False, so active == deliver[0] drops them host-side
            emit_serve(deliver[0], live_bits + key_salt(key),
                       live_sent, live_skip)
        return ServeResult(preds=jnp.argmax(total, axis=-1),
                           blocks=jnp.stack(blocks, axis=0),
                           sent=jnp.stack(sent_l),
                           codec_idx=jnp.stack(rung_l),
                           exhausted=exhausted)

    if not qmax_arg:
        return (lambda key, Xs, params, alphas, valid, rem_s, rem_l, deliver:
                serve_fn(key, Xs, params, alphas, valid, rem_s, rem_l,
                         deliver))
    return serve_fn


@functools.lru_cache(maxsize=64)
def _serve_program(plan: SessionPlan, feature_shapes: tuple,
                   live: bool = False):
    return jax.jit(make_serve_fn(plan, feature_shapes, live=live))


def serve_session(plan: SessionPlan, result: SessionResult, key,
                  Xs: Sequence[jnp.ndarray], *, valid=None,
                  rem_session=None, rem_link=None,
                  deliver=None, live: bool = False) -> ServeResult:
    """Run the traced serve step for one completed compiled session: the
    one-program distributed prediction over ``Xs`` (per-agent serve-time
    feature blocks).  ``valid`` optionally overrides ``result.valid`` (e.g.
    masked by ``max_round``); ``rem_session``/``rem_link`` seed the budget
    counters from the live transport state (None = uncapped); ``deliver``
    [M] bool gates which non-head blocks ship (None = all)."""
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = tuple(x.shape[1:] for x in Xs)
    num = plan.num_agents
    valid = result.valid if valid is None else valid
    if rem_session is None:
        rem_session = _INT32_MAX
    if rem_link is None:
        rem_link = (_INT32_MAX,) * num
    if key is None:
        key = jax.random.key(0)        # unused by a channel-less serve
    rem_s = jnp.asarray(min(int(rem_session), _INT32_MAX), jnp.int32)
    rem_l = jnp.asarray([min(int(r), _INT32_MAX) for r in rem_link],
                        jnp.int32)
    if deliver is None:
        deliver = jnp.ones((num,), bool)
    return _serve_program(plan, shapes, live)(
        key, Xs, result.params, result.alphas, jnp.asarray(valid),
        rem_s, rem_l, jnp.asarray(deliver, bool))


# ================================================================ batched serve
@functools.lru_cache(maxsize=64)
def _serve_batch_program(plan: SessionPlan, feature_shapes: tuple,
                         width: int, live: bool = False):
    fn = make_serve_fn(plan, feature_shapes, live=live)
    num = plan.num_agents

    from repro.comm.codecs import serve_key

    def run(slots):
        # the per-slot -> batch stacking happens INSIDE the jitted program:
        # a flush costs one XLA dispatch per bucket, not O(leaves) host
        # dispatches (host-side jnp.stack was the serve loop's bottleneck)
        if "request" in slots[0]:
            # slot carries (evolved session key, request id); the
            # request-keyed serve key folds in-program — two eager fold_in
            # dispatches per request otherwise
            keys = jnp.stack([serve_key(s["key"], s["request"])
                              for s in slots])
        else:
            keys = jnp.stack([s["key"] for s in slots])
        Xs = tuple(jnp.stack([s["Xs"][m] for s in slots])
                   for m in range(num))
        params = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[s["params"] for s in slots])
        alphas = jnp.stack([s["alphas"] for s in slots])
        valid = jnp.stack([s["valid"] for s in slots])
        rem_s = jnp.stack([jnp.asarray(s["rem_session"], jnp.int32)
                           for s in slots])
        rem_l = jnp.stack([jnp.asarray(s["rem_link"], jnp.int32)
                           for s in slots])
        deliver = jnp.stack([jnp.asarray(s["deliver"], bool)
                             for s in slots])
        return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0))(
            keys, Xs, params, alphas, valid, rem_s, rem_l, deliver)

    return jax.jit(run)


def serve_batch(plan: SessionPlan, slots, *,
                live: bool = False) -> ServeResult:
    """Run one traced serve step for a whole *batch* of slots in ONE XLA
    program — the continuous-batching primitive behind
    :mod:`repro.serve.batcher`.

    ``slots`` is a sequence of per-slot dicts, each holding what one
    ``serve_session`` call would consume: ``key`` (the request-keyed serve
    key), ``Xs`` (length-M tuple of [n, p_m] feature blocks), ``params`` /
    ``alphas`` / ``valid`` (the fitted session's ``SessionResult`` fields),
    ``rem_session`` / ``rem_link`` (int32 budget counters), and ``deliver``
    ([M] bool admission mask).  A slot may carry ``request`` (an int
    request id) alongside the *evolved session* key instead of a
    pre-derived serve key — the ``serve_key`` fold then happens inside the
    program.  Returns a ServeResult with a leading slot axis.  Slot b computes exactly what ``serve_session`` would for that
    session and request alone — the vmap axis never mixes slots, so batched
    serving is bit-identical to per-request serving (the pin
    ``tests/test_serve_engine.py`` holds).  Programs cache per
    (plan, feature_shapes, batch width): one bucket = one compile.
    """
    slots = tuple(dict(s) for s in slots)
    shapes = tuple(tuple(np.shape(x)[1:]) for x in slots[0]["Xs"])
    return _serve_batch_program(plan, shapes, len(slots), live)(slots)


# ================================================================= codec sweep
@functools.lru_cache(maxsize=64)
def _sweep_program(plan: SessionPlan, feature_shapes: tuple):
    fn = make_session_fn(plan, feature_shapes, qmax_arg=True)
    return jax.jit(jax.vmap(fn, in_axes=(0, None, None, 0)))


@functools.lru_cache(maxsize=64)
def _sweep_serve_program(plan: SessionPlan, feature_shapes: tuple):
    sess = make_session_fn(plan, feature_shapes, qmax_arg=True)
    srv = make_serve_fn(plan, feature_shapes, qmax_arg=True)
    num = plan.num_agents

    def run_one(key, Xs, classes, qmax, serve_Xs):
        from repro.comm.codecs import SERVE_FOLD
        res = sess(key, Xs, classes, qmax)
        serve = srv(jax.random.fold_in(key, SERVE_FOLD), serve_Xs,
                    res.params, res.alphas, res.valid,
                    jnp.asarray(_INT32_MAX, jnp.int32),
                    jnp.full((num,), _INT32_MAX, jnp.int32),
                    jnp.ones((num,), bool), qmax)
        return res, serve

    return jax.jit(jax.vmap(run_one, in_axes=(0, None, None, 0, None)))


def quant_sweep_run(plan: SessionPlan, keys: jax.Array,
                    Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
                    qmaxes: jnp.ndarray, serve_Xs=None):
    """Sweep quantization levels across a session fleet in ONE XLA program.

    The plan's :class:`~repro.comm.codecs.QuantCodec` clipping level becomes
    a traced per-session scalar: session s runs with PRNG key ``keys[s]``
    and integer range [-qmaxes[s], qmaxes[s]] (e.g. ``[127, 31, 7]`` for an
    int8/int6/int4 frontier — pass identical keys to isolate the codec
    axis).  This is the codec analogue of :func:`fleet_run`: because codecs
    are fixed-shape pure functions, the whole accuracy-vs-precision frontier
    vmaps instead of re-running per config.  Wire bits per session follow
    from :func:`repro.comm.codecs.quant_bits_per_element`.

    With ``serve_Xs`` (per-agent serve-time feature blocks) the sweep gains
    a serve axis: each swept session also runs the traced serve step at its
    qmax (serve key folded off the session key with the SERVE tag, matching
    ``Protocol.predict_distributed``) and the call returns a
    ``(SessionResult, ServeResult)`` pair, both with a leading sweep axis —
    train-bits vs serve-bits vs accuracy from one XLA program.
    """
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = tuple(x.shape[1:] for x in Xs)
    if serve_Xs is None:
        return _sweep_program(plan, shapes)(
            keys, Xs, classes, jnp.asarray(qmaxes, jnp.float32))
    serve_Xs = tuple(jnp.asarray(x) for x in serve_Xs)
    return _sweep_serve_program(plan, shapes)(
        keys, Xs, classes, jnp.asarray(qmaxes, jnp.float32), serve_Xs)


# ============================================================== control sweep
#: Trace-entry counters keyed by program family — CI's compile-count
#: assertion reads these: a correctly cached sweep traces exactly once no
#: matter how many configs it vmaps over.
TRACE_COUNTS: dict = {}


@functools.lru_cache(maxsize=64)
def _control_sweep_program(plan: SessionPlan, feature_shapes: tuple,
                           live: bool = False):
    fn = make_session_fn(plan, feature_shapes, control_arg=True, live=live)

    def counted(key, Xs, classes, cuts, beta, session_cap, link_cap):
        # runs at trace time only: one increment per compile, not per config
        TRACE_COUNTS["control_sweep"] = \
            TRACE_COUNTS.get("control_sweep", 0) + 1
        return fn(key, Xs, classes, cuts, beta, session_cap, link_cap)

    return jax.jit(jax.vmap(counted, in_axes=(0, None, None, 0, 0, 0, 0)))


def control_sweep_run(plan: SessionPlan, keys: jax.Array,
                      Xs: Sequence[jnp.ndarray], classes: jnp.ndarray, *,
                      cuts=None, betas=None, session_bits=None,
                      link_bits=None, live: bool = False) -> SessionResult:
    """Sweep the *control plane* across a session fleet in ONE XLA program.

    The plan's adaptive-controller thresholds (``cuts`` [S, R-1]) and EMA
    coefficient (``betas`` [S]) and/or its budget caps (``session_bits`` /
    ``link_bits``, [S] sequences with ``None`` entries = uncapped) become
    traced per-session operands: config s runs with PRNG key ``keys[s]``
    under its own controller/budget hyperparameters — the control-plane
    analogue of :func:`quant_sweep_run`, replacing one re-trace per
    hyperparameter with a single compile (``TRACE_COUNTS['control_sweep']``
    counts the traces; CI asserts it stays at one across a sweep).  Any
    axis left ``None`` is filled from the plan's static values, so a sweep
    can vary thresholds alone, caps alone, or both.  Returns a
    :class:`SessionResult` with a leading config axis, each row bit-equal
    to a static plan compiled with that config's values.
    """
    if plan.budget is None and plan.controller is None:
        raise ValueError("control_sweep_run sweeps controller thresholds "
                         "and budget caps; the plan has neither")
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = tuple(x.shape[1:] for x in Xs)
    S = int(jnp.shape(keys)[0])
    if cuts is None:
        base = (plan.controller.thresholds if plan.controller is not None
                else ())
        cuts = jnp.tile(jnp.asarray(base, jnp.float32)[None, :], (S, 1))
    else:
        cuts = jnp.asarray(cuts, jnp.float32)
    if betas is None:
        b = plan.controller.beta if plan.controller is not None else 0.0
        betas = jnp.full((S,), b, jnp.float32)
    else:
        betas = jnp.asarray(betas, jnp.float32)

    def cap_axis(vals, static):
        clip = lambda v: min(int(v), _INT32_MAX) if v is not None \
            else _INT32_MAX
        if vals is None:
            return jnp.full((S,), clip(static), jnp.int32)
        return jnp.asarray([clip(v) for v in vals], jnp.int32)

    sb = cap_axis(session_bits,
                  plan.budget.session_bits if plan.budget else None)
    lb = cap_axis(link_bits, plan.budget.link_bits if plan.budget else None)
    return _control_sweep_program(plan, shapes, live)(keys, Xs, classes,
                                                      cuts, betas, sb, lb)


# ============================================================= host extraction
def agent_major_result(result: SessionResult) -> SessionResult:
    """Re-collect a slot-major :class:`SessionResult` to agent-major.

    Under a permuting scheduler, slot ``j`` of round ``t`` holds whichever
    agent ``result.order[t, j]`` names, so consumers that index per-agent
    state positionally (the serve paths read ``params[m]``) need the
    inverse permutation applied first.  Host-side and cheap (numpy gathers
    plus one params re-stack); identity plans short-circuit.
    """
    order = getattr(result, "order", None)
    if order is None:
        return result
    order = np.asarray(order)
    T, M = order.shape
    if np.array_equal(order, np.tile(np.arange(M), (T, 1))):
        return result
    inv = np.argsort(order, axis=1)      # inv[t, m] = slot agent m ran in

    def collect(a):
        if a is None:
            return None
        return jnp.asarray(np.take_along_axis(np.asarray(a), inv, axis=1))

    params = tuple(
        jax.tree.map(
            lambda *xs, _m=m: jnp.stack(
                [xs[int(inv[t, _m])][t] for t in range(T)]),
            *result.params)
        for m in range(M))
    return result._replace(
        alphas=collect(result.alphas), accs=collect(result.accs),
        executed=collect(result.executed), valid=collect(result.valid),
        params=params,
        sent=collect(result.sent), codec_idx=collect(result.codec_idx),
        order=jnp.tile(jnp.arange(M, dtype=jnp.int32), (T, 1)))


def fitted_from_result(plan: SessionPlan, result: SessionResult,
                       learners: Sequence):
    """Rebuild the eager engine's result objects from a compiled run: the
    component list (valid slots in chain order), the round history, and a
    :class:`repro.core.engine.FittedASCII` — byte-compatible with what
    ``Protocol.fit`` returns on the eager path.  Slot-major input: under a
    permuting scheduler the component agent ids come from ``result.order``
    (slot ``j`` holds agent ``order[t, j]``), matching the eager visit
    order exactly."""
    from repro.core.engine import Component, FittedASCII

    alphas = np.asarray(result.alphas)
    accs = np.asarray(result.accs)
    executed = np.asarray(result.executed)
    valid = np.asarray(result.valid)
    order = getattr(result, "order", None)
    order = None if order is None else np.asarray(order)
    components, history = [], []
    for t in range(plan.max_rounds):
        if not executed[t].any():
            break                        # the eager loop stopped before t
        rec = {"round": t, "alphas": [], "accs": []}
        for j in range(plan.num_agents):
            if not executed[t, j]:
                break                    # mid-round alpha<=0 stop
            rec["alphas"].append(float(alphas[t, j]))
            rec["accs"].append(float(accs[t, j]))
            if valid[t, j]:
                agent = j if order is None else int(order[t, j])
                params_tj = jax.tree.map(lambda x, _t=t: x[_t],
                                         result.params[j])
                components.append(Component(agent, t, float(alphas[t, j]),
                                            params_tj))
        history.append(rec)
    return FittedASCII(components, list(learners), plan.num_classes, history)
