"""Label encoding for multi-class exponential-loss boosting (paper eq. 1).

A class label c_i in {0, ..., K-1} (we use 0-based indices internally; the
paper uses 1-based) is re-coded into a length-K vector

    y_ij = 1            if c_i == j
         = -1/(K-1)     otherwise

so that the exponential loss exp(-y^T f / K) behaves as the multi-class
margin loss of SAMME (Hastie et al., 2009).  Key identities used throughout
(see DESIGN.md and tests/test_core_scores.py):

    y^T g / K =  1/(K-1)      if g encodes the same class as y
              = -1/(K-1)^2    if g encodes a different class
"""
from __future__ import annotations

import jax.numpy as jnp


def encode_labels(classes: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Recode integer classes [n] -> coded label matrix [n, K] per eq. (1)."""
    k = num_classes
    onehot = jnp.equal(classes[..., None], jnp.arange(k)).astype(jnp.float32)
    return onehot * (1.0 + 1.0 / (k - 1)) - 1.0 / (k - 1)


def decode_labels(coded: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`encode_labels` (argmax over the coded axis)."""
    return jnp.argmax(coded, axis=-1)


def margin(coded_y: jnp.ndarray, scores: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """The exponent y^T f / K of the exponential loss, elementwise over rows."""
    return jnp.sum(coded_y * scores, axis=-1) / num_classes


def exp_loss(coded_y: jnp.ndarray, scores: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    """Per-sample exponential loss exp(-y^T f / K)."""
    return jnp.exp(-margin(coded_y, scores, num_classes))
