"""Agent-session engine for the ASCII interchange protocol.

The paper's contribution is an *interchange protocol*: agents passing
ignorance scores and model weights around a ring while all raw features stay
private.  This module is the one place that protocol is implemented; the
variant-branched host loop, the byte-metered simulator, and the mesh-native
ring are now three pluggable pieces of a single engine:

  * ``AgentEndpoint`` — one agent: a private :class:`~repro.learners.base.
    Learner` plus its local feature block, addressable by name, with a typed
    message inbox.  Endpoints can drop out mid-session (``active = False``)
    or join late (:meth:`Session.add_endpoint`).
  * Typed messages — :class:`IgnoranceMsg`, :class:`ModelWeightMsg`,
    :class:`ScoreBlockMsg` (plus the one-time :class:`LabelsMsg` /
    :class:`SampleIdsMsg` collation setup).  Every message knows its size so
    transports can meter it.
  * ``Transport`` — how messages move and where the interchange update
    executes.  :class:`InProcessTransport` is the plain host path,
    :class:`MeteredTransport` additionally books every bit into a
    :class:`~repro.core.transport.TransportLog` (Fig. 4 accounting), and
    :class:`MeshRingTransport` runs the fused update on-device via the
    Pallas kernel / ``core.collectives`` ring.
  * ``Scheduler`` — the round order, replacing the old ``variant`` string
    branching: :class:`SequentialScheduler` (paper chain),
    :class:`RandomScheduler` (ASCII-Random), :class:`AsyncStaleScheduler`
    (beyond-paper stale-read parallel rounds).
  * ``SessionState`` — the explicit protocol state (ignorance vector, PRNG
    key, fitted components, round history, stop bookkeeping).  It is a plain
    tree of arrays + JSON-able metadata, checkpointable mid-run through
    ``train/checkpoint.py`` and resumable to bit-identical trajectories.
  * ``Protocol`` — the engine: wires a config, a scheduler, and a transport,
    and drives endpoints round by round (``start`` / ``step`` / ``run`` /
    ``resume``).

``repro.core.protocol.fit`` is a thin back-compat wrapper over this engine;
its ``variant`` strings map onto schedulers via :func:`variant_setup`.

Quickstart::

    endpoints = [AgentEndpoint(0, DecisionTree(depth=3), X_a),
                 AgentEndpoint(1, DecisionTree(depth=3), X_b)]
    engine = Protocol(SessionConfig(num_classes=10, max_rounds=6),
                      scheduler=SequentialScheduler(),
                      transport=MeteredTransport())
    session = engine.start(jax.random.key(0), endpoints, classes)
    session.run()
    preds = session.fitted().predict([Xte_a, Xte_b])
"""
from __future__ import annotations

import abc
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scores
from repro.core.encoding import encode_labels
from repro.core.transport import TransportLog
from repro.learners.base import Learner
from repro.telemetry.live import installed as live_installed

PyTree = Any

VARIANTS = ("ascii", "simple", "random", "async")


# ===================================================================== messages
@dataclass(frozen=True)
class Message:
    """Base class for everything that crosses an agent boundary.

    ``num_elements``/``bits_per_element`` expose the wire size so transports
    can meter without understanding the payload; messages that went through
    a wire codec (repro.comm) carry their *encoded* size in ``wire_bits``
    instead, and ``bits`` prefers it — the ledger prices what actually
    crossed the wire, not the decoded payload.
    """
    src: str
    dst: str

    kind = "message"
    bits_per_element = 32
    # plain class attribute, NOT a dataclass field: subclasses that carry an
    # encoded payload redeclare it as a trailing field; adding it as a field
    # here would splice it before subclass fields and break positional
    # construction
    wire_bits = None

    @property
    def num_elements(self) -> int:
        return 0

    @property
    def bits(self) -> int:
        if self.wire_bits is not None:
            return self.wire_bits
        return self.num_elements * self.bits_per_element


@dataclass(frozen=True)
class IgnoranceMsg(Message):
    """The length-n ignorance score shipped on every interchange hop.

    ``w`` is the *decoded* payload (what the receiver computes with);
    ``wire_bits`` the encoded size when a codec was active."""
    w: jnp.ndarray = None
    wire_bits: int | None = None

    kind = "ignorance"

    @property
    def num_elements(self) -> int:
        return int(np.size(self.w))


@dataclass(frozen=True)
class ModelWeightMsg(Message):
    """The scalar model weight alpha accompanying each hop."""
    alpha: float = 0.0

    kind = "model_weight"

    @property
    def num_elements(self) -> int:
        return 1


@dataclass(frozen=True)
class ScoreBlockMsg(Message):
    """An [n, K] coded score block: an agent's alpha-weighted votes for the
    collated samples — the O(nK) prediction-time traffic of Algorithm 1
    line 12 (raw features never move).

    ``scores`` is the *decoded* payload the head agent sums; ``wire_bits``
    the encoded size when the serve channel ran a codec."""
    scores: jnp.ndarray = None
    wire_bits: int | None = None

    kind = "score_block"

    @property
    def num_elements(self) -> int:
        return int(np.size(self.scores))


@dataclass(frozen=True)
class GradientMsg(Message):
    """A FedAvg-style flattened model delta (client -> server uplink, or the
    server's raw broadcast of the new global model).

    ``delta`` is the *decoded* payload (what the server averages);
    ``wire_bits`` the encoded size when the channel ran a codec."""
    delta: jnp.ndarray = None
    wire_bits: int | None = None

    kind = "gradient"

    @property
    def num_elements(self) -> int:
        return int(np.size(self.delta))


@dataclass(frozen=True)
class ResidualMsg(Message):
    """An Assisted-Learning [n, K] residual block passed along the ring:
    agent m ships what remains of the label signal after its local fit.

    ``residual`` is the *decoded* payload the next agent fits against;
    ``wire_bits`` the encoded size when the channel ran a codec."""
    residual: jnp.ndarray = None
    wire_bits: int | None = None

    kind = "residual"

    @property
    def num_elements(self) -> int:
        return int(np.size(self.residual))


@dataclass(frozen=True)
class LabelsMsg(Message):
    """One-time setup: the head agent shares the numeric labels."""
    num_samples: int = 0

    kind = "labels"

    @property
    def num_elements(self) -> int:
        return self.num_samples


@dataclass(frozen=True)
class SampleIdsMsg(Message):
    """One-time setup: collation IDs aligning rows across agents."""
    num_samples: int = 0

    kind = "sample_ids"

    @property
    def num_elements(self) -> int:
        return self.num_samples


# =================================================================== transports
class Transport(abc.ABC):
    """How messages move between endpoints and where interchange math runs.

    ``bind`` gives the transport the endpoint registry; ``send`` routes a
    message into the destination inbox (subclasses hook ``_on_send`` for
    accounting); ``interchange`` executes one hop of eqs. (10)/(12): update
    the ignorance score with ``src``'s reward and alpha, then deliver it to
    ``dst``.

    Every transport optionally carries a wire channel (repro.comm): a
    ``codec`` (the outgoing score is encoded, priced at its *encoded* size,
    and the protocol continues from the decoded array — a genuinely lossy
    wire) and/or a ``privacy`` Gaussian mechanism (DP noise on the outgoing
    vector, per-agent epsilon tallied in ``accountant``).
    """

    def __init__(self, codec=None, privacy=None, serve_codec=None,
                 controller=None, accountant=None,
                 serve_controller=None) -> None:
        self._endpoints: dict[str, "AgentEndpoint"] = {}
        if controller is not None:
            if codec is not None:
                raise ValueError(
                    "an adaptive controller drives codec choice through its "
                    "ladder; drop codec= (or pass the codec as a one-rung "
                    "controller ladder)")
            codec = controller.ladder[0]
        if serve_controller is not None and serve_codec is not None:
            raise ValueError(
                "a serve controller picks the serve rung per score block "
                "through its ladder; drop serve_codec=")
        self.codec = codec
        self.privacy = privacy
        # serve-path codec override: prediction-time ScoreBlockMsg traffic
        # encodes with this codec when set, else with ``codec`` (so one
        # codec serves both payload types by default)
        self.serve_codec = serve_codec
        # per-hop codec-rung policy (repro.control.adaptive) + its EMA state
        self.controller = controller
        self.ctrl_state = (None if controller is None
                           else controller.init_state())
        # per-block serve rung policy (repro.control.adaptive
        # .ServeController): stateless — each block's uncertainty statistic
        # picks its own codec rung, no EMA to checkpoint
        self.serve_controller = serve_controller
        if accountant is not None and privacy is None:
            raise ValueError("an accountant without a privacy mechanism has "
                             "nothing to account; pass privacy= too")
        self.accountant = None
        if privacy is not None:
            if accountant is None:
                from repro.comm.privacy import PrivacyAccountant
                accountant = PrivacyAccountant()
            self.accountant = accountant

    @property
    def has_channel(self) -> bool:
        return self.codec is not None or self.privacy is not None

    @property
    def effective_serve_codec(self):
        if self.serve_codec is not None:
            return self.serve_codec
        if self.serve_controller is not None:
            # the serve controller picks the rung per block inside
            # serve_block; there is no single static serve codec
            return None
        if self.controller is not None:
            # the controller is a training-interchange policy (its entropy
            # statistic is defined on the ignorance vector, not on score
            # blocks) and mutates ``codec`` hop by hop — serve traffic ships
            # raw unless an explicit serve_codec is set, identically on both
            # backends (SessionPlan.serve_ladder applies the same rule)
            return None
        return self.codec

    @property
    def has_serve_channel(self) -> bool:
        return (self.effective_serve_codec is not None
                or self.serve_controller is not None
                or self.privacy is not None)

    def bind(self, endpoints: Sequence["AgentEndpoint"]) -> None:
        self._endpoints = {ep.name: ep for ep in endpoints}

    def send(self, msg: Message) -> None:
        self._on_send(msg)
        ep = self._endpoints.get(msg.dst)
        if ep is not None:
            ep.receive(msg)

    def _on_send(self, msg: Message) -> None:  # metering hook
        pass

    def _execute_update(self, w: jnp.ndarray, r: jnp.ndarray, alpha,
                        reweight: Callable, standard: bool) -> jnp.ndarray:
        return reweight(w, r, alpha)

    def _controller_rung(self, w_prev: jnp.ndarray,
                         w_out: jnp.ndarray) -> int:
        """One adaptive-controller step: observe the hop (receiver's stale
        vector, outgoing vector), advance the EMA state, return the chosen
        ladder rung.  Runs the cached-jit controller program (the exact
        computation the compiled session scan embeds)."""
        from repro.control.adaptive import jitted_controller
        rung, self.ctrl_state = jitted_controller(self.controller)(
            w_prev, w_out, self.ctrl_state)
        return int(rung)

    def _choose_codec(self, w_prev: jnp.ndarray, w_out: jnp.ndarray) -> None:
        """Per-hop codec selection hook: with an adaptive controller the
        outgoing codec is the controller's rung for this hop.  Budgeted
        transports override this as a no-op — their ladder walk consumes
        the controller rung as a floor instead."""
        if self.controller is not None:
            self.codec = self.controller.ladder[
                self._controller_rung(w_prev, w_out)]

    def interchange(self, src: "AgentEndpoint", dst: "AgentEndpoint",
                    w: jnp.ndarray, r: jnp.ndarray, alpha,
                    reweight: Callable, standard: bool = True, *,
                    key=None, codec_state=None, _w_out=None):
        """One hop: w' = reweight(w, r, alpha), through the wire channel
        (DP noise, then codec encode/decode), shipped src -> dst.

        Returns ``(w_received, codec_state)`` — what the receiver decodes
        (the trajectory continues from it) plus the updated per-link codec
        state (error-feedback residual; None for stateless codecs).
        ``key`` is the hop's per-fit subkey; the channel folds its own keys
        from it, so attaching a channel never shifts the fit PRNG stream.
        ``_w_out`` lets a subclass that already ran the update (the
        budgeted transport's controller floor) pass it through instead of
        recomputing it.
        """
        w_next = (_w_out if _w_out is not None
                  else self._execute_update(w, r, alpha, reweight, standard))
        self._choose_codec(w, w_next)
        wire_bits = None
        if self.has_channel:
            from repro.comm.codecs import jitted_channel
            if (self.codec is not None and self.codec.stateful
                    and codec_state is None):
                codec_state = self.codec.init_state(int(w.shape[0]))
            w_next, codec_state = jitted_channel(self.codec, self.privacy)(
                w_next, key, codec_state)
            if self.privacy is not None:
                self.accountant.record(src.name)
            if self.codec is not None:
                wire_bits = self.codec.wire_bits(int(w.shape[0]))
        self.send(IgnoranceMsg(src.name, dst.name, w_next,
                               wire_bits=wire_bits))
        self.send(ModelWeightMsg(src.name, dst.name, float(alpha)))
        return w_next, codec_state

    def serve_block(self, src: "AgentEndpoint", dst: "AgentEndpoint",
                    block: jnp.ndarray, *, key=None):
        """One prediction-time hop: ship ``src``'s [n, K] score block to
        ``dst`` (the head agent) through the serve channel — DP noise, then
        codec encode/decode — priced at its *encoded* size.

        Returns the decoded block the head agent sums (the serve-path
        analogue of :meth:`interchange`'s decoded score), or ``None`` when a
        budgeted transport drops the block (see
        :class:`repro.comm.budget.BudgetedTransport`).  ``key`` is the
        per-block serve subkey; stateful codecs run with a fresh residual —
        serve calls are independent, there is no next hop to defer mass to.
        """
        codec = self.effective_serve_codec
        if self.serve_controller is not None and codec is None:
            # per-block rung policy: the controller reads the raw outgoing
            # block (pre-noise) through the cached-jit program the compiled
            # serve step embeds, so both backends pick identical rungs
            from repro.control.adaptive import jitted_serve_controller
            rung = int(jitted_serve_controller(self.serve_controller)(block))
            codec = self.serve_controller.ladder[rung]
        wire_bits = None
        if codec is not None or self.privacy is not None:
            from repro.comm.codecs import jitted_channel
            block, _ = jitted_channel(codec, self.privacy)(block, key, None)
            if self.privacy is not None:
                self.accountant.record(src.name)
            if codec is not None:
                wire_bits = int(codec.wire_bits(tuple(block.shape)))
        self.send(ScoreBlockMsg(src.name, dst.name, block,
                                wire_bits=wire_bits))
        return block

    def ship(self, src: "AgentEndpoint", dst: "AgentEndpoint",
             payload: jnp.ndarray, wrap, *, key=None):
        """One generic protocol-variant hop: ship ``payload`` (a FedAvg
        model delta, an Assisted-Learning residual block, ...) src -> dst
        through the wire channel — DP noise, then codec encode/decode —
        priced at its *encoded* size and wrapped in the ``wrap`` message
        type (:class:`GradientMsg` / :class:`ResidualMsg`).

        Returns the decoded payload the receiver computes with (the
        protocol continues from it — a genuinely lossy wire), or ``None``
        when a budgeted transport drops the hop (the receiver keeps its
        stale state, exactly like a skipped interchange hop).  ``key`` is
        the hop's per-fit subkey; the channel folds its own keys from it.
        Stateful (error-feedback) codecs run with a fresh residual per hop,
        like serve blocks — variant traffic has no per-link residual state.
        """
        wire_bits = None
        if self.has_channel:
            from repro.comm.codecs import jitted_channel
            payload, _ = jitted_channel(self.codec, self.privacy)(
                payload, key, None)
            if self.privacy is not None:
                self.accountant.record(src.name)
            if self.codec is not None:
                wire_bits = int(self.codec.wire_bits(tuple(payload.shape)))
        self.send(wrap(src.name, dst.name, payload, wire_bits=wire_bits))
        return payload

    def barrier_release(self, head: "AgentEndpoint", w_bar: jnp.ndarray, *,
                        key=None, codec_state=None):
        """One asynchronous-barrier release: the merged, renormalized score
        crosses the wire channel *once per round* — DP noise, then codec
        encode/decode, priced at its encoded size — published to the round
        head as a single IgnoranceMsg from the synthetic ``"barrier"``
        sender (the merge itself has no single agent source, and per-agent
        alphas already crossed raw).

        Returns ``(w_released, codec_state)``; a budgeted transport may
        instead skip the release (``(None, codec_state)``) when the session
        budget cannot afford even the cheapest rung, leaving the published
        score stale for one more round.  ``key`` is the per-barrier subkey
        (split *after* the round's fit splits, so attaching a channel never
        shifts the fit PRNG stream); ``codec_state`` is the barrier link's
        error-feedback residual for stateful codecs.
        """
        from repro.comm.codecs import jitted_channel
        if (self.codec is not None and self.codec.stateful
                and codec_state is None):
            codec_state = self.codec.init_state(int(w_bar.shape[0]))
        w_rel, codec_state = jitted_channel(self.codec, self.privacy)(
            w_bar, key, codec_state)
        if self.privacy is not None:
            self.accountant.record("barrier")
        wire_bits = (self.codec.wire_bits(int(w_bar.shape[0]))
                     if self.codec is not None else None)
        self.send(IgnoranceMsg("barrier", head.name, w_rel,
                               wire_bits=wire_bits))
        return w_rel, codec_state


class InProcessTransport(Transport):
    """Direct in-memory delivery; the plain single-host path."""


class MeteredTransport(Transport):
    """In-process delivery that books every bit into a
    :class:`~repro.core.transport.TransportLog` — the byte-accounted
    simulator behind the Fig. 4 transmission-cost benchmark.  With a codec
    attached the ledger books *encoded* bits."""

    def __init__(self, log: TransportLog | None = None, codec=None,
                 privacy=None, serve_codec=None, controller=None,
                 accountant=None, serve_controller=None) -> None:
        super().__init__(codec=codec, privacy=privacy,
                         serve_codec=serve_codec, controller=controller,
                         accountant=accountant,
                         serve_controller=serve_controller)
        self.log = log if log is not None else TransportLog()

    def _on_send(self, msg: Message) -> None:
        if msg.wire_bits is not None:
            # a budgeted subclass arms _pending_rung in record_spend; the
            # wire-priced booking that follows consumes it, stamping the
            # chosen ladder rung onto the ledger entry so a registry
            # attached *after* the traffic can still backfill
            # hops_by_rung_total
            rung = getattr(self, "_pending_rung", None)
            self.log.send_bits(msg.src, msg.dst, msg.kind, msg.wire_bits,
                               rung=rung)
            if rung is not None:
                self._pending_rung = None
        else:
            self.log.send(msg.src, msg.dst, msg.kind, msg.num_elements,
                          msg.bits_per_element)

    @property
    def total_bits(self) -> int:
        return self.log.total_bits

    def bits_by_kind(self) -> dict:
        return self.log.bits_by_kind()


class MeshRingTransport(Transport):
    """Device-resident interchange.

    The per-hop ignorance update runs the fused Pallas kernel
    (``kernels.ops.ignorance_update``); given a mesh with an ``agent`` axis,
    :meth:`ring_step` executes a whole round of hops as one
    ``shard_map``-ed neighbour ``ppermute`` via ``core.collectives`` — one
    ICI hop of n/|data| floats per device, zero resharding.

    The beyond-paper ``exact_reweight`` surrogate has no fused kernel; those
    hops fall back to the host formula.
    """

    def __init__(self, mesh=None, *, agent_axis: str = "agent",
                 data_axis: str = "data",
                 interpret: bool | None = None, codec=None,
                 privacy=None, serve_codec=None, controller=None,
                 accountant=None, serve_controller=None) -> None:
        super().__init__(codec=codec, privacy=privacy,
                         serve_codec=serve_codec, controller=controller,
                         accountant=accountant,
                         serve_controller=serve_controller)
        self.mesh = mesh
        self.agent_axis = agent_axis
        self.data_axis = data_axis
        self.interpret = interpret
        self._ring = None

    def _execute_update(self, w, r, alpha, reweight, standard):
        if not standard:
            return reweight(w, r, alpha)
        from repro.kernels import ops
        from repro.kernels.ignorance import tiles_evenly
        if not tiles_evenly(w.shape[0]):
            # score length doesn't tile the kernel grid; host formula
            # (same shared predicate the compiled backend's _make_reweight
            # checks, so eager and compiled stay in lockstep at any n)
            return reweight(w, r, alpha)
        return ops.ignorance_update(w, r, jnp.asarray(alpha, w.dtype),
                                    interpret=self.interpret)

    def ring_step(self, w_stack: jnp.ndarray, r_stack: jnp.ndarray,
                  alphas: jnp.ndarray) -> jnp.ndarray:
        """All-lanes ring hop on the mesh: agent m+1 receives agent m's
        updated score.  Shapes [M, n], [M, n], [M]."""
        if self.mesh is None:
            raise ValueError("ring_step needs a mesh with an agent axis")
        if self._ring is None:
            from repro.core.collectives import make_ring_interchange
            self._ring = make_ring_interchange(
                self.mesh, agent_axis=self.agent_axis,
                data_axis=self.data_axis)
        return self._ring(w_stack, r_stack, alphas)


# =================================================================== schedulers
class Scheduler(abc.ABC):
    """Round-order policy: which active agents act, in what order.

    ``stale`` selects the asynchronous execution model (all agents read the
    same round-t ignorance score; updates merge at the round barrier) instead
    of the sequential chain.
    """

    stale = False

    def reset(self) -> None:
        """Called at session start; clears any per-run RNG state."""

    def bind_transport(self, transport: "Transport") -> None:
        """Budget-introspection hook: schedulers that order agents by live
        channel state (repro.control.scheduler) receive the transport here;
        stateless schedulers ignore it."""

    def observe(self, agent_id: int, acc: float) -> None:
        """Reward-observation hook: the session reports each agent's
        weighted accuracy after its fit, for schedulers that bias order by
        expected reward; stateless schedulers ignore it."""

    @abc.abstractmethod
    def round_order(self, round_idx: int, active: list[int]) -> list[int]:
        """Agent ids (a permutation of ``active``) for round ``round_idx``."""

    def skip_to(self, order_sizes: Sequence[int]) -> None:
        """Fast-forward RNG state past already-executed rounds (resume).
        ``order_sizes`` holds each completed round's active-agent count, so
        the replayed RNG draws match even if agents dropped out or joined
        mid-session."""
        for t, size in enumerate(order_sizes):
            self.round_order(t, list(range(size)))


class SequentialScheduler(Scheduler):
    """The paper's chain 1 -> 2 -> ... -> M, every round."""

    def round_order(self, round_idx: int, active: list[int]) -> list[int]:
        return list(active)


class RandomScheduler(Scheduler):
    """ASCII-Random: a fresh random agent order each round."""

    def __init__(self, seed: int = 0) -> None:
        self.seed = seed
        self._rng = np.random.default_rng(seed)

    def reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def round_order(self, round_idx: int, active: list[int]) -> list[int]:
        perm = self._rng.permutation(len(active))
        return [active[i] for i in perm]


class AsyncStaleScheduler(SequentialScheduler):
    """Beyond-paper asynchronous rounds (the paper's open problem): all
    agents train concurrently against the same stale round-t score; positive
    updates merge multiplicatively (damped by 1/M) at the round barrier, so
    the M WST fits parallelize."""

    stale = True


# ======================================================================= agents
@dataclass
class AgentEndpoint:
    """One protocol participant: a private learner plus its local feature
    block.  Raw features never leave the endpoint; only messages do.

    ``active`` gates participation round by round — flip it off to simulate
    dropout mid-session, or append a fresh endpoint to a live session
    (:meth:`Session.add_endpoint`) for a late join.
    """

    agent_id: int
    learner: Learner
    X: jnp.ndarray
    name: str = ""
    active: bool = True
    inbox: list[Message] = field(default_factory=list)

    def __post_init__(self) -> None:
        if not self.name:
            self.name = f"agent{self.agent_id}"

    def receive(self, msg: Message) -> None:
        # keep only the freshest message per kind: the protocol never reads
        # stale state, and retaining every length-n IgnoranceMsg would grow
        # memory O(rounds * n)
        self.inbox = [m for m in self.inbox if m.kind != msg.kind]
        self.inbox.append(msg)

    def latest(self, kind: str) -> Message | None:
        for msg in reversed(self.inbox):
            if msg.kind == kind:
                return msg
        return None

    # ---- local computation (Algorithm 2: weighted supervised training)
    def fit_local(self, key, classes: jnp.ndarray, w: jnp.ndarray,
                  num_classes: int) -> PyTree:
        return self.learner.fit(key, self.X, classes, w, num_classes)

    def reward(self, params: PyTree, classes: jnp.ndarray) -> jnp.ndarray:
        return self.learner.reward(params, self.X, classes)

    def score_block(self, components: Sequence["Component"], num_classes: int,
                    X: jnp.ndarray | None = None,
                    max_round: int | None = None) -> jnp.ndarray:
        """This agent's [n, K] alpha-weighted coded votes over its own
        components (the prediction-time ScoreBlockMsg payload)."""
        X = self.X if X is None else X
        total = jnp.zeros((X.shape[0], num_classes), jnp.float32)
        for comp in components:
            if comp.agent != self.agent_id:
                continue
            if max_round is not None and comp.round > max_round:
                continue
            total = total + _component_score(comp, self.learner, X,
                                             num_classes)
        return total


# ================================================================ fitted result
@dataclass
class Component:
    """One boosting component: (agent, round, alpha, fitted params)."""
    agent: int
    round: int
    alpha: float
    params: PyTree


def _component_score(comp: "Component", learner: Learner, X: jnp.ndarray,
                     num_classes: int) -> jnp.ndarray:
    """One component's [n, K] contribution: alpha * coded votes (Algorithm 1
    line 12 term) — the single definition shared by host-side prediction and
    endpoint score blocks."""
    pred = learner.predict(comp.params, X)
    return comp.alpha * encode_labels(pred, num_classes)


@dataclass
class FittedASCII:
    """The trained ensemble: Algorithm 1's output, usable for prediction.

    Also the engine's session result (``Session.fitted()``) and the
    back-compat return type of ``protocol.fit``.
    """
    components: list[Component]
    learners: Sequence[Learner]
    num_classes: int
    history: list[dict] = field(default_factory=list)

    def decision_scores(self, Xs: Sequence[jnp.ndarray],
                        max_round: int | None = None) -> jnp.ndarray:
        """Line 12 of Algorithm 1: sum_t sum_m alpha * g (coded scores).

        Each agent evaluates only its own components on its own features and
        ships a [n, K] score block — O(nK) communication, not raw data.
        """
        n = Xs[0].shape[0]
        k = self.num_classes
        # NB: summed in component order (not grouped per agent) so float
        # addition order — and therefore predictions — match the legacy loop
        # bit for bit.
        total = jnp.zeros((n, k), jnp.float32)
        for comp in self.components:
            if max_round is not None and comp.round > max_round:
                continue
            total = total + _component_score(comp, self.learners[comp.agent],
                                             Xs[comp.agent], k)
        return total

    def predict(self, Xs: Sequence[jnp.ndarray],
                max_round: int | None = None) -> jnp.ndarray:
        return jnp.argmax(self.decision_scores(Xs, max_round), axis=-1)

    @property
    def num_rounds(self) -> int:
        return max((c.round for c in self.components), default=-1) + 1


# ============================================================ protocol variants
class ProtocolVariant(abc.ABC):
    """The round rule of one decentralized-learning protocol.

    The engine's session loop (scheduling, churn filtering, budget
    exhaustion, CV stop, checkpointing) is protocol-agnostic; a variant
    supplies what happens *inside* one round and how the trained model
    predicts.  ASCII (ignorance interchange) is the built-in variant;
    FedAvg and Assisted Learning live in :mod:`repro.scenarios.protocols`
    and ship their traffic through the same transports, codecs, budgets,
    and DP accounting — that is the whole point: one wire, comparable
    ledgers.
    """

    name = "variant"

    def bind(self, session: "Session") -> None:
        """Session-start hook: validate the endpoint roster and initialize
        the variant's protocol state (``session.state.proto``, a
        checkpointable pytree) when the session is fresh.  Called on both
        fresh starts and resumes; ``state.proto`` is only initialized when
        missing."""

    @abc.abstractmethod
    def run_round(self, session: "Session", order: list[int],
                  rec: dict) -> bool:
        """Execute one round over the (churn-filtered) agent ``order``,
        recording into the history record ``rec``.  Returns True when the
        protocol's own stop criterion fired."""

    @abc.abstractmethod
    def fitted(self, session: "Session"):
        """The trained, predict-capable result of this session."""

    def fit_compiled(self, protocol: "Protocol", key, endpoints, classes,
                     validation):
        """Lower a whole run into one XLA program (optional).  Variants
        without a lowering run eager only."""
        raise ValueError(
            f"protocol variant {self.name!r} has no compiled lowering; "
            f"use backend='eager'")


class ASCIIVariant(ProtocolVariant):
    """The paper's protocol: ignorance-score interchange around the chain
    (Algorithm 1 lines 3-11), including the stale-read async barrier."""

    name = "ascii"

    def bind(self, session: "Session") -> None:
        sc = session.scenario
        if sc is not None and getattr(sc, "clock_skew", None):
            if session.state.proto is None:
                # bounded ignorance history for clock-skewed stale reads:
                # agent m reads the score from skew_m barriers ago
                session.state.proto = {"w_hist": [session.state.w]}

    def run_round(self, session: "Session", order: list[int],
                  rec: dict) -> bool:
        st, cfg = session.state, session.cfg
        eps = {ep.agent_id: ep for ep in session.endpoints}
        rec.setdefault("alphas", [])
        rec.setdefault("accs", [])
        if session.scheduler.stale:
            return session._step_stale(order, eps, rec)
        reweight, standard = session._reweight()
        k = cfg.num_classes
        t = st.round
        n = st.w.shape[0]
        u = jnp.ones((n,), jnp.float32)
        stop = False
        for j, m in enumerate(order):
            dst = eps[order[(j + 1) % len(order)]]
            with session._span("hop", src=eps[m].name, dst=dst.name):
                st.key, sub = jax.random.split(st.key)
                w_fit = session.fit_weight(m, st.w)
                params = eps[m].fit_local(sub, session.classes, w_fit, k)
                r = eps[m].reward(params, session.classes)
                if (not cfg.upstream) or j == 0:
                    a, rbar = scores.model_weight(st.w, r, k,
                                                  alpha_cap=cfg.alpha_cap)
                else:
                    a, rbar = scores.model_weight(st.w, r, k, u=u,
                                                  alpha_cap=cfg.alpha_cap)
                rec["alphas"].append(float(a))
                rec["accs"].append(float(rbar))
                session.scheduler.observe(m, float(rbar))
                if cfg.stop_on_negative_alpha and float(a) <= 0:
                    return True        # Algorithm 1, line 8
                st.components.append(Component(m, t, float(a), params))
                u = scores.upstream_factor_update(u, a, r, k)
                link_state = (None if st.codec_state is None
                              else st.codec_state.get(eps[m].name))
                st.w, link_state = session.transport.interchange(
                    eps[m], dst, st.w, r, a, reweight, standard,
                    key=sub if session.transport.has_channel else None,
                    codec_state=link_state)
                if link_state is not None:
                    if st.codec_state is None:
                        st.codec_state = {}
                    st.codec_state[eps[m].name] = link_state
        return stop

    def fitted(self, session: "Session") -> "FittedASCII":
        return FittedASCII(session.state.components,
                           [ep.learner for ep in session.endpoints],
                           session.cfg.num_classes, session.state.history)


# ================================================================ session state
@dataclass
class SessionState:
    """Explicit, checkpointable protocol state.

    Arrays (ignorance score, PRNG key, component params) serialize through
    ``train/checkpoint.py``'s structured tree writer; everything else is
    JSON-able metadata.  Saving mid-run and resuming reproduces the exact
    trajectory: the PRNG key is part of the state and schedulers fast-forward
    their RNG via :meth:`Scheduler.skip_to`.
    """

    w: jnp.ndarray
    key: jax.Array
    round: int = 0
    components: list[Component] = field(default_factory=list)
    history: list[dict] = field(default_factory=list)
    stopped: bool = False
    best_val: float = -1.0
    cv_stale: int = 0
    # per-round active-agent counts (for exact scheduler-RNG replay on
    # resume) and the endpoint active flags at checkpoint time
    order_sizes: list[int] = field(default_factory=list)
    active: list[bool] | None = None
    # per-link wire-codec state (top-k error-feedback residuals, keyed by
    # sender name) — part of the protocol state, so checkpoint/resume
    # reproduces lossy-channel trajectories exactly
    codec_state: dict | None = None
    # JSON-able transport channel bookkeeping captured at checkpoint time
    # (budget spent-bits / link spend / exhaustion, DP release counts):
    # without it a resumed run would restart the bit budget and epsilon
    # ledger from zero, violating the caps the paused run was under
    comm: dict | None = None
    # protocol-variant state (repro.scenarios): a checkpointable pytree of
    # arrays — FedAvg's flat global params, Assisted Learning's running
    # residual, the clock-skew ignorance history.  None for plain ASCII.
    proto: PyTree = None

    # ---- (de)serialization --------------------------------------------------
    def to_tree(self) -> tuple[PyTree, dict]:
        """Split into (array tree, JSON-able metadata)."""
        tree = {"w": self.w,
                "key": jax.random.key_data(self.key),
                "params": [c.params for c in self.components],
                "codec_state": self.codec_state,
                "proto": self.proto}
        meta = {"round": self.round,
                "stopped": self.stopped,
                "best_val": self.best_val,
                "cv_stale": self.cv_stale,
                "history": self.history,
                "order_sizes": self.order_sizes,
                "active": self.active,
                "comm": self.comm,
                "components": [{"agent": c.agent, "round": c.round,
                                "alpha": c.alpha} for c in self.components]}
        return tree, meta

    @classmethod
    def from_tree(cls, tree: PyTree, meta: dict) -> "SessionState":
        components = [
            Component(int(c["agent"]), int(c["round"]), float(c["alpha"]), p)
            for c, p in zip(meta["components"], tree["params"])]
        return cls(w=jnp.asarray(tree["w"]),
                   key=jax.random.wrap_key_data(jnp.asarray(tree["key"])),
                   round=int(meta["round"]),
                   components=components,
                   history=list(meta["history"]),
                   stopped=bool(meta["stopped"]),
                   best_val=float(meta["best_val"]),
                   cv_stale=int(meta["cv_stale"]),
                   order_sizes=[int(s) for s in meta.get("order_sizes", [])],
                   active=meta.get("active"),
                   codec_state=tree.get("codec_state"),
                   comm=meta.get("comm"),
                   proto=tree.get("proto"))

    def save(self, directory: str, step: int | None = None) -> str:
        from repro.train import checkpoint
        tree, meta = self.to_tree()
        return checkpoint.save_structured(
            directory, self.round if step is None else step, tree, meta=meta)

    @classmethod
    def restore(cls, directory: str, step: int | None = None) -> "SessionState":
        from repro.train import checkpoint
        tree, meta, _ = checkpoint.restore_structured(directory, step=step)
        return cls.from_tree(tree, meta)


# ======================================================================= config
@dataclass(frozen=True)
class SessionConfig:
    """Engine knobs (the old ASCIIConfig minus variant/seed, which became
    the Scheduler)."""
    num_classes: int
    max_rounds: int = 20
    upstream: bool = True             # eqs. 11/13 side info (False = -Simple)
    stop_on_negative_alpha: bool = True
    cv_patience: int = 2
    alpha_cap: float = 20.0
    exact_reweight: bool = False      # beyond-paper exact exp-loss reweight


def holdout_split(Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
                  fraction: float):
    """The paper's CV stop criterion split (Section III-C): reserve the
    trailing rows (aligned by sample ID) for validation."""
    cut = int(round((1.0 - fraction) * Xs[0].shape[0]))
    return ([x[:cut] for x in Xs], classes[:cut],
            [x[cut:] for x in Xs], classes[cut:])


# ====================================================================== session
class Session:
    """A live protocol run: endpoints + scheduler + transport + state.

    ``step()`` executes one interchange round and returns whether the
    session should continue; ``run()`` loops to completion.  Between steps
    callers may drop endpoints (``active = False``), add late joiners
    (:meth:`add_endpoint`), or checkpoint (:meth:`checkpoint`).
    """

    def __init__(self, cfg: SessionConfig, scheduler: Scheduler,
                 transport: Transport, endpoints: Sequence[AgentEndpoint],
                 classes: jnp.ndarray, state: SessionState,
                 validation: tuple[Sequence[jnp.ndarray], jnp.ndarray] | None = None,
                 variant: ProtocolVariant | None = None,
                 scenario=None, telemetry=None,
                 _send_setup: bool = True) -> None:
        self.cfg = cfg
        self.scheduler = scheduler
        self.transport = transport
        # optional repro.telemetry.Telemetry: pure observation — attached
        # before any traffic so the registry sees every booking, never read
        # by protocol logic (telemetry on == off, bit for bit)
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.attach_transport(transport)
        self.endpoints = list(endpoints)
        for i, ep in enumerate(self.endpoints):
            assert ep.agent_id == i, "endpoint agent_ids must be 0..M-1"
        self.classes = classes
        self.state = state
        self.validation = validation
        self.variant = variant if variant is not None else ASCIIVariant()
        self.scenario = scenario
        # per-session variant context (derived, non-checkpointed: unravel
        # closures, one-hot labels, fit-weight tables) — variants stash what
        # bind() computes here so one variant object can drive many sessions
        self.vctx: dict = {}
        if scheduler.stale and transport.controller is not None:
            raise ValueError(
                "adaptive controllers do not apply to the stale-read async "
                "path: their EMA statistic is defined on per-hop "
                "interchange, and the barrier releases once per round; "
                "drop controller= (codec/privacy/budget channels release "
                "per barrier and are supported)")
        if not isinstance(self.variant, ASCIIVariant):
            if scheduler.stale:
                raise ValueError(
                    f"the stale-read async barrier is an ASCII merge rule; "
                    f"protocol variant {self.variant.name!r} needs a "
                    f"sequential or random scheduler")
            if transport.controller is not None \
                    or transport.serve_controller is not None:
                raise ValueError(
                    "adaptive controllers read ignorance-vector statistics; "
                    f"they do not apply to protocol variant "
                    f"{self.variant.name!r} traffic — drop controller=/"
                    "serve_controller=")
        self._participation = None
        self._shard_w = None
        if scenario is not None:
            scenario.validate(len(self.endpoints), scheduler, self.variant)
            self._participation = scenario.participation(
                cfg.max_rounds, len(self.endpoints))
            self._shard_w = scenario.shard_weights(classes,
                                                   len(self.endpoints))
        transport.bind(self.endpoints)
        scheduler.bind_transport(transport)
        self.variant.bind(self)
        # live in-flight emission (telemetry.live): eager rounds tap the
        # sink directly with per-round registry deltas.  Metered transports
        # only — an unmetered run books nothing, so its taps would read
        # all-zero and break the eager==compiled live-series pin.  The prev
        # counters snapshot *before* the collation setup so the setup bits
        # land in round 0's delta, matching the compiled t==0 tap.
        self._live = None
        if telemetry is not None \
                and getattr(telemetry, "live", None) is not None \
                and getattr(transport, "log", None) is not None:
            self._live = telemetry.live
            self._live_prev = self._live_counters()
        if _send_setup:
            self._send_setup()

    # ---- live emission ------------------------------------------------------
    def _live_counters(self) -> tuple:
        """The replay-equal counters the eager round taps difference: total
        wire bits, ignorance messages, budget skips, the exhausted flag."""
        reg = self.telemetry.registry
        return (reg.total("wire_bits_total"),
                reg.value("messages_total", kind="ignorance"),
                reg.total("budget_skips_total"),
                bool(getattr(self.transport, "exhausted", False)))

    def _emit_live_round(self, t: int) -> None:
        """One eager round tap: the same (round, bits, sent, skipped,
        exhaustion-edge) payload the compiled scan's emit_round stages, so
        the two backends fold identical live series."""
        bits, ign, skips, exh = cur = self._live_counters()
        p_bits, p_ign, p_skips, p_exh = self._live_prev
        self._live_prev = cur
        self._live.round_tap(t, int(bits - p_bits), int(ign - p_ign),
                             int(skips - p_skips), int(exh and not p_exh))

    # ---- wiring -------------------------------------------------------------
    def _span(self, name: str, step: int | None = None, **attrs):
        """A telemetry span when telemetry is attached, else a no-op
        context — call sites stay branch-free."""
        if self.telemetry is None:
            return nullcontext()
        return self.telemetry.span(name, step, **attrs)

    def _send_setup_to(self, ep: AgentEndpoint) -> None:
        """Collation setup for one endpoint: the head agent shares labels
        and sample IDs (metered under Fig. 4)."""
        n = int(self.classes.shape[0])
        head = self.endpoints[0].name
        self.transport.send(LabelsMsg(head, ep.name, n))
        self.transport.send(SampleIdsMsg(head, ep.name, n))

    def _send_setup(self) -> None:
        for ep in self.endpoints[1:]:
            self._send_setup_to(ep)

    def add_endpoint(self, learner: Learner, X: jnp.ndarray,
                     name: str = "") -> AgentEndpoint:
        """Late join: a new agent enters the live session.  It receives the
        collation setup and participates from the next round on."""
        ep = AgentEndpoint(len(self.endpoints), learner, X, name=name)
        self.endpoints.append(ep)
        self.transport.bind(self.endpoints)
        self._send_setup_to(ep)
        return ep

    def _reweight(self):
        cfg = self.cfg
        if cfg.exact_reweight:
            return (lambda w, r, a:
                    scores.ignorance_update_exact(w, r, a, cfg.num_classes)), False
        return scores.ignorance_update, True

    def fit_weight(self, m: int, w: jnp.ndarray) -> jnp.ndarray:
        """Agent m's fit-weight vector: the protocol weight ``w`` masked to
        the agent's non-IID shard (repro.scenarios partitions) and
        renormalized.  Identity when the scenario is IID — the zero-scenario
        path is untouched, byte for byte."""
        if self._shard_w is None:
            return w
        wm = w * self._shard_w[m]
        return wm / jnp.maximum(jnp.sum(wm), 1e-12)

    # ---- the round loop -----------------------------------------------------
    def step(self) -> bool:
        """One interchange round (Algorithm 1 lines 3-11 / the Section-IV
        chain).  Returns False once the session stopped."""
        st, cfg = self.state, self.cfg
        if st.stopped or st.round >= cfg.max_rounds:
            return False
        if getattr(self.transport, "exhausted", False):
            # budget-aware scheduling: the session bit budget can no longer
            # afford even the cheapest codec rung — stop scheduling rounds
            st.stopped = True
            return False
        t = st.round
        active = [ep.agent_id for ep in self.endpoints if ep.active]
        if not active:
            st.stopped = True          # everyone dropped out: nothing to run
            return False
        order = self.scheduler.round_order(t, active)
        # record the *pre-churn* order size: scheduler-RNG replay on resume
        # redraws from the active roster, then re-applies the (pure, seeded)
        # participation schedule
        st.order_sizes.append(len(order))
        rec: dict = {"round": t}
        if self._participation is not None:
            order = [m for m in order if self._participation[t, m]]
            rec["participants"] = list(order)
        stop = False
        with self._span("round", step=t, agents=len(order)):
            if order:
                stop = self.variant.run_round(self, order, rec)
        # an all-churned round is an empty round, not a stop: stragglers
        # come back

        if self.validation is not None:
            Xs_val, c_val = self.validation
            val_acc = float(jnp.mean(self.fitted().predict(Xs_val) == c_val))
            rec["val_acc"] = val_acc
            if val_acc > st.best_val + 1e-9:
                st.best_val, st.cv_stale = val_acc, 0
            else:
                st.cv_stale += 1
                if st.cv_stale >= cfg.cv_patience:
                    stop = True        # out-sample error no longer decreasing
        st.history.append(rec)
        st.round += 1
        if stop:
            st.stopped = True
        if self._live is not None:
            self._emit_live_round(t)
        return not st.stopped and st.round < cfg.max_rounds

    def _step_stale(self, order: list[int], eps: dict, rec: dict) -> bool:
        """Asynchronous round: stale reads, damped multiplicative merge at
        the barrier (see AsyncStaleScheduler)."""
        st, cfg = self.state, self.cfg
        k = cfg.num_classes
        t = st.round
        fits = []
        for m in order:
            st.key, sub = jax.random.split(st.key)
            w_read = self._stale_view(m)
            params = eps[m].fit_local(sub, self.classes,
                                      self.fit_weight(m, w_read), k)
            r = eps[m].reward(params, self.classes)
            a, rbar = scores.model_weight(w_read, r, k,
                                          alpha_cap=cfg.alpha_cap)
            fits.append((m, params, r, a, rbar))
        w_next = st.w
        any_pos = False
        total = len(order)
        channel = self.transport.has_channel
        for j, (m, params, r, a, rbar) in enumerate(fits):
            rec["alphas"].append(float(a))
            rec["accs"].append(float(rbar))
            self.scheduler.observe(m, float(rbar))
            if float(a) <= 0:
                continue
            any_pos = True
            st.components.append(Component(m, t, float(a), params))
            # damp the stale multiplicative updates by 1/M: the naive product
            # of M per-agent reweights diverges for large M (measured:
            # chance-level at M=20); damping restores the per-round weight
            # movement of the sequential chain.
            w_next = w_next * jnp.exp((a / total) * (1.0 - r))
            if channel:
                # under a wire channel the barrier is the release point:
                # only the raw scalar alphas cross per agent; the merged
                # score ships once, below
                self.transport.send(ModelWeightMsg(eps[m].name, "barrier",
                                                   float(a)))
            else:
                dst = eps[order[(j + 1) % total]]
                self.transport.send(IgnoranceMsg(eps[m].name, dst.name,
                                                 w_next))
                self.transport.send(ModelWeightMsg(eps[m].name, dst.name,
                                                   float(a)))
        w_bar = w_next / jnp.maximum(jnp.sum(w_next), 1e-12)
        if not channel:
            st.w = w_bar
        else:
            # per-barrier release semantics: DP noise + codec encode happen
            # at merge time, once per round, and a budgeted transport walks
            # its ladder at the *barrier* granularity — a skipped release
            # leaves the published score stale for one more round
            st.key, kbar = jax.random.split(st.key)
            link_state = (None if st.codec_state is None
                          else st.codec_state.get("barrier"))
            released, link_state = self.transport.barrier_release(
                eps[order[0]], w_bar, key=kbar, codec_state=link_state)
            if link_state is not None:
                if st.codec_state is None:
                    st.codec_state = {}
                st.codec_state["barrier"] = link_state
            if released is not None:
                st.w = released
        self._push_stale_hist()
        return not any_pos and cfg.stop_on_negative_alpha

    def _stale_view(self, m: int) -> jnp.ndarray:
        """The ignorance score agent ``m`` reads at the barrier: the current
        one, or — under a clock-skewed scenario — the one from ``skew_m``
        barriers ago (a slow agent trains against an old broadcast)."""
        sc = self.scenario
        skew = None if sc is None else getattr(sc, "clock_skew", None)
        if not skew or not skew[m]:
            return self.state.w
        hist = self.state.proto["w_hist"]
        return hist[max(0, len(hist) - 1 - int(skew[m]))]

    def _push_stale_hist(self) -> None:
        """Advance the bounded clock-skew history after a barrier merge."""
        sc = self.scenario
        skew = None if sc is None else getattr(sc, "clock_skew", None)
        if not skew:
            return
        hist = self.state.proto["w_hist"]
        hist.append(self.state.w)
        depth = max(int(s) for s in skew) + 1
        del hist[:-depth]

    def run(self, max_rounds: int | None = None) -> SessionState:
        """Drive ``step()`` to completion (or for ``max_rounds`` more)."""
        budget = float("inf") if max_rounds is None else max_rounds
        with self._span("session", backend="eager",
                        agents=len(self.endpoints)):
            while budget > 0:
                budget -= 1
                if not self.step():
                    break
        return self.state

    # ---- results ------------------------------------------------------------
    def fitted(self):
        return self.variant.fitted(self)

    def predict_distributed(self, Xs: Sequence[jnp.ndarray] | None = None,
                            max_round: int | None = None, *,
                            key=None, request=None) -> jnp.ndarray:
        """Prediction as the protocol actually runs it: every endpoint ships
        its [n, K] ScoreBlockMsg to the head agent, which sums and argmaxes.

        The blocks travel through the transport's wire channel
        (:meth:`Transport.serve_block`): DP-noised, codec-encoded, booked at
        their *encoded* size, and — on a budgeted transport — walked down
        the same degrade-then-skip ladder as training hops.  A skipped block
        degrades the answer toward head-only prediction instead of booking
        bits the budget cannot afford.  ``key`` seeds the serve channel
        (stochastic rounding / DP noise); by default it folds off the
        session's current PRNG key with the SERVE tag (plus the integer
        ``request`` tag when given — request-keyed serving: distinct
        requests against one session draw independent channel noise, and
        the serve engine's batched slots derive the identical key), so
        serving never perturbs the fit stream and resumed sessions serve
        identically."""
        if not isinstance(self.variant, ASCIIVariant):
            raise ValueError(
                f"score-block serving is ASCII's prediction protocol; "
                f"variant {self.variant.name!r} predicts via "
                f"session.fitted().predict(Xs)")
        head = self.endpoints[0]
        if key is None and self.transport.has_serve_channel:
            from repro.comm.codecs import serve_key
            key = serve_key(self.state.key, request)
        if self._live is not None:
            reg = self.telemetry.registry
            p_bits = reg.total("wire_bits_total")
            p_blk = reg.value("messages_total", kind="score_block")
            p_skips = reg.total("budget_skips_total")
        total = None
        with self._span("serve", backend="eager",
                        agents=len(self.endpoints)):
            for i, ep in enumerate(self.endpoints):
                X = None if Xs is None else Xs[i]
                block = ep.score_block(self.state.components,
                                       self.cfg.num_classes, X=X,
                                       max_round=max_round)
                if ep is head:
                    contrib = block
                else:
                    sub = None if key is None else jax.random.fold_in(key, i)
                    contrib = self.transport.serve_block(ep, head, block,
                                                         key=sub)
                    if contrib is None:
                        continue       # budget skip: head-only fallback
                total = contrib if total is None else total + contrib
        if self._live is not None:
            # one serve tap per request — the eager twin of the traced
            # emit_serve, differencing the same booked counters
            self._live.serve_tap(
                int(reg.total("wire_bits_total") - p_bits),
                int(reg.value("messages_total", kind="score_block")
                    - p_blk),
                int(reg.total("budget_skips_total") - p_skips))
        return jnp.argmax(total, axis=-1)

    # ---- checkpointing ------------------------------------------------------
    def _comm_snapshot(self) -> dict | None:
        """JSON-able channel bookkeeping that must survive pause/resume:
        budget spend (the cap applies to the whole session, not to one
        process lifetime) and DP release counts (epsilon composes across
        the resume boundary)."""
        t = self.transport
        snap: dict = {}
        if t.accountant is not None:
            snap["releases"] = dict(t.accountant.releases)
        if hasattr(t, "budget"):
            snap["ledger_bits"] = (int(t.log.total_bits)
                                   + int(getattr(t, "carryover_bits", 0)))
            snap["link_spent"] = [[s, d, int(b)]
                                  for (s, d), b in t.link_spent.items()]
            snap["exhausted"] = bool(t.exhausted)
        if t.controller is not None:
            # the adaptive controller's EMA (a float32 scalar — exact
            # through the JSON float round-trip): a resumed session must
            # pick the rungs the uninterrupted one would, not restart the
            # policy at the uniform-entropy state
            snap["ctrl_state"] = float(np.asarray(t.ctrl_state))
        state_dict = getattr(self.scheduler, "state_dict", None)
        if state_dict is not None:
            snap["scheduler"] = state_dict()
        return snap or None

    def _comm_restore(self, snap: dict | None) -> None:
        t = self.transport
        if not snap:
            return
        if snap.get("releases") and t.accountant is not None:
            t.accountant.releases.update(snap["releases"])
        if hasattr(t, "budget"):
            # the resumed transport's log starts empty; the paused run's
            # spend counts against the session cap via carryover_bits
            t.carryover_bits = int(snap.get("ledger_bits", 0))
            t.link_spent = {(s, d): b
                            for s, d, b in snap.get("link_spent", [])}
            t.exhausted = bool(snap.get("exhausted", False))
        if t.controller is not None and snap.get("ctrl_state") is not None:
            t.ctrl_state = jnp.asarray(snap["ctrl_state"], jnp.float32)
        load_state = getattr(self.scheduler, "load_state_dict", None)
        if load_state is not None and snap.get("scheduler") is not None:
            load_state(snap["scheduler"])

    def checkpoint(self, directory: str, step: int | None = None) -> str:
        """Save the live SessionState mid-run (resumable via
        ``Protocol.resume``)."""
        self.state.active = [ep.active for ep in self.endpoints]
        self.state.comm = self._comm_snapshot()
        return self.state.save(directory, step)


# ======================================================================= engine
BACKENDS = ("eager", "compiled")


class Protocol:
    """The ASCII engine: config + scheduler + transport, driving endpoints.

    ``start`` opens a fresh session, ``resume`` restores one from a
    checkpoint directory (fast-forwarding the scheduler RNG), and ``fit`` is
    the one-call convenience that runs a session to completion.

    ``backend`` selects how ``fit`` executes the rounds:

      * ``"eager"`` (default) — the host loop above: one dispatch per fit /
        reward / hop.  Works with every learner, scheduler, and transport,
        and supports mid-run checkpointing, dropout, and late joins.
      * ``"compiled"`` — lower the whole run (all agents x all rounds of
        weighted fit, reward, alpha, ignorance update) into a single
        ``lax.scan`` program via :mod:`repro.core.compiled`.  Requires
        sequential scheduling, no CV validation split, and learners with a
        :class:`~repro.learners.base.LearnerCore` (``functional = True``);
        reproduces the eager trajectory bit for bit, and metered transports
        still receive the exact same message ledger (replayed post-run).
        ``start``/``resume`` (interactive stepping) always run eager.
    """

    def __init__(self, cfg: SessionConfig, scheduler: Scheduler | None = None,
                 transport: Transport | None = None,
                 backend: str = "eager",
                 variant: ProtocolVariant | None = None,
                 scenario=None, telemetry=None) -> None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
        self.cfg = cfg
        self.scheduler = scheduler if scheduler is not None else SequentialScheduler()
        self.transport = transport if transport is not None else InProcessTransport()
        self.backend = backend
        self.variant = variant if variant is not None else ASCIIVariant()
        self.scenario = scenario
        # optional repro.telemetry.Telemetry, threaded into sessions (eager)
        # and attached around the ledger replay (compiled) — observation
        # only, never read by protocol logic
        self.telemetry = telemetry
        # last fit() context, so predict_distributed works on both backends:
        # the eager session, or the compiled (endpoints, plan, result)
        self._fit_key = None
        self._session: Session | None = None
        self._compiled_ctx = None

    def start(self, key: jax.Array, endpoints: Sequence[AgentEndpoint],
              classes: jnp.ndarray,
              validation=None) -> Session:
        n = endpoints[0].X.shape[0]
        state = SessionState(w=scores.init_ignorance(n), key=key)
        self.scheduler.reset()
        return Session(self.cfg, self.scheduler, self.transport, endpoints,
                       classes, state, validation=validation,
                       variant=self.variant, scenario=self.scenario,
                       telemetry=self.telemetry)

    def resume(self, directory: str, endpoints: Sequence[AgentEndpoint],
               classes: jnp.ndarray, validation=None,
               step: int | None = None) -> Session:
        """Restore a checkpointed session and continue where it left off."""
        state = SessionState.restore(directory, step=step)
        self.scheduler.reset()
        self.scheduler.skip_to(state.order_sizes)
        if state.active is not None:
            if len(endpoints) != len(state.active):
                raise ValueError(
                    f"resume expects {len(state.active)} endpoints (the "
                    f"checkpointed session's roster, incl. late joiners), "
                    f"got {len(endpoints)}")
            for ep, flag in zip(endpoints, state.active):
                ep.active = bool(flag)
        session = Session(self.cfg, self.scheduler, self.transport, endpoints,
                          classes, state, validation=validation,
                          variant=self.variant, scenario=self.scenario,
                          telemetry=self.telemetry, _send_setup=False)
        session._comm_restore(state.comm)
        return session

    def fit(self, key: jax.Array, endpoints: Sequence[AgentEndpoint],
            classes: jnp.ndarray, validation=None) -> FittedASCII:
        self._fit_key = key
        if self.backend == "compiled":
            return self._fit_compiled(key, endpoints, classes, validation)
        session = self.start(key, endpoints, classes, validation=validation)
        session.run()
        self._session = session
        return session.fitted()

    # ---- compiled backend ---------------------------------------------------
    def _span(self, name: str, step: int | None = None, **attrs):
        if self.telemetry is None:
            return nullcontext()
        return self.telemetry.span(name, step, **attrs)

    def _fence(self, value):
        return value if self.telemetry is None else \
            self.telemetry.fence(value)

    def _live_sink(self):
        """The live sink when in-flight emission applies to this run:
        telemetry opened the live plane AND the transport is metered (an
        unmetered run books no wire bits on either backend, so live taps
        would have nothing to mirror)."""
        if self.telemetry is not None \
                and getattr(self.telemetry, "live", None) is not None \
                and getattr(self.transport, "log", None) is not None:
            return self.telemetry.live
        return None

    def _fit_compiled(self, key, endpoints: Sequence[AgentEndpoint],
                      classes: jnp.ndarray, validation) -> FittedASCII:
        """One-program execution of the whole run (core/compiled.py), with
        the transport ledger replayed afterwards so Fig.-4 metering is
        byte-identical to the eager path."""
        from repro.core import compiled
        cfg = self.cfg
        if self.telemetry is not None:
            # attach before any booking: the replay walk below (and the
            # variant lowerings' replays) then emit into the registry
            # through the same TransportLog/accountant hooks the eager
            # path uses
            self.telemetry.attach_transport(self.transport)
        if not isinstance(self.variant, ASCIIVariant):
            # protocol variants own their lowering (repro.scenarios.compiled
            # lowers FedAvg's homogeneous round into a lax.scan); the engine
            # stays variant-agnostic
            return self.variant.fit_compiled(self, key, endpoints, classes,
                                             validation)
        if self.scenario is not None and not self.scenario.trivial:
            raise ValueError(
                "backend='compiled' does not lower ASCII scenario knobs "
                "(churn/subsampling/partitions change the chain per round); "
                "use backend='eager', or protocol='fedavg' whose lowering "
                "takes a participation mask")
        sched_plan = None
        if self.scheduler.stale:
            # the stale-read barrier has its own lowering (one scan over
            # barrier rounds) — selected by the AsyncStalePlan marker
            sched_plan = compiled.AsyncStalePlan()
        elif not isinstance(self.scheduler, SequentialScheduler):
            plan_fn = getattr(self.scheduler, "plan", None)
            if plan_fn is None:
                raise ValueError(
                    f"backend='compiled' supports sequential, budget-aware "
                    f"and async-stale scheduling, "
                    f"got {type(self.scheduler).__name__}")
            # the scheduler's static twin (spend signal depends on which
            # transport it will order against)
            self.scheduler.bind_transport(self.transport)
            sched_plan = plan_fn()
        if validation is not None:
            raise ValueError("backend='compiled' does not support the CV "
                             "validation stop; use the eager backend")
        if not all(ep.active for ep in endpoints):
            raise ValueError("backend='compiled' assumes all endpoints "
                             "active for the whole run")
        plan = compiled.plan_for(
            [ep.learner for ep in endpoints], cfg.num_classes,
            max_rounds=cfg.max_rounds, upstream=cfg.upstream,
            stop_on_negative_alpha=cfg.stop_on_negative_alpha,
            alpha_cap=cfg.alpha_cap, exact_reweight=cfg.exact_reweight,
            # mirror the eager transport's update implementation: mesh-ring
            # runs the fused Pallas kernel (with its configured interpret
            # mode), the host transports the jnp formula — so the pin holds
            # at any score length (at n <= bn the two are bit-identical
            # anyway)
            use_kernel=isinstance(self.transport, MeshRingTransport),
            kernel_interpret=getattr(self.transport, "interpret", None),
            # the wire channel rides the scan: same codec/privacy/budget
            # objects the eager transport holds, so the traced channel and
            # the rung-choice rule are shared, not re-implemented
            codec=self.transport.codec, privacy=self.transport.privacy,
            budget=getattr(self.transport, "budget", None),
            serve_codec=self.transport.serve_codec,
            controller=self.transport.controller,
            serve_controller=self.transport.serve_controller,
            scheduler=sched_plan)
        live_sink = self._live_sink()
        live = live_sink is not None
        if isinstance(sched_plan, compiled.AsyncStalePlan):
            with self._span("session", backend="compiled",
                            agents=len(endpoints)):
                with live_installed(live_sink):
                    result = self._fence(compiled.async_session(
                        plan, key, tuple(ep.X for ep in endpoints),
                        classes, live=live))
            fitted = compiled.fitted_from_async_result(
                plan, result, [ep.learner for ep in endpoints])
            with self._span("replay", backend="compiled"):
                self._replay_traffic_async(endpoints, classes, result, plan)
            self._compiled_ctx = (tuple(endpoints), plan, result)
            return fitted
        with self._span("session", backend="compiled",
                        agents=len(endpoints)):
            # the fence closes the span at computation-done, not at
            # async-dispatch enqueue — timing only, values untouched
            with live_installed(live_sink):
                result = self._fence(compiled.compiled_session(
                    plan, key, tuple(ep.X for ep in endpoints), classes,
                    live=live))
        fitted = compiled.fitted_from_result(
            plan, result, [ep.learner for ep in endpoints])
        with self._span("replay", backend="compiled"):
            self._replay_traffic(endpoints, classes, result, plan)
        # the serve path indexes per-agent state positionally: store the
        # agent-major view (identity re-collection for sequential plans)
        self._compiled_ctx = (tuple(endpoints), plan,
                              compiled.agent_major_result(result))
        return fitted

    def _replay_traffic(self, endpoints: Sequence[AgentEndpoint],
                        classes: jnp.ndarray, result, plan=None) -> None:
        """Book the message ledger a sequential eager run would have
        produced: collation setup, then one IgnoranceMsg + ModelWeightMsg
        per component-producing hop, in chain order — at the *encoded* size
        of whichever codec rung the scan shipped each hop with, skipping
        budget-dropped hops, and tallying the privacy accountant, so the
        compiled ledger is byte-identical to the eager one."""
        self.transport.bind(endpoints)
        n = int(classes.shape[0])
        head = endpoints[0].name
        for ep in endpoints[1:]:
            self.transport.send(LabelsMsg(head, ep.name, n))
            self.transport.send(SampleIdsMsg(head, ep.name, n))
        valid = np.asarray(result.valid)
        alphas = np.asarray(result.alphas)
        accs = np.asarray(result.accs)
        executed = np.asarray(result.executed)
        sent = np.asarray(result.sent)
        codec_idx = np.asarray(result.codec_idx)
        order = getattr(result, "order", None)
        order = None if order is None else np.asarray(order)
        ladder = plan.ladder if plan is not None and plan.has_channel else None
        budget = plan.budget if plan is not None else None
        budgeted = budget is not None and hasattr(self.transport,
                                                  "link_spent")
        # a permuting scheduler replays too: round_order reads the live
        # ledger state at each round entry (telemetry + RNG side effects)
        # and observe feeds the reward EMAs — so post-run scheduler state
        # and registry counters match the eager session's exactly
        permuted = plan is not None and plan.scheduler is not None
        num = len(endpoints)
        for t in range(valid.shape[0]):
            if permuted and executed[t].any():
                self.scheduler.round_order(t, list(range(num)))
            for j in range(num):
                src = j if order is None else int(order[t, j])
                dst_i = ((j + 1) % num if order is None
                         else int(order[t, (j + 1) % num]))
                if permuted and executed[t, j]:
                    self.scheduler.observe(src, float(accs[t, j]))
                if not valid[t, j]:
                    continue
                dst = endpoints[dst_i]
                link = (endpoints[src].name, dst.name)
                if not sent[t, j]:
                    if budgeted:
                        self.transport.record_skip(link)
                    continue
                if budgeted:
                    # spend-first, like the eager ladder walk: record_spend
                    # arms the rung stamp the wire-priced send consumes
                    rung = int(codec_idx[t, j])
                    self.transport.record_spend(
                        link, budget.hop_costs(n)[rung], rung)
                codec = ladder[int(codec_idx[t, j])] if ladder else None
                wire_bits = codec.wire_bits(n) if codec is not None else None
                self.transport.send(IgnoranceMsg(
                    endpoints[src].name, dst.name, result.w_trace[t, j],
                    wire_bits=wire_bits))
                self.transport.send(ModelWeightMsg(
                    endpoints[src].name, dst.name, float(alphas[t, j])))
                if self.transport.privacy is not None:
                    self.transport.accountant.record(endpoints[src].name)
        if budgeted:
            self.transport.exhausted = bool(result.exhausted)

    def _replay_traffic_async(self, endpoints: Sequence[AgentEndpoint],
                              classes: jnp.ndarray, result, plan) -> None:
        """Book the ledger an eager async-stale run produces: channel-less,
        the per-agent mid-merge IgnoranceMsg + ModelWeightMsg pairs; with a
        wire channel, the raw per-agent alpha messages followed by the one
        per-barrier release (or its budget skip) — spend-first, rung
        stamped, DP release tallied, byte-identical to the eager barrier."""
        self.transport.bind(endpoints)
        n = int(classes.shape[0])
        head = endpoints[0].name
        for ep in endpoints[1:]:
            self.transport.send(LabelsMsg(head, ep.name, n))
            self.transport.send(SampleIdsMsg(head, ep.name, n))
        executed = np.asarray(result.executed)
        valid = np.asarray(result.valid)
        alphas = np.asarray(result.alphas)
        sent = np.asarray(result.sent)
        rungs = np.asarray(result.codec_idx)
        num = len(endpoints)
        channel = plan.has_channel
        budget = plan.budget
        budgeted = budget is not None and hasattr(self.transport,
                                                  "link_spent")
        for t in range(valid.shape[0]):
            if not executed[t].any():
                break
            if not channel:
                for m in range(num):
                    if not valid[t, m]:
                        continue
                    dst = endpoints[(m + 1) % num]
                    self.transport.send(IgnoranceMsg(
                        endpoints[m].name, dst.name, result.w_trace[t, m]))
                    self.transport.send(ModelWeightMsg(
                        endpoints[m].name, dst.name, float(alphas[t, m])))
                continue
            for m in range(num):
                if valid[t, m]:
                    self.transport.send(ModelWeightMsg(
                        endpoints[m].name, "barrier", float(alphas[t, m])))
            link = ("barrier", endpoints[0].name)
            if not sent[t]:
                if budgeted:
                    self.transport.record_skip(link)
                continue
            rung = int(rungs[t])
            codec = plan.ladder[rung] if rung >= 0 else None
            if budgeted:
                self.transport.record_spend(
                    link, budget.payload_costs(n)[rung], rung)
            wire_bits = codec.wire_bits(n) if codec is not None else None
            self.transport.send(IgnoranceMsg(
                "barrier", endpoints[0].name, result.w_bar[t],
                wire_bits=wire_bits))
            if self.transport.privacy is not None:
                self.transport.accountant.record("barrier")
        if budgeted:
            self.transport.exhausted = bool(result.exhausted)

    # ---- serve path ---------------------------------------------------------
    def predict_distributed(self, Xs: Sequence[jnp.ndarray] | None = None,
                            max_round: int | None = None, *,
                            key=None, request=None) -> jnp.ndarray:
        """Distributed prediction after :meth:`fit`, on either backend:
        every endpoint ships its [n, K] ScoreBlockMsg to the head agent
        through the transport's serve channel (codec, DP noise, budget
        ladder).  The compiled backend runs the traced serve step
        (:func:`repro.core.compiled.serve_session`) and replays the exact
        encoded-bit ledger the eager path books — predictions and ledgers
        are pinned bit-identical across backends per codec.

        The default serve ``key`` is the same on both backends: the
        session's *evolved* PRNG key (post-run ``state.key``) folded with
        the SERVE tag (and the integer ``request`` tag when given) — the
        only derivation a resumed session can also reproduce, since it no
        longer knows the original fit key."""
        if self.backend == "eager":
            if self._session is None:
                raise RuntimeError("predict_distributed needs a completed "
                                   "fit() on this Protocol (or use "
                                   "Session.predict_distributed directly)")
            # key=None: the Session derives the default from its evolved
            # state.key, matching the compiled branch below
            return self._session.predict_distributed(Xs, max_round, key=key,
                                                     request=request)
        from repro.core import compiled
        if self._compiled_ctx is None:
            raise RuntimeError("predict_distributed needs a completed fit()")
        endpoints, plan, result = self._compiled_ctx
        if key is None and self.transport.has_serve_channel:
            from repro.comm.codecs import serve_key
            key = serve_key(self._evolved_key(result), request)
        Xs_serve = (tuple(ep.X for ep in endpoints) if Xs is None
                    else tuple(jnp.asarray(x) for x in Xs))
        valid = result.valid
        if max_round is not None:
            mask = (jnp.arange(valid.shape[0]) <= max_round)[:, None]
            valid = jnp.logical_and(valid, mask)
        shape = (int(Xs_serve[0].shape[0]), self.cfg.num_classes)
        rem_session, rem_link = self._serve_remaining(endpoints, shape, plan)
        live_sink = self._live_sink()
        with self._span("serve", backend="compiled",
                        agents=len(endpoints)):
            with live_installed(live_sink):
                serve = self._fence(compiled.serve_session(
                    plan, result, key, Xs_serve, valid=valid,
                    rem_session=rem_session, rem_link=rem_link,
                    live=live_sink is not None))
        with self._span("replay", backend="compiled"):
            self._replay_serve(endpoints, serve, shape, plan)
        return serve.preds

    def _evolved_key(self, result):
        """The eager session's post-run ``state.key``, reconstructed from
        the fit key: the eager loop splits once per fit slot it reaches
        (plus once per executed round for the channelized async barrier's
        release subkey), and the compiled scan's key chain agrees with it
        on every executed slot (post-stop splits are masked out), so the
        same split count lands on the identical key."""
        executed = np.asarray(result.executed)
        splits = int(executed.sum())
        from repro.core import compiled
        if isinstance(result, compiled.AsyncSessionResult) \
                and self.transport.has_channel:
            splits += int(executed.any(axis=1).sum())
        k = self._fit_key
        for _ in range(splits):
            k, _ = jax.random.split(k)
        return k

    def _serve_remaining(self, endpoints, shape, plan):
        """Host-side remaining-budget snapshot the traced serve step starts
        from (the compiled analogue of BudgetedTransport's per-hop reads)."""
        num = len(endpoints)
        if plan.budget is None or not hasattr(self.transport, "link_spent"):
            return None, None
        t, budget = self.transport, plan.budget
        rem_s = (np.iinfo(np.int32).max if budget.session_bits is None
                 else budget.session_bits - t.log.total_bits
                 - t.carryover_bits)
        head = endpoints[0].name
        rem_l = []
        for ep in endpoints:
            link = (ep.name, head)
            rem_l.append(np.iinfo(np.int32).max if budget.link_bits is None
                         else budget.link_bits - t.link_spent.get(link, 0))
        return int(rem_s), tuple(int(r) for r in rem_l)

    def _replay_serve(self, endpoints, serve, shape, plan) -> None:
        """Book the serve-path message ledger the eager path would have
        produced: one ScoreBlockMsg per shipped block at the encoded size of
        the rung the traced serve step chose, skipped links recorded, DP
        releases tallied, budget state advanced — byte-identical to eager
        ``Session.predict_distributed``."""
        head = endpoints[0]
        sent = np.asarray(serve.sent)
        rungs = np.asarray(serve.codec_idx)
        ladder = plan.serve_ladder
        budgeted = (plan.budget is not None
                    and hasattr(self.transport, "link_spent"))
        for j in range(1, len(endpoints)):
            link = (endpoints[j].name, head.name)
            if not sent[j]:
                if budgeted:
                    self.transport.record_skip(link)
                continue
            codec = ladder[int(rungs[j])] if int(rungs[j]) >= 0 else None
            wire_bits = (int(codec.wire_bits(shape))
                         if codec is not None else None)
            if budgeted:
                # spend-first, like the eager ladder walk: record_spend arms
                # _pending_rung so the booking below stamps the rung
                self.transport.record_spend(link, wire_bits, int(rungs[j]))
            self.transport.send(ScoreBlockMsg(
                endpoints[j].name, head.name, serve.blocks[j],
                wire_bits=wire_bits))
            if self.transport.privacy is not None:
                self.transport.accountant.record(endpoints[j].name)
        if budgeted:
            self.transport.exhausted = bool(self.transport.exhausted
                                            or bool(serve.exhausted))


def variant_setup(variant: str, seed: int = 0) -> tuple[Scheduler, bool]:
    """Map a legacy ``variant`` string to (scheduler, upstream flag):

      ascii  -> sequential chain, upstream side info (eqs. 11/13)
      simple -> sequential chain, own-loss alphas only
      random -> random order per round, upstream side info
      async  -> stale-read parallel rounds (beyond paper)
    """
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}; expected {VARIANTS}")
    if variant == "random":
        return RandomScheduler(seed), True
    if variant == "async":
        return AsyncStaleScheduler(), True
    return SequentialScheduler(), variant != "simple"


def endpoints_for(learners: Sequence[Learner],
                  Xs: Sequence[jnp.ndarray]) -> list[AgentEndpoint]:
    """Build the endpoint list for aligned (learner, feature-block) pairs."""
    assert len(learners) == len(Xs)
    return [AgentEndpoint(m, lr, X) for m, (lr, X) in
            enumerate(zip(learners, Xs))]
