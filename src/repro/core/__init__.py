# The paper's primary contribution — the ASCII interchange protocol — lives
# here.  `engine` is the agent-session engine (endpoints, schedulers,
# transports, SessionState); `compiled` lowers whole sessions into single
# lax.scan programs (and vmapped session fleets); `protocol` is the
# back-compat front door; `scores`/`encoding` the math; `collectives` the
# mesh-native ring; `transport` the byte ledger.
from repro.core.compiled import (SessionPlan, SessionResult, compiled_session,
                                 fitted_from_result, fleet_run,
                                 make_session_fn, plan_for)
from repro.core.engine import (AgentEndpoint, AsyncStaleScheduler, Component,
                               FittedASCII, IgnoranceMsg, InProcessTransport,
                               MeshRingTransport, MeteredTransport,
                               ModelWeightMsg, Protocol, RandomScheduler,
                               Scheduler, ScoreBlockMsg, SequentialScheduler,
                               Session, SessionConfig, SessionState, Transport,
                               endpoints_for, holdout_split, variant_setup)

__all__ = ["AgentEndpoint", "AsyncStaleScheduler", "Component", "FittedASCII",
           "IgnoranceMsg", "InProcessTransport", "MeshRingTransport",
           "MeteredTransport", "ModelWeightMsg", "Protocol", "RandomScheduler",
           "Scheduler", "ScoreBlockMsg", "SequentialScheduler", "Session",
           "SessionConfig", "SessionPlan", "SessionResult", "SessionState",
           "Transport", "compiled_session", "endpoints_for",
           "fitted_from_result", "fleet_run", "holdout_split",
           "make_session_fn", "plan_for", "variant_setup"]
