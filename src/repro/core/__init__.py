# The paper's primary contribution — the ASCII interchange protocol — lives
# here.  `engine` is the agent-session engine (endpoints, schedulers,
# transports, SessionState); `protocol` is the back-compat front door;
# `scores`/`encoding` the math; `collectives` the mesh-native ring;
# `transport` the byte ledger.
from repro.core.engine import (AgentEndpoint, AsyncStaleScheduler, Component,
                               FittedASCII, IgnoranceMsg, InProcessTransport,
                               MeshRingTransport, MeteredTransport,
                               ModelWeightMsg, Protocol, RandomScheduler,
                               Scheduler, ScoreBlockMsg, SequentialScheduler,
                               Session, SessionConfig, SessionState, Transport,
                               endpoints_for, holdout_split, variant_setup)

__all__ = ["AgentEndpoint", "AsyncStaleScheduler", "Component", "FittedASCII",
           "IgnoranceMsg", "InProcessTransport", "MeshRingTransport",
           "MeteredTransport", "ModelWeightMsg", "Protocol", "RandomScheduler",
           "Scheduler", "ScoreBlockMsg", "SequentialScheduler", "Session",
           "SessionConfig", "SessionState", "Transport", "endpoints_for",
           "holdout_split", "variant_setup"]
