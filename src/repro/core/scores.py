"""Ignorance-score and model-weight updates (paper eqs. 9-13, Props. 1-2).

All functions are pure and jittable.  Shapes: rewards ``r`` and ignorance
scores ``w`` are length-n vectors; ``r_i = I{g(x_i) == y_i}`` (Prop. 1).

Derivation notes (verified in tests/test_core_scores.py):

With the eq.-(1) coding, exp(-alpha * y^T g / K) equals
``exp(-alpha/(K-1))`` on a correctly classified sample and
``exp(+alpha/(K-1)^2)`` on a misclassified one.  Minimizing the staged
exponential loss in alpha therefore gives

    alpha = (K-1)^2/K * [ log(S_correct / S_wrong) + log(K-1) ]

where S_correct/S_wrong weight each sample by its ignorance score times the
*upstream factor* u_i (the exponential loss contributed by the agents that
already acted this round — eq. 13).  The leading (K-1)^2/K constant is common
to every agent and round, so the paper drops it (remark under eq. 13); we do
the same by default and expose it via ``exact_scale`` for the tests.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

_EPS = 1e-12


class AlphaResult(NamedTuple):
    alpha: jnp.ndarray          # scalar model weight
    weighted_acc: jnp.ndarray   # scalar, the r-bar of eq. (9) (u-adjusted)


def upstream_factor_update(u: jnp.ndarray, alpha: jnp.ndarray, r: jnp.ndarray,
                           num_classes: int) -> jnp.ndarray:
    """Multiply the within-round upstream factor u_i by this agent's term.

    u_i *= exp(-alpha y_i^T g(x_i) / K)
        =  exp(-alpha/(K-1))      if r_i = 1
           exp(+alpha/(K-1)^2)    if r_i = 0
    """
    k = num_classes
    term = jnp.where(r > 0, jnp.exp(-alpha / (k - 1)), jnp.exp(alpha / (k - 1) ** 2))
    return u * term


def model_weight(w: jnp.ndarray, r: jnp.ndarray, num_classes: int,
                 u: jnp.ndarray | None = None,
                 alpha_cap: float = 20.0,
                 exact_scale: bool = False) -> AlphaResult:
    """Generalized model weight (eq. 13); eq. (9) when ``u is None`` (head
    agent) and eq. (11) when ``u`` carries exactly one upstream agent.

    ``alpha_cap`` guards the alpha -> +inf degeneracy the paper notes when
    every sample is classified correctly.
    """
    k = num_classes
    if u is None:
        u = jnp.ones_like(w)
    s_correct = jnp.sum(w * u * r)
    s_wrong = jnp.sum(w * u * (1.0 - r))
    rbar = s_correct / jnp.maximum(s_correct + s_wrong, _EPS)
    alpha = jnp.log(jnp.maximum(s_correct, _EPS)) - jnp.log(jnp.maximum(s_wrong, _EPS)) \
        + jnp.log(float(k - 1))
    if exact_scale:
        alpha = alpha * (k - 1) ** 2 / k
    alpha = jnp.clip(alpha, -alpha_cap, alpha_cap)
    return AlphaResult(alpha=alpha, weighted_acc=rbar)


def ignorance_update(w: jnp.ndarray, r: jnp.ndarray, alpha: jnp.ndarray) -> jnp.ndarray:
    """Interchange update (eqs. 10/12): up-weight misclassified samples by
    e^alpha and renormalize to a probability vector (the 'ignorance' in
    [0, 1])."""
    w_new = w * jnp.exp(alpha * (1.0 - r))
    return w_new / jnp.maximum(jnp.sum(w_new), _EPS)


def ignorance_update_exact(w: jnp.ndarray, r: jnp.ndarray, alpha: jnp.ndarray,
                           num_classes: int) -> jnp.ndarray:
    """Beyond-paper variant: the *exact* exponential-loss reweighting
    w_i *= exp(-alpha y^T g / K) rather than the SAMME-style surrogate of
    eqs. (10)/(12).  Proportional to the surrogate up to a per-round constant
    exp(-alpha/(K-1)) times exp(alpha K /((K-1)^2) (1-r)) -- after
    normalization they differ only in the effective alpha scale."""
    k = num_classes
    mult = jnp.where(r > 0, jnp.exp(-alpha / (k - 1)), jnp.exp(alpha / (k - 1) ** 2))
    w_new = w * mult
    return w_new / jnp.maximum(jnp.sum(w_new), _EPS)


def init_ignorance(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Line 1 of Algorithm 1: w_1 = [1, ..., 1] (we keep it normalized;
    every downstream formula is invariant to the global scale of w)."""
    return jnp.full((n,), 1.0 / n, dtype=dtype)


def head_agent_alpha(w: jnp.ndarray, r: jnp.ndarray, num_classes: int,
                     alpha_cap: float = 20.0) -> AlphaResult:
    """Eq. (9): alpha^(A) = log(rbar/(1-rbar)) + log(K-1)."""
    return model_weight(w, r, num_classes, u=None, alpha_cap=alpha_cap)


def assistant_alpha(w: jnp.ndarray, r: jnp.ndarray, u: jnp.ndarray,
                    num_classes: int, alpha_cap: float = 20.0) -> AlphaResult:
    """Eq. (11)/(13): assistant's alpha given upstream factor u."""
    return model_weight(w, r, num_classes, u=u, alpha_cap=alpha_cap)
