"""Byte ledger for the metered transport.

The paper's Fig. 4 measures transmission cost in bits.  ASCII transmits per
hop: the length-n ignorance score plus one scalar model weight; once at
setup: the numeric labels and sample IDs (collation).  The oracle baseline
transmits agent B's raw feature matrix.

The transport itself now lives in the agent-session engine
(`core/engine.py`): `MeteredTransport` routes every typed message through
this ledger, so benchmarks/fig4_transmission.py reads its accounting from
`MeteredTransport.log`.  `TransportLog` stays importable here for
back-compat (`protocol.fit(..., transport=TransportLog())` still works and
is wrapped into a MeteredTransport by the engine).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TransportLog:
    entries: list = field(default_factory=list)

    def send(self, src: str, dst: str, kind: str, num_elements: int,
             bits_per_element: int = 32) -> None:
        if isinstance(num_elements, bool) or not isinstance(
                num_elements, (int, np.integer)):
            raise TypeError(f"num_elements must be an integer, got "
                            f"{type(num_elements).__name__} ({num_elements!r})")
        if num_elements < 0:
            raise ValueError(f"num_elements must be >= 0, got {num_elements}")
        self.send_bits(src, dst, kind, int(num_elements) * bits_per_element)

    def send_bits(self, src: str, dst: str, kind: str, bits: int) -> None:
        """Book an exact encoded size (codec wire formats — int8 values plus
        fp32 tile scales, top-k pairs — aren't a clean elements x width)."""
        if isinstance(bits, bool) or not isinstance(bits, (int, np.integer)):
            raise TypeError(f"bits must be an integer, got "
                            f"{type(bits).__name__} ({bits!r})")
        if bits < 0:
            raise ValueError(f"bits must be >= 0, got {bits}")
        self.entries.append({"src": src, "dst": dst, "kind": kind,
                             "bits": int(bits)})

    def send_array(self, src: str, dst: str, kind: str, arr) -> None:
        arr = np.asarray(arr)
        self.send(src, dst, kind, int(arr.size), arr.dtype.itemsize * 8)

    @property
    def total_bits(self) -> int:
        return sum(e["bits"] for e in self.entries)

    def bits_by_kind(self) -> dict:
        """Per-kind totals with deterministically (name-) ordered keys, so
        serialized benchmark JSON diffs stably across runs."""
        out: dict = {}
        for e in self.entries:
            out[e["kind"]] = out.get(e["kind"], 0) + e["bits"]
        return dict(sorted(out.items()))

    def bits_by_src(self, kinds=None) -> dict:
        """Per-sender totals (name-ordered), optionally restricted to the
        given message kinds — the budget introspection the budget-aware
        scheduler (repro.control.scheduler) orders rounds by."""
        out: dict = {}
        for e in self.entries:
            if kinds is not None and e["kind"] not in kinds:
                continue
            out[e["src"]] = out.get(e["src"], 0) + e["bits"]
        return dict(sorted(out.items()))


def oracle_bits(n: int, p_remote: int, bits_per_element: int = 32) -> int:
    """Cost of the oracle: shipping the remote agents' raw features."""
    return n * p_remote * bits_per_element


def oracle_bits_codec(n: int, p_remote: int, codec) -> int:
    """Oracle baseline under a wire codec: the remote [n, p] raw feature
    matrix shipped through the same codec the protocol uses — the fair
    comparison point for the Fig. 4 frontier (a quantized ASCII run should
    beat a *quantized* oracle, not only the raw-fp32 one)."""
    return int(codec.wire_bits((n, p_remote)))
