"""Byte ledger for the metered transport.

The paper's Fig. 4 measures transmission cost in bits.  ASCII transmits per
hop: the length-n ignorance score plus one scalar model weight; once at
setup: the numeric labels and sample IDs (collation).  The oracle baseline
transmits agent B's raw feature matrix.

The transport itself now lives in the agent-session engine
(`core/engine.py`): `MeteredTransport` routes every typed message through
this ledger, so benchmarks/fig4_transmission.py reads its accounting from
`MeteredTransport.log`.  `TransportLog` stays importable here for
back-compat (`protocol.fit(..., transport=TransportLog())` still works and
is wrapped into a MeteredTransport by the engine).

Bookkeeping is incremental with one source of truth: every booking passes
through :meth:`TransportLog.send_bits`, which appends the entry *and*
updates the (kind, src, dst) accumulator that `total_bits`,
`bits_by_kind`, `bits_by_src`, and `snapshot` all derive from — the
aggregate views can never drift from the entry list, and reads are O(#links)
instead of O(#entries).  When a telemetry ``registry`` is attached
(`repro.telemetry`), the same booking emits ``wire_bits_total{kind,src,dst}``
and ``messages_total{kind}`` — the single emission point that covers both
engine backends, since compiled runs book their replayed ledger through
this exact method.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TransportLog:
    entries: list = field(default_factory=list)
    #: optional repro.telemetry MetricsRegistry; attached by Telemetry
    registry: object = None

    def __post_init__(self):
        self._rebuild()

    def _rebuild(self) -> None:
        """(Re)derive the aggregate accumulators from ``entries`` — runs at
        construction so a log seeded with pre-existing entries stays
        consistent; afterwards ``send_bits`` maintains them incrementally."""
        self._total = 0
        self._hops = 0
        self._by: dict = {}            # (kind, src, dst) -> bits
        for e in self.entries:
            self._accumulate(e["src"], e["dst"], e["kind"], e["bits"])

    def _accumulate(self, src: str, dst: str, kind: str, bits: int) -> None:
        self._total += bits
        self._hops += 1
        key = (kind, src, dst)
        self._by[key] = self._by.get(key, 0) + bits

    def send(self, src: str, dst: str, kind: str, num_elements: int,
             bits_per_element: int = 32) -> None:
        if isinstance(num_elements, bool) or not isinstance(
                num_elements, (int, np.integer)):
            raise TypeError(f"num_elements must be an integer, got "
                            f"{type(num_elements).__name__} ({num_elements!r})")
        if num_elements < 0:
            raise ValueError(f"num_elements must be >= 0, got {num_elements}")
        self.send_bits(src, dst, kind, int(num_elements) * bits_per_element)

    def send_bits(self, src: str, dst: str, kind: str, bits: int,
                  rung: int | None = None) -> None:
        """Book an exact encoded size (codec wire formats — int8 values plus
        fp32 tile scales, top-k pairs — aren't a clean elements x width).

        ``rung`` records which codec-ladder rung priced this payload (budget
        walks only); it rides the entry so a registry attached *after*
        traffic can still backfill ``hops_by_rung_total`` — unbudgeted
        entries carry no rung key and stay byte-identical to before."""
        if isinstance(bits, bool) or not isinstance(bits, (int, np.integer)):
            raise TypeError(f"bits must be an integer, got "
                            f"{type(bits).__name__} ({bits!r})")
        if bits < 0:
            raise ValueError(f"bits must be >= 0, got {bits}")
        bits = int(bits)
        entry = {"src": src, "dst": dst, "kind": kind, "bits": bits}
        if rung is not None:
            entry["rung"] = int(rung)
        self.entries.append(entry)
        self._accumulate(src, dst, kind, bits)
        if self.registry is not None:
            self.registry.inc("wire_bits_total", bits,
                              kind=kind, src=src, dst=dst)
            self.registry.inc("messages_total", 1, kind=kind)

    def send_array(self, src: str, dst: str, kind: str, arr) -> None:
        arr = np.asarray(arr)
        self.send(src, dst, kind, int(arr.size), arr.dtype.itemsize * 8)

    @property
    def total_bits(self) -> int:
        return self._total

    @property
    def hops(self) -> int:
        """Number of booked messages."""
        return self._hops

    def bits_by_kind(self) -> dict:
        """Per-kind totals with deterministically (name-) ordered keys, so
        serialized benchmark JSON diffs stably across runs."""
        out: dict = {}
        for (kind, _src, _dst), bits in self._by.items():
            out[kind] = out.get(kind, 0) + bits
        return dict(sorted(out.items()))

    def bits_by_src(self, kinds=None) -> dict:
        """Per-sender totals (name-ordered), optionally restricted to the
        given message kinds — the budget introspection the budget-aware
        scheduler (repro.control.scheduler) orders rounds by."""
        out: dict = {}
        for (kind, src, _dst), bits in self._by.items():
            if kinds is not None and kind not in kinds:
                continue
            out[src] = out.get(src, 0) + bits
        return dict(sorted(out.items()))

    def snapshot(self) -> dict:
        """Cheap aggregate view — the registry bridge's backfill source:
        total bits, hop count, and bits by kind x directed link, all from
        the same accumulator the per-kind/per-src views read."""
        return {
            "total_bits": self._total,
            "hops": self._hops,
            "by_kind_link": {k: v for k, v in sorted(self._by.items())},
        }


def oracle_bits(n: int, p_remote: int, bits_per_element: int = 32) -> int:
    """Cost of the oracle: shipping the remote agents' raw features."""
    return n * p_remote * bits_per_element


def oracle_bits_codec(n: int, p_remote: int, codec) -> int:
    """Oracle baseline under a wire codec: the remote [n, p] raw feature
    matrix shipped through the same codec the protocol uses — the fair
    comparison point for the Fig. 4 frontier (a quantized ASCII run should
    beat a *quantized* oracle, not only the raw-fp32 one)."""
    return int(codec.wire_bits((n, p_remote)))
