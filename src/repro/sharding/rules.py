"""Sharding rules: parameter-path patterns -> PartitionSpec, plus
shape-aware batch/cache specs.

Strategy (DESIGN.md §5):
  * params: Megatron-style tensor parallelism on the ``model`` axis (heads,
    d_ff, vocab); MoE expert banks sharded expert-dim over ``data`` and
    ff-dim over ``model`` (FSDP-like, brings qwen3-moe's 454 GB expert bank
    to ~1.8 GB/chip); SSM streams sharded on d_inner/heads.
  * batch: data parallel over ("pod", "data").
  * every rule is divisibility-guarded: a dimension that does not divide by
    the axis size falls back to replication instead of mis-lowering.  Tiny
    backbones (d_model < 1024: whisper-tiny, mamba2-130m) skip TP entirely —
    sharding a 384-wide projection 16 ways buys nothing and forces padding.

The rule table is keyed on parameter *names* (leaf path suffixes), so it
covers every model family without the model code knowing about meshes.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape

PyTree = Any


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _maybe(mesh: Mesh, axes, dim: int):
    """axes if dim divides evenly, else None (replicate)."""
    return axes if dim % _axsize(mesh, axes) == 0 else None


def use_tp(cfg: ArchConfig) -> bool:
    return cfg.d_model >= 1024


# Rule table: (regex on 'a/b/c' path, fn(cfg, mesh, shape) -> trailing spec).
# The spec is right-aligned: leading (scan/stack) dims are replicated.
def _rules(cfg: ArchConfig, mesh: Mesh):
    tp = "model" if use_tp(cfg) else None

    def last_dim(path, shape):       # shard the output features
        return (None,) * (len(shape) - 1) + (_maybe(mesh, tp, shape[-1]),)

    def attn_q(path, shape):         # shard on whole q-head boundaries
        ax = tp if cfg.num_heads % _axsize(mesh, tp) == 0 else None
        return (None,) * (len(shape) - 1) + (_maybe(mesh, ax, shape[-1]),)

    def attn_kv(path, shape):        # kv heads < tp: replicate (GQA-TP rule)
        ax = tp if cfg.num_kv_heads % _axsize(mesh, tp) == 0 else None
        return (None,) * (len(shape) - 1) + (_maybe(mesh, ax, shape[-1]),)

    def attn_o(path, shape):         # wo input dim follows the q sharding
        ax = tp if cfg.num_heads % _axsize(mesh, tp) == 0 else None
        if cfg.attention == "mla" and cfg.mla_rank_shard:
            # MLA: the wo input (H*dv) is a pure contraction dim — sharding
            # it never crosses a *data* head boundary (partial sums +
            # all-reduce), so head count need not divide the axis.
            ax = tp
        return (None,) * (len(shape) - 2) + (_maybe(mesh, ax, shape[-2]), None)

    def mla_b(path, shape):
        # [r_lora, H*dims]: prefer whole-head output sharding; when the head
        # count does not divide the axis (minicpm3: 40 heads, 16-way model)
        # and mla_rank_shard is set, shard the *input rank* instead —
        # weights/optimizer state shard 16x at the cost of one all-reduce
        # per projection (capacity-for-bandwidth; see EXPERIMENTS §Perf).
        if cfg.num_heads % _axsize(mesh, tp) == 0:
            return (None,) * (len(shape) - 1) + (_maybe(mesh, tp, shape[-1]),)
        if cfg.mla_rank_shard:
            return (None,) * (len(shape) - 2) + (_maybe(mesh, tp, shape[-2]),
                                                 None)
        return (None,) * len(shape)

    def first_of_two(path, shape):   # shard the input features (2nd-last)
        return (None,) * (len(shape) - 2) + (_maybe(mesh, tp, shape[-2]), None)

    def expert_bank(path, shape):    # [E, d, f] or [E, f, d]
        e_want = cfg.moe_expert_axis if cfg.moe_expert_axis in mesh.axis_names \
            else None
        f_want = cfg.moe_ff_axis if cfg.moe_ff_axis in mesh.axis_names else None
        e_ax = _maybe(mesh, e_want, shape[-3])
        f_dim = shape[-2] if path.endswith("wo") else shape[-1]
        f_ax = _maybe(mesh, f_want, f_dim)
        if f_ax == e_ax:
            f_ax = None                  # never reuse a mesh axis in one spec
        if path.endswith("wo"):
            return (None,) * (len(shape) - 3) + (e_ax, f_ax, None)
        return (None,) * (len(shape) - 3) + (e_ax, None, f_ax)

    def vocab_first(path, shape):    # embedding [V, d]
        return (None,) * (len(shape) - 2) + (_maybe(mesh, tp, shape[-2]), None)

    def replicate(path, shape):
        return (None,) * len(shape)

    return [
        (r"embed/embedding$", vocab_first),
        (r"lm_head/unembedding$", last_dim),
        (r"(attn|self_attn|cross_attn)/wq$", attn_q),
        (r"(attn|self_attn|cross_attn)/(wk|wv)$", attn_kv),
        (r"(attn|self_attn|cross_attn)/wo$", attn_o),
        (r"attn/(wq_b|wk_b|wv_b)$", mla_b),    # MLA latent projections
        (r"attn/(wq_a|wkv_a)$", replicate),
        (r"mlp/wi_(gate|up)$", last_dim),
        (r"mlp/wo$", first_of_two),
        (r"moe/router$", replicate),
        (r"moe/(wi_gate|wi_up|wo)$", expert_bank),
        (r"ssm/in_(z|x)$", last_dim),
        (r"ssm/in_dt$", last_dim),
        (r"ssm/in_(B|C)$", replicate),
        (r"ssm/conv_x(_bias)?$", last_dim),
        (r"ssm/(conv_[BC](_bias)?|A_log|D|dt_bias)$", replicate),
        (r"ssm/out_proj$", first_of_two),
        (r"ssm/norm/scale$", last_dim),
        (r".*", replicate),           # norms, biases, heads, projections
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "name",
                                                   getattr(p, "idx", p)))))
    return "/".join(parts)


def param_specs(params_shape: PyTree, cfg: ArchConfig, mesh: Mesh) -> PyTree:
    """PartitionSpec pytree for a parameter (or optimizer-state) tree."""
    rules = _rules(cfg, mesh)

    def spec_for(path, leaf):
        ps = _path_str(path)
        for pat, fn in rules:
            if re.search(pat, ps):
                return P(*fn(ps, leaf.shape))
        raise AssertionError(ps)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    return jax.tree_util.tree_unflatten(treedef,
                                        [spec_for(p, l) for p, l in flat])


# ------------------------------------------------------------- activations
def batch_spec(cfg: ArchConfig, shape: InputShape, mesh: Mesh) -> PyTree:
    """Specs for the input batch dict (shape-aware)."""
    dp = data_axes(mesh)
    b_ax = _maybe(mesh, dp, shape.global_batch)
    specs = {"tokens": P(b_ax, None)}
    if shape.kind == "train":
        specs["sample_weight"] = P(b_ax)
    if cfg.frontend == "vision":
        specs["patch_emb"] = P(b_ax, None, None)
    if cfg.frontend == "audio":
        specs["frames"] = P(b_ax, None, None)
    return specs


def cache_specs(cfg: ArchConfig, mesh: Mesh, batch: int,
                s_cache: int) -> PyTree:
    """Specs for the decode cache pytree (scanned [U, B, S, ...] layout).

    KV heads shard over ``model`` when divisible; otherwise the cache
    *length* shards over ``model`` (long_500k batch=1 also pushes the
    length onto the data axes)."""
    from repro.models.attention import KVCache, QuantKVCache
    from repro.models.ssm import SSMState

    dp = data_axes(mesh)
    tp = "model" if use_tp(cfg) else None
    b_ax = _maybe(mesh, dp, batch)
    if batch == 1:
        # batch unshardable: spread the cache length over every axis that
        # divides it (data + model)
        cand = dp + ((tp,) if tp else ())
        seq_long = tuple(a for a in cand if s_cache % mesh.shape[a] == 0)
        seq_long = seq_long or None

    def kv_spec(leaf_ndim: int, kv_heads: int):
        # [U, B, S, KV, D] (gqa) or [U, B, S, R] (mla latents)
        if batch == 1:
            seq_ax = seq_long
        elif leaf_ndim == 5 and tp and _maybe(mesh, tp, kv_heads):
            return P(None, b_ax, None, tp, None)   # heads shard cleanly
        else:
            seq_ax = _maybe(mesh, tp, s_cache)     # fall back: shard length
        if leaf_ndim == 5:
            return P(None, b_ax, seq_ax, None, None)
        return P(None, b_ax, seq_ax, None)

    def walk(node, key=None):
        if isinstance(node, QuantKVCache):
            base = kv_spec(5, cfg.num_kv_heads)
            scale = P(*base[:-1])          # scales drop the head_dim axis
            return QuantKVCache(base, base, scale, scale)
        if isinstance(node, KVCache):
            if key == "cross":       # encoder memory: short, replicate S
                return KVCache(P(None, b_ax, None, None, None),
                               P(None, b_ax, None, None, None))
            if cfg.attention == "mla":
                return KVCache(kv_spec(4, 0), kv_spec(4, 0))
            return KVCache(kv_spec(5, cfg.num_kv_heads),
                           kv_spec(5, cfg.num_kv_heads))
        if isinstance(node, SSMState):
            h_ax = _maybe(mesh, tp, cfg.ssm_heads)
            di_ax = _maybe(mesh, tp, cfg.d_inner)
            return SSMState(conv_x=P(None, b_ax, None, di_ax),
                            conv_B=P(None, b_ax, None, None),
                            conv_C=P(None, b_ax, None, None),
                            ssm=P(None, b_ax, h_ax, None, None))
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        raise TypeError(type(node))

    return walk


def cache_spec_tree(caches_shape: PyTree, cfg: ArchConfig, mesh: Mesh,
                    batch: int, s_cache: int) -> PyTree:
    return cache_specs(cfg, mesh, batch, s_cache)(caches_shape)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
