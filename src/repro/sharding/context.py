"""Trace-time mesh context: lets model-internal shard_map blocks (the
ep_a2a MoE) see the mesh the launcher is lowering under, without threading
a Mesh handle through every model signature.  Also home of the shard_map
version shim used by every shard_map call site."""
from __future__ import annotations

import contextlib

import jax

_CURRENT_MESH = None


def shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map if present (newer JAX), else the experimental home —
    same semantics; replication checking disabled either way (the kwarg is
    `check_vma` on new JAX, `check_rep` on the versions before — including a
    window where jax.shard_map exists but only takes `check_rep`)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


@contextlib.contextmanager
def mesh_context(mesh):
    global _CURRENT_MESH
    prev = _CURRENT_MESH
    _CURRENT_MESH = mesh
    try:
        yield mesh
    finally:
        _CURRENT_MESH = prev


def current_mesh():
    return _CURRENT_MESH
