"""Trace-time mesh context: lets model-internal shard_map blocks (the
ep_a2a MoE) see the mesh the launcher is lowering under, without threading
a Mesh handle through every model signature."""
from __future__ import annotations

import contextlib

_CURRENT_MESH = None


@contextlib.contextmanager
def mesh_context(mesh):
    global _CURRENT_MESH
    prev = _CURRENT_MESH
    _CURRENT_MESH = mesh
    try:
        yield mesh
    finally:
        _CURRENT_MESH = prev


def current_mesh():
    return _CURRENT_MESH
