"""Expert-parallel MoE with explicit all-to-all (moe_impl='ep_a2a').

The §Perf fix for the collective-bound MoE baselines: under plain pjit the
data-sharded expert banks force XLA to all-gather either every token or
every expert bank per layer (O(T·d) or O(E·d·f) wire bytes).  The
communication-optimal schedule is the classic two-hop all_to_all:

  1. each data shard routes its T_loc·k (token, expert) picks to the shard
     owning that expert — fixed-capacity buffers [D, C, d], one all_to_all;
  2. the owner runs the grouped matmul (ragged_dot) over its E_loc experts
     with the ff dim sharded over ``model`` (psum over model combines ff
     partials);
  3. a second all_to_all returns results; the source applies gate probs and
     scatter-adds into the token order.

Wire bytes per device per layer ~ 2·T_loc·k·d·bytes — independent of E —
vs. the baseline's O(T·d) gather.  Tokens beyond capacity C =
ceil(T_loc·k/D·capacity_factor) are dropped (standard Switch semantics);
the router aux loss keeps loads balanced so drops are rare.

Everything is differentiable (all_to_all/psum/gather transpose cleanly),
so the same code serves train and serve paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.moe import router_topk
from repro.sharding.context import current_mesh, shard_map


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_apply_ep_a2a(params, x: jnp.ndarray, cfg: ArchConfig):
    """x [B, S, d] (batch sharded over the data axes) -> (y, aux)."""
    mesh = current_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        from repro.models import moe as moe_lib          # single-host fallback
        return moe_lib.moe_apply(params, x, cfg, impl="gmm")

    data_ax = "data"
    model_ax = "model" if "model" in mesh.axis_names else None
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    D = mesh.shape[data_ax]
    E, k = cfg.num_experts, cfg.top_k
    assert E % D == 0, (E, D)
    e_loc = E // D
    b, s, d = x.shape
    b_loc = b // int(np.prod([mesh.shape[a] for a in dp]))
    t_loc = b_loc * s
    cap = _round_up(int(t_loc * k / D * cfg.capacity_factor) + 1, 128)

    ff_ax = model_ax if (model_ax and cfg.moe_d_ff % mesh.shape[model_ax] == 0
                         ) else None
    w_spec = P(data_ax, None, ff_ax)
    wo_spec = P(data_ax, ff_ax, None)

    def inner(x_loc, router_w, wg, wu, wo):
        tl = x_loc.reshape(-1, d)                         # [T_loc, d]
        probs, idx, aux = router_topk({"router": router_w}, tl, cfg)
        flat_e = idx.reshape(-1)                          # [T_loc*k]
        p_flat = probs.reshape(-1)
        dest = flat_e // e_loc
        order = jnp.argsort(dest)                         # stable
        dest_s = dest[order]
        counts = jnp.bincount(dest, length=D)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(dest.shape[0]) - starts[dest_s]
        keep = rank < cap
        slot = jnp.where(keep, dest_s * cap + rank, D * cap)  # overflow slot
        tok_s = order // k

        def scatter(vals, fill=0.0):
            buf = jnp.full((D * cap + 1,) + vals.shape[1:], fill, vals.dtype)
            return buf.at[slot].set(vals)[:-1]

        send_x = scatter(tl[tok_s])
        send_e = scatter((flat_e[order] % e_loc).astype(jnp.int32), e_loc)
        # ---- hop 1: tokens to their expert's shard
        recv_x = jax.lax.all_to_all(send_x.reshape(D, cap, d), data_ax,
                                    0, 0, tiled=True).reshape(D * cap, d)
        recv_e = jax.lax.all_to_all(send_e.reshape(D, cap), data_ax,
                                    0, 0, tiled=True).reshape(D * cap)
        # invalid/padded entries: route to expert 0 with zeroed input
        valid = recv_e < e_loc
        re0 = jnp.where(valid, recv_e, 0)
        rx = jnp.where(valid[:, None], recv_x, 0.0)
        order2 = jnp.argsort(re0)
        gs = jnp.bincount(re0, length=e_loc).astype(jnp.int32)
        rs = rx[order2]
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        h = act(jax.lax.ragged_dot(rs, wg, gs)) * jax.lax.ragged_dot(rs, wu, gs)
        y = jax.lax.ragged_dot(h, wo, gs)                 # [D*cap, d]
        y = jnp.zeros_like(y).at[order2].set(y)
        if ff_ax is not None:
            y = jax.lax.psum(y, model_ax)                 # combine ff shards
        # ---- hop 2: results back to their source shard
        back = jax.lax.all_to_all(y.reshape(D, cap, d), data_ax,
                                  0, 0, tiled=True).reshape(D * cap, d)
        gathered = back[jnp.where(keep, slot, 0)]
        vals = gathered * (p_flat[order] * keep)[:, None].astype(gathered.dtype)
        out = jnp.zeros((t_loc, d), gathered.dtype).at[tok_s].add(vals)
        aux = jax.lax.pmean(aux, data_ax)
        return out.reshape(b_loc, s, d).astype(x_loc.dtype), aux

    mapped = shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), w_spec, w_spec, wo_spec),
        out_specs=(P(dp, None, None), P()))
    return mapped(x, params["router"], params["wi_gate"], params["wi_up"],
                  params["wo"])
