"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, qk-norm GQA.
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                # per-expert hidden (the assigned d_ff)
    vocab_size=151936,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
    act="silu",
    tie_embeddings=False,
)
