"""gemma-7b [dense] — GeGLU, head_dim=256, 16 kv heads (MQA is on the 2b).
[arXiv:2403.08295]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    arch_type="dense",
    source="arXiv:2403.08295",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    act="gelu",               # GeGLU
    embed_scale=True,
    tie_embeddings=True,
)
