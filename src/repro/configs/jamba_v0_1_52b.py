"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2
every other layer. [arXiv:2403.19887]"""
from repro.configs.base import ArchConfig

# One Jamba block = 8 layers, attention at index 4 (1:7 ratio), MoE replaces
# the MLP on every other layer (odd indices).  32 layers = 4 scanned blocks.
_PATTERN = ("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm")

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,              # MoE on every other layer
    layer_pattern=_PATTERN,
    ssm_state=16,             # Jamba uses Mamba-1 d_state=16
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
    act="silu",
)
