"""qwen3-0.6b [dense] — qk-norm, GQA. [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    act="silu",
)
