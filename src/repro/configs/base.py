"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen ArchConfig in its own module under
``repro/configs``; ``registry.py`` maps ``--arch <id>`` to it.  ``reduced()``
derives the CPU smoke-test variant (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""                    # paper / model-card citation

    # attention flavour
    attention: str = "gqa"              # gqa | mla | none
    qk_norm: bool = False
    window: int | None = None           # sliding-window size (SWA)
    rope_theta: float = 10_000.0
    logit_softcap: float | None = None

    # MLA (MiniCPM3 / DeepSeek-style multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # feed-forward
    act: str = "silu"                   # silu (SwiGLU) | gelu (GeGLU)

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                   # per-expert hidden dim
    moe_every: int = 1                  # MoE block every k-th layer
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid interleave (Jamba): layer-kind pattern unit, scanned repeats
    layer_pattern: tuple[str, ...] = ()  # e.g. ("ssm","ssm","ssm","attn",...)

    # encoder-decoder (Whisper backbone)
    encoder_layers: int = 0
    encoder_seq: int = 0                # frame positions from the frontend stub
    cross_attention: bool = False

    # modality frontend stub
    frontend: str | None = None         # audio | vision
    num_frontend_tokens: int = 0        # tokens the stub prepends (vision)

    # embeddings / misc
    tie_embeddings: bool = True
    embed_scale: bool = False           # gemma-style sqrt(d) embedding scale
    norm_eps: float = 1e-6
    max_position: int = 1_048_576

    # numerics / perf knobs (§Perf levers)
    dtype: str = "bfloat16"
    remat: str = "none"                 # none | block
    scan_layers: bool = True            # False: unrolled (cost extraction)
    attn_impl: str = "einsum"           # einsum | chunked (online-softmax)
    attn_chunk: int = 2048              # query-chunk for attn_impl=chunked
    moe_impl: str = "gmm"               # dense | gmm | ep_a2a
    moe_expert_axis: str = "data"       # mesh axis sharding the expert dim
    moe_ff_axis: str = "model"          # mesh axis sharding expert d_ff
    microbatches: int = 1               # grad-accumulation splits (§Perf)
    kv_quant: bool = False              # int8 KV cache (GQA decode, §Perf)
    mla_rank_shard: bool = False        # shard MLA b-mats on contraction dims
                                        # (capacity-for-bandwidth trade, §Perf)
    seq_parallel: bool = False          # Megatron-SP: shard S over model in
                                        # the norm/residual regions (§Perf)
    use_flash: bool = False             # Pallas attention (TPU runtime only)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:           # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def with_overrides(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        head_dim = min(self.head_dim, 64) if self.head_dim else 0
        scale = d_model / self.d_model
        kw = dict(
            num_layers=min(self.num_layers, 2),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=max(64, min(self.d_ff, 512)),
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
        )
        if self.is_moe:
            kw.update(num_experts=min(self.num_experts, 4),
                      top_k=min(self.top_k, 2),
                      moe_d_ff=max(32, min(self.moe_d_ff, 128)))
        if self.attention == "mla":
            kw.update(q_lora_rank=min(self.q_lora_rank, 64) or 0,
                      kv_lora_rank=min(self.kv_lora_rank, 32),
                      qk_rope_head_dim=min(self.qk_rope_head_dim, 16),
                      qk_nope_head_dim=min(self.qk_nope_head_dim, 16),
                      v_head_dim=min(self.v_head_dim, 16))
        if self.ssm_state:
            kw.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32,
                      ssm_chunk=32)
        if self.layer_pattern:
            kw.update(num_layers=len(self.layer_pattern))  # one pattern unit
        if self.encoder_layers:
            kw.update(encoder_layers=min(self.encoder_layers, 2),
                      encoder_seq=min(self.encoder_seq, 64) or 64)
        if self.num_frontend_tokens:
            kw.update(num_frontend_tokens=min(self.num_frontend_tokens, 16))
        del scale
        return self.with_overrides(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                           # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
