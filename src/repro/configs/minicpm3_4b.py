"""minicpm3-4b [dense] — multi-head latent attention (MLA) with compressed
KV cache. [hf:openbmb/MiniCPM3-4B]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    arch_type="dense",
    source="hf:openbmb/MiniCPM3-4B",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,          # MLA: per-head latents, GQA kv==heads
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attention="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_head_dim=32,
    qk_nope_head_dim=64,
    v_head_dim=64,
    act="silu",
)
