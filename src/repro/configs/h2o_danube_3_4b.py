"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window
attention. [arXiv:2401.16818]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    window=4096,              # mistral-style SWA
    act="silu",
)
