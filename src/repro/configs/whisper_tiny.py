"""whisper-tiny [audio] — enc-dec transformer backbone; the mel+conv
frontend is a stub (input_specs provides frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    source="arXiv:2212.04356",
    num_layers=4,             # decoder layers
    encoder_layers=4,
    encoder_seq=1500,         # conv-downsampled mel frames (30 s @ 50 Hz)
    cross_attention=True,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    frontend="audio",
    rope_theta=10_000.0,      # backbone uses RoPE in lieu of learned pos
)
