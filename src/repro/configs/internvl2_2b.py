"""internvl2-2b [vlm] — InternLM2-1.8B language backbone; the InternViT
vision encoder + projector is a stub (input_specs provides patch
embeddings). [arXiv:2404.16821]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    arch_type="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    act="silu",
    frontend="vision",
    num_frontend_tokens=256,  # one image tile worth of patch embeddings
)
