"""--arch <id> registry for every assigned architecture (plus the paper's
own experiment configs, which are learner-level and live in repro/data)."""
from __future__ import annotations

from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape
from repro.configs.granite_moe_1b_a400m import CONFIG as granite_moe_1b_a400m
from repro.configs.whisper_tiny import CONFIG as whisper_tiny
from repro.configs.h2o_danube_3_4b import CONFIG as h2o_danube_3_4b
from repro.configs.qwen3_moe_235b_a22b import CONFIG as qwen3_moe_235b_a22b
from repro.configs.mamba2_130m import CONFIG as mamba2_130m
from repro.configs.gemma_7b import CONFIG as gemma_7b
from repro.configs.jamba_v0_1_52b import CONFIG as jamba_v0_1_52b
from repro.configs.internvl2_2b import CONFIG as internvl2_2b
from repro.configs.qwen3_0_6b import CONFIG as qwen3_0_6b
from repro.configs.minicpm3_4b import CONFIG as minicpm3_4b

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    granite_moe_1b_a400m,
    whisper_tiny,
    h2o_danube_3_4b,
    qwen3_moe_235b_a22b,
    mamba2_130m,
    gemma_7b,
    jamba_v0_1_52b,
    internvl2_2b,
    qwen3_0_6b,
    minicpm3_4b,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown --arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


# (arch, shape) pairs that are skipped, with the DESIGN.md §4 rationale.
SKIPS: dict[tuple[str, str], str] = {
    ("whisper-tiny", "long_500k"):
        "enc-dec with a 448-position decoder; no sub-quadratic variant claimed",
}


def long_context_overrides(cfg: ArchConfig) -> ArchConfig:
    """long_500k pathway: SSM/hybrid run natively; full-attention archs get
    the sliding-window variant (DESIGN.md §4)."""
    if cfg.ssm_state and not cfg.layer_pattern and cfg.attention == "none":
        return cfg                              # pure SSM: O(1)-state decode
    if cfg.window is None or cfg.window > 8192:
        cfg = cfg.with_overrides(window=4096)   # SWA carve-out
    return cfg
