"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    arch_type="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                   # attention-free, FFN folded into the SSD block
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
)
