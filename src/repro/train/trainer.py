"""Training loop driver: jitted weighted train step (the WST engine for
neural ASCII agents and the standalone LM trainer), metrics, periodic
checkpointing, optional mesh shardings."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api
from repro.optim.optimizers import Optimizer
from repro.train import checkpoint as ckpt_lib


@dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0                 # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"


@dataclass
class Trainer:
    cfg: ArchConfig
    optimizer: Optimizer
    tcfg: TrainerConfig = field(default_factory=TrainerConfig)
    in_shardings: Any = None
    mesh: Any = None

    def init(self, key):
        params = api.init_params(key, self.cfg)
        return params, self.optimizer.init(params)

    def run(self, key, data: Iterator[dict],
            params=None, opt_state=None,
            on_metrics: Callable[[int, dict], None] | None = None):
        if params is None:
            params, opt_state = self.init(key)
        step_fn = jax.jit(api.make_train_step(self.cfg, self.optimizer))
        history = []
        t0 = time.time()
        for step in range(self.tcfg.steps):
            batch = next(data)
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, wall=time.time() - t0)
                history.append(m)
                if on_metrics:
                    on_metrics(step, m)
            if self.tcfg.ckpt_every and step and step % self.tcfg.ckpt_every == 0:
                ckpt_lib.save(self.tcfg.ckpt_dir, step,
                              {"params": params, "opt": opt_state})
        return params, opt_state, history
