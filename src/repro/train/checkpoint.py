"""Checkpointing: pytree -> sharded .npz + structure manifest (orbax is not
available offline).  Handles any nested dict/NamedTuple/list of arrays via
jax.tree flattening with key paths."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: PyTree, max_keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    np.savez(path, **flat)
    with open(os.path.join(directory, "latest.json"), "w") as f:
        json.dump({"step": step, "path": path}, f)
    # retention
    ckpts = sorted(p for p in os.listdir(directory) if p.startswith("ckpt_"))
    for old in ckpts[:-max_keep]:
        os.remove(os.path.join(directory, old))
    return path


def _encode_structure(tree: PyTree, arrays: dict[str, np.ndarray]) -> Any:
    """Recursively encode a nested dict/list/tuple tree into a JSON-able
    structure spec; array leaves are swapped for npz keys, Python scalars
    inline.  The inverse of _decode_structure — no template needed."""
    if isinstance(tree, dict):
        if not all(isinstance(k, (str, int)) for k in tree):
            raise TypeError(f"save_structured: dict keys must be str/int, "
                            f"got {sorted(map(type, tree), key=repr)}")
        return {"t": "d", "k": list(tree.keys()),
                "c": [_encode_structure(v, arrays) for v in tree.values()]}
    if isinstance(tree, tuple):
        if hasattr(tree, "_fields"):
            raise TypeError(f"save_structured: namedtuple nodes "
                            f"({type(tree).__name__}) would be restored as "
                            f"plain tuples; convert to dict first")
        return {"t": "t", "c": [_encode_structure(v, arrays) for v in tree]}
    if isinstance(tree, list):
        return {"t": "l", "c": [_encode_structure(v, arrays) for v in tree]}
    if tree is None or isinstance(tree, (bool, int, float, str)):
        return {"t": "p", "v": tree}
    key = f"arr_{len(arrays)}"
    arrays[key] = np.asarray(tree)
    return {"t": "a", "key": key}


def _decode_structure(spec: Any, arrays) -> PyTree:
    if spec["t"] == "d":
        return {k: _decode_structure(c, arrays)
                for k, c in zip(spec["k"], spec["c"])}
    if spec["t"] == "t":
        return tuple(_decode_structure(c, arrays) for c in spec["c"])
    if spec["t"] == "l":
        return [_decode_structure(c, arrays) for c in spec["c"]]
    if spec["t"] == "p":
        return spec["v"]
    return jax.numpy.asarray(arrays[spec["key"]])


def save_structured(directory: str, step: int, tree: PyTree,
                    meta: Any = None, max_keep: int = 3) -> str:
    """Template-free checkpoint of a nested dict/list/tuple tree of arrays
    and Python scalars: arrays go to .npz, the container structure (plus
    optional JSON-able ``meta``) to a sidecar manifest.  Used for protocol
    SessionState, whose component list grows over rounds and so has no
    fixed-shape template."""
    os.makedirs(directory, exist_ok=True)
    arrays: dict[str, np.ndarray] = {}
    spec = _encode_structure(tree, arrays)
    path = os.path.join(directory, f"state_{step:08d}.npz")
    np.savez(path, **arrays)
    with open(os.path.join(directory, f"state_{step:08d}.json"), "w") as f:
        json.dump({"structure": spec, "meta": meta, "step": step}, f)
    with open(os.path.join(directory, "latest_state.json"), "w") as f:
        json.dump({"step": step, "path": path}, f)
    # retention, mirroring save(): keep the newest max_keep state pairs
    states = sorted(p for p in os.listdir(directory)
                    if p.startswith("state_") and p.endswith(".npz"))
    for old in states[:-max_keep]:
        os.remove(os.path.join(directory, old))
        sidecar = old[:-len(".npz")] + ".json"
        if os.path.exists(os.path.join(directory, sidecar)):
            os.remove(os.path.join(directory, sidecar))
    return path


def exists_structured(directory: str) -> bool:
    """Whether ``directory`` holds a restorable structured checkpoint —
    the cold-miss vs. spilled distinction the serve-path session cache
    (:mod:`repro.serve.cache`) gates restore on."""
    return os.path.exists(os.path.join(directory, "latest_state.json"))


def restore_structured(directory: str,
                       step: int | None = None) -> tuple[PyTree, Any, int]:
    """Inverse of save_structured: returns (tree, meta, step)."""
    if step is None:
        with open(os.path.join(directory, "latest_state.json")) as f:
            step = json.load(f)["step"]
    with open(os.path.join(directory, f"state_{step:08d}.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(directory, f"state_{step:08d}.npz"))
    tree = _decode_structure(manifest["structure"], arrays)
    return tree, manifest["meta"], step


def restore(directory: str, template: PyTree, step: int | None = None) -> tuple[PyTree, int]:
    with open(os.path.join(directory, "latest.json")) as f:
        meta = json.load(f)
    if step is not None:
        meta = {"step": step,
                "path": os.path.join(directory, f"ckpt_{step:08d}.npz")}
    data = np.load(meta["path"])
    flat = _flatten(template)
    assert set(flat) == set(data.files), (
        f"checkpoint/template mismatch: {set(flat) ^ set(data.files)}")
    restored_flat = [data[k] for k in flat]
    leaves, treedef = jax.tree_util.tree_flatten(template)
    # tree_flatten_with_path and tree_flatten use the same leaf order
    restored = jax.tree_util.tree_unflatten(treedef, [
        jax.numpy.asarray(v).astype(l.dtype) for v, l in zip(restored_flat, leaves)])
    return restored, meta["step"]
