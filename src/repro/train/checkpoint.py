"""Checkpointing: pytree -> sharded .npz + structure manifest (orbax is not
available offline).  Handles any nested dict/NamedTuple/list of arrays via
jax.tree flattening with key paths."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: PyTree, max_keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    np.savez(path, **flat)
    with open(os.path.join(directory, "latest.json"), "w") as f:
        json.dump({"step": step, "path": path}, f)
    # retention
    ckpts = sorted(p for p in os.listdir(directory) if p.startswith("ckpt_"))
    for old in ckpts[:-max_keep]:
        os.remove(os.path.join(directory, old))
    return path


def restore(directory: str, template: PyTree, step: int | None = None) -> tuple[PyTree, int]:
    with open(os.path.join(directory, "latest.json")) as f:
        meta = json.load(f)
    if step is not None:
        meta = {"step": step,
                "path": os.path.join(directory, f"ckpt_{step:08d}.npz")}
    data = np.load(meta["path"])
    flat = _flatten(template)
    assert set(flat) == set(data.files), (
        f"checkpoint/template mismatch: {set(flat) ^ set(data.files)}")
    restored_flat = [data[k] for k in flat]
    leaves, treedef = jax.tree_util.tree_flatten(template)
    # tree_flatten_with_path and tree_flatten use the same leaf order
    restored = jax.tree_util.tree_unflatten(treedef, [
        jax.numpy.asarray(v).astype(l.dtype) for v, l in zip(restored_flat, leaves)])
    return restored, meta["step"]
