"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_with_warmup(peak: float, warmup_steps: int, total_steps: int,
                       floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn


def linear_decay(peak: float, warmup_steps: int, total_steps: int):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, peak * (1.0 - prog))
    return fn
