"""Minimal optimizer library (optax is not available offline).

Optimizers follow the (init, update) pure-function convention so they
compose with jit/scan and with sharded parameter pytrees.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], tuple[PyTree, PyTree]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def _tree_zeros(params: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, params)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def sgd(lr: float | Callable[[jnp.ndarray], jnp.ndarray], momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        return {"mu": _tree_zeros(params)} if momentum else {}

    def update(grads, state, params, step):
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            step_dir = jax.tree.map(lambda m, g: momentum * m + g, mu, grads) if nesterov else mu
            new_state = {"mu": mu}
        else:
            step_dir = grads
            new_state = {}
        new_params = jax.tree.map(lambda p, d: p - lr_t * d.astype(p.dtype), params, step_dir)
        return new_params, new_state

    return Optimizer(init, update)


def adamw(lr: float | Callable[[jnp.ndarray], jnp.ndarray], b1: float = 0.9,
          b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip_norm: float | None = None) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr))

    def init(params):
        return {"m": _tree_zeros(params), "v": _tree_zeros(params)}

    def update(grads, state, params, step):
        if grad_clip_norm is not None:
            grads = clip_by_global_norm(grads, grad_clip_norm)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
                         state["v"], grads)
        lr_t = lr_fn(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def leaf(p, m_, v_):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(upd.dtype)
            return (p.astype(jnp.float32) - lr_t * upd).astype(p.dtype)

        new_params = jax.tree.map(leaf, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)
