"""Continuous batching for score-block prediction traffic.

The serve fleet's hot loop: requests against live sessions accumulate in a
queue, and ``flush`` drains it as a handful of *bucketed* vmapped serve
programs instead of one XLA dispatch per request.  A bucket is the compile
key — (SessionPlan, per-agent feature-block shapes) — so every slot in a
bucket runs the exact program :func:`repro.core.compiled.serve_batch`
compiled once for that shape; buckets pad to the next power of two (capped
at ``max_batch``) by repeating a slot with an all-False ``deliver`` mask,
so the pad contributes nothing, books nothing, and bounds the number of
distinct batch shapes XLA ever sees per bucket.

The vmap axis never mixes slots, so a batched slot is bit-identical to the
same request served alone (``serve_session``) — the engine's parity pin.
One ordering rule keeps that true for *sequences* of requests: a flush
drains the queue in waves of at most one request per session, because two
budgeted requests against the same session must see each other's spent
bits, and two slots in one vmapped call cannot.
"""
from __future__ import annotations

from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any

import numpy as np

from repro.core import compiled


@dataclass
class Slot:
    """One admitted request, fully materialized for its bucket: the static
    plan, the per-request serve key, the per-agent feature blocks, and the
    admission ``deliver`` mask.  The session's *array* state is resolved at
    run time (``Batcher.resolve``), not captured here — budget counters
    advance between waves, and a capture at submit time would serve a later
    same-session request from pre-spend counters."""
    request_id: int
    session_id: str
    tenant: str
    plan: Any
    key: Any
    Xs: tuple
    deliver: Any
    decision: Any = None
    state: Any = None               # fallback when no resolver is set
    request: Any = None             # set -> key is the EVOLVED session key
    #                                 and the serve key folds in-program

    @property
    def bucket(self) -> tuple:
        return (self.plan, tuple(tuple(x.shape) for x in self.Xs))


@dataclass
class Batcher:
    """Collect :class:`Slot`\\ s, run them as bucketed vmapped programs.

    ``flush`` returns ``[(slot, ServeResult)]`` in request order; each
    ServeResult is the slot's slice of the batched output (no leading
    axis).  ``resolve`` maps a slot to its live session state (the engine
    plugs the cache in here); ``settle`` is called per wave — BEFORE the
    next wave runs — so budget bookkeeping lands between same-session
    requests exactly like sequential serving.  ``batches_run`` /
    ``slots_run`` / ``padded_slots`` meter how much batching actually
    happened (the serve bench reads them) — tallied as
    ``batch_events_total{event}`` in the telemetry registry (the engine
    shares its own; a standalone batcher keeps a private one) and read back
    through the same-named properties.  ``tracer`` (optional
    :class:`repro.telemetry.SpanTracer`) opens a ``flush_wave`` span per
    wave and a ``bucket_dispatch`` span (fenced) per vmapped program run.
    """
    max_batch: int = 8
    resolve: Any = None             # slot -> ServeSessionState
    pending: list = field(default_factory=list)
    registry: Any = None            # telemetry MetricsRegistry
    tracer: Any = None              # telemetry SpanTracer
    live: bool = False              # stage in-flight serve taps (the pad
    #                                 slots' all-False deliver masks make
    #                                 the sink drop them host-side)

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.registry is None:
            from repro.telemetry.registry import MetricsRegistry
            self.registry = MetricsRegistry()

    def _span(self, name: str, **attrs):
        return (nullcontext() if self.tracer is None
                else self.tracer.span(name, **attrs))

    @property
    def batches_run(self) -> int:
        return self.registry.value("batch_events_total", event="batch")

    @property
    def slots_run(self) -> int:
        return self.registry.value("batch_events_total", event="slot")

    @property
    def padded_slots(self) -> int:
        return self.registry.value("batch_events_total", event="pad")

    def add(self, slot: Slot) -> None:
        self.pending.append(slot)

    def __len__(self) -> int:
        return len(self.pending)

    # ------------------------------------------------------------- internals
    def _pad_to(self, b: int) -> int:
        size = 1
        while size < b:
            size *= 2
        return min(size, self.max_batch)

    def _waves(self) -> list:
        """Split the queue into waves of at most one slot per session (in
        request order), so budget counters serialize across same-session
        requests exactly like per-request serving."""
        waves, rest = [], self.pending
        while rest:
            seen, wave, deferred = set(), [], []
            for slot in rest:
                if slot.session_id in seen:
                    deferred.append(slot)
                else:
                    seen.add(slot.session_id)
                    wave.append(slot)
            waves.append(wave)
            rest = deferred
        return waves

    def _state(self, slot: Slot):
        return self.resolve(slot) if self.resolve is not None else slot.state

    def _run_chunk(self, chunk: list) -> list:
        plan = chunk[0].plan
        width = self._pad_to(len(chunk))
        pad = width - len(chunk)
        keyed = chunk[0].request is not None
        args = [{"key": s.key, "Xs": s.Xs, "params": st.params,
                 "alphas": st.alphas, "valid": st.valid,
                 "rem_session": st.rem_session, "rem_link": st.rem_link,
                 "deliver": s.deliver,
                 **({"request": s.request} if keyed else {})}
                for s, st in ((s, self._state(s)) for s in chunk)]
        if pad:
            filler = dict(args[0],
                          deliver=np.zeros_like(np.asarray(args[0]["deliver"])))
            args.extend([filler] * pad)
        with self._span("bucket_dispatch", slots=len(chunk), pad=pad):
            res = compiled.serve_batch(plan, args, live=self.live)
            if self.tracer is not None:
                # fence so the span times the computation, not the enqueue
                self.tracer.fence(res)
        self.registry.inc("batch_events_total", 1, event="batch")
        self.registry.inc("batch_events_total", len(chunk), event="slot")
        if pad:
            self.registry.inc("batch_events_total", pad, event="pad")
        # one device->host transfer per field for the WHOLE batch; per-slot
        # slices below are then free numpy views (per-slot jax indexing was
        # a measurable chunk of serve overhead)
        preds, blocks, sent, codec_idx, exhausted = (
            np.asarray(f) for f in res)
        return [(slot, compiled.ServeResult(
                    preds=preds[i], blocks=blocks[i], sent=sent[i],
                    codec_idx=codec_idx[i], exhausted=exhausted[i]))
                for i, slot in enumerate(chunk)]

    # ------------------------------------------------------------------- api
    def flush(self, settle=None) -> list:
        out = []
        waves = self._waves()
        self.pending = []
        for w, wave in enumerate(waves):
            with self._span("flush_wave", step=w, slots=len(wave)):
                buckets: dict = {}
                for slot in wave:
                    buckets.setdefault(slot.bucket, []).append(slot)
                wave_out = []
                for group in buckets.values():
                    for lo in range(0, len(group), self.max_batch):
                        wave_out.extend(
                            self._run_chunk(group[lo:lo + self.max_batch]))
                wave_out.sort(key=lambda pair: pair[0].request_id)
                if settle is not None:
                    # settle this wave before the next runs: a later
                    # same-session request must start from post-spend
                    # counters
                    for slot, res in wave_out:
                        settle(slot, res)
                out.extend(wave_out)
        out.sort(key=lambda pair: pair[0].request_id)
        return out

    def stats(self) -> dict:
        return {"batches_run": self.batches_run,
                "slots_run": self.slots_run,
                "padded_slots": self.padded_slots,
                "max_batch": self.max_batch}
