"""The serve engine: admission -> resident cache -> continuous batching.

``ServeEngine`` turns fitted protocols into *servable sessions* and fields
prediction requests against them behind one API:

    engine = ServeEngine(cache_capacity=8, max_batch=8, ...)
    engine.add_session("s0", fitted_protocol)
    rid, decision = engine.submit("tenant-a", "s0", Xs_block)
    outcomes = engine.flush()          # {rid: ServeOutcome}

``submit`` runs per-tenant admission FIRST (deny / degrade-to-head-only /
accept — no session state is touched for a denied request), then
materializes an admitted request into a batch slot: the session's array
state from the LRU cache (restored from spill if evicted), the per-request
serve key ``serve_key(evolved_session_key, request_id)``, and the
admission ``deliver`` mask.  ``flush`` drains the queue through the
bucketed vmapped serve programs (:mod:`repro.serve.batcher`) and then
books the ledgers exactly the way ``Protocol._replay_serve`` would have
for each request alone — one ``score_block`` entry per shipped block at
its encoded rung size under session-prefixed endpoint names, per-session
DP releases, budget counters advanced, and the tenant account charged the
same bits the wire ledger booked.

The defining invariant (pinned by ``tests/test_serve_engine.py``): a
request served through the batch is **bit-identical** to the same request
served alone via ``Protocol.predict_distributed(Xs, request=rid)`` —
predictions, booked wire bits, and accountant releases.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from time import perf_counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.privacy import PrivacyAccountant
from repro.serve.admission import DENY, AdmissionController, Decision
from repro.serve.batcher import Batcher, Slot
from repro.serve.cache import ServeSessionState, SessionCache
from repro.telemetry.live import installed as live_installed
from repro.telemetry.slo import SLOConfig, SLOTracker

_INT32_MAX = int(np.iinfo(np.int32).max)


@functools.lru_cache(maxsize=1)
def _zero_key():
    return jax.random.key(0)


@dataclass
class SessionMeta:
    """Static host-side half of a servable session (never spilled): the
    compiled plan, endpoint names, and the per-session serve ledgers the
    engine replays into."""
    plan: object
    names: tuple
    accountant: PrivacyAccountant = field(default_factory=PrivacyAccountant)
    skipped: list = field(default_factory=list)
    exhausted: bool = False
    served: int = 0

    @property
    def has_serve_channel(self) -> bool:
        return (self.plan.serve_ladder[0] is not None
                or self.plan.serve_controller is not None
                or self.plan.privacy is not None)


@dataclass(frozen=True)
class ServeOutcome:
    """What one request came to: the admission verdict, the head agent's
    predictions (None when denied), and what it cost."""
    request_id: int
    session_id: str
    tenant: str
    decision: Decision
    preds: object = None
    bits: int = 0
    releases: int = 0


class ServeEngine:
    """Continuous-batching serve engine over fitted ASCII protocols.

    ``telemetry`` (optional :class:`repro.telemetry.Telemetry`) makes the
    engine emit into one shared registry: the wire ledger, the admission/
    cache/batcher counters, per-session request counts, budget skips, and
    ``flush``/``flush_wave``/``bucket_dispatch`` spans.  Without it the
    engine still keeps a private registry so every counter surface reads
    from the same sink either way."""

    def __init__(self, *, cache_capacity: int = 8, max_batch: int = 8,
                 spill_dir: str | None = None,
                 admission: AdmissionController | None = None,
                 telemetry=None, slo: SLOConfig | None = None) -> None:
        from repro.telemetry.registry import MetricsRegistry
        self.telemetry = telemetry
        self.registry = (telemetry.registry if telemetry is not None
                         else MetricsRegistry())
        # the live plane: batch programs stage in-flight serve taps, and
        # flush() installs this sink around the dispatch so they land here
        self.live = (telemetry.live if telemetry is not None else None)
        self.cache = SessionCache(cache_capacity, spill_dir,
                                  registry=self.registry)
        self.batcher = Batcher(
            max_batch=max_batch,
            resolve=lambda slot: self.cache.get(slot.session_id),
            registry=self.registry,
            tracer=telemetry.tracer if telemetry is not None else None,
            live=self.live is not None)
        self.admission = (admission if admission is not None
                          else AdmissionController())
        self.slo = (SLOTracker(slo, self.registry)
                    if slo is not None else None)
        # denials book their SLO violation at the admission settle point
        self.admission.slo = self.slo
        self._submitted: dict[int, float] = {}
        # a caller-supplied controller keeps its history: fold what it
        # already counted into the shared registry, then rebind
        if self.admission.registry is not self.registry:
            for e in self.admission.registry.to_events():
                if e["type"] == "counter":
                    self.registry.inc(e["name"], e["value"], **e["labels"])
            self.admission.registry = self.registry
        self.log = None             # lazily a TransportLog
        self.sessions: dict[str, SessionMeta] = {}
        self.outcomes: dict[int, ServeOutcome] = {}
        self._next_request = 0

    # -------------------------------------------------------------- sessions
    def add_session(self, session_id: str, protocol) -> None:
        """Register a fitted compiled-backend Protocol as servable: its
        static plan goes in the host registry, its array state (params,
        alphas, valid, evolved key, remaining budget counters) into the
        LRU cache."""
        if session_id in self.sessions:
            raise ValueError(f"session {session_id!r} already registered")
        ctx = getattr(protocol, "_compiled_ctx", None)
        if ctx is None:
            raise ValueError(
                "add_session needs a *fitted* backend='compiled' Protocol "
                "(the serve engine batches traced serve programs)")
        endpoints, plan, result = ctx
        evolved = protocol._evolved_key(result)
        num = plan.num_agents
        rem_s, rem_l = _INT32_MAX, [_INT32_MAX] * num
        budget = plan.budget
        if budget is not None and hasattr(protocol.transport, "link_spent"):
            t = protocol.transport
            if budget.session_bits is not None:
                rem_s = min(budget.session_bits - t.log.total_bits
                            - t.carryover_bits, _INT32_MAX)
            if budget.link_bits is not None:
                head = endpoints[0].name
                rem_l = [min(budget.link_bits
                             - t.link_spent.get((ep.name, head), 0),
                             _INT32_MAX)
                         for ep in endpoints]
        state = ServeSessionState(
            params=result.params, alphas=result.alphas, valid=result.valid,
            key_data=jax.random.key_data(evolved),
            rem_session=jnp.asarray(rem_s, jnp.int32),
            rem_link=jnp.asarray(rem_l, jnp.int32))
        self.sessions[session_id] = SessionMeta(
            plan=plan, names=tuple(ep.name for ep in endpoints))
        self.cache.put(session_id, state)

    # ------------------------------------------------------------- admission
    def _min_full_bits(self, meta: SessionMeta, shape: tuple) -> int:
        """Cheapest-rung full-serve wire cost: the coarsest serve-ladder
        price for every non-head block (raw fp32 when the rung is None)."""
        raw = 32 * shape[0] * shape[1]
        cheapest = min((int(c.wire_bits(shape)) if c is not None else raw)
                       for c in meta.plan.serve_ladder)
        return cheapest * (len(meta.names) - 1)

    # ---------------------------------------------------------------- submit
    def submit(self, tenant: str, session_id: str, Xs,
               request: int | None = None) -> tuple[int, Decision]:
        """Gate, materialize, and enqueue one prediction request.  ``Xs``
        is the per-agent serve-time feature blocks (same layout as
        ``Protocol.predict_distributed``).  Returns (request_id, decision);
        a denied request completes immediately (its ServeOutcome carries no
        predictions), admitted ones resolve at the next :meth:`flush`."""
        meta = self.sessions[session_id]
        rid = self._next_request if request is None else int(request)
        self._next_request = max(self._next_request, rid) + 1
        Xs = tuple(x if isinstance(x, jax.Array) else jnp.asarray(x)
                   for x in Xs)
        if len(Xs) != len(meta.names):
            raise ValueError(f"session {session_id!r} has "
                             f"{len(meta.names)} agents, got {len(Xs)} "
                             f"feature blocks")
        n = int(Xs[0].shape[0])
        shape = (n, meta.plan.num_classes)
        releases = (len(meta.names) - 1
                    if meta.plan.privacy is not None else 0)
        decision = self.admission.admit(
            tenant, min_full_bits=self._min_full_bits(meta, shape),
            releases=releases)
        if decision.outcome == DENY:
            self.admission.book(tenant, decision)
            out = ServeOutcome(rid, session_id, tenant, decision)
            self.outcomes[rid] = out
            return rid, decision
        state = self.cache.get(session_id)
        num = len(meta.names)
        deliver = np.ones((num,), bool)
        if decision.outcome == "degrade":
            deliver[1:] = False                     # head-only
        if meta.has_serve_channel:
            # hand the batch program the evolved session key + request id;
            # the serve_key fold happens in-program (one dispatch per
            # flush, not two per submit)
            key, request = state.key, rid
        else:
            key, request = _zero_key(), None
        self._submitted[rid] = perf_counter()
        self.batcher.add(Slot(
            request_id=rid, session_id=session_id, tenant=tenant,
            plan=meta.plan, key=key, Xs=Xs, deliver=deliver,
            decision=decision, request=request))
        return rid, decision

    # ----------------------------------------------------------------- flush
    def _book(self, slot: Slot, res) -> ServeOutcome:
        """Settle one served slot: replay the per-request serve ledger the
        standalone path books (``Protocol._replay_serve``), under
        session-prefixed endpoint names so sessions never collide in the
        fleet-wide log, then charge the tenant the same bits."""
        from repro.core.transport import TransportLog
        if self.log is None:
            self.log = TransportLog(registry=self.registry)
        sid = slot.session_id
        meta = self.sessions[sid]
        plan, names = meta.plan, meta.names
        shape = (int(slot.Xs[0].shape[0]), plan.num_classes)
        ladder = plan.serve_ladder
        sent = np.asarray(res.sent)
        rungs = np.asarray(res.codec_idx)
        deliver = np.asarray(slot.deliver)
        budgeted = plan.budget is not None
        head = f"{sid}:{names[0]}"
        bits_total, releases = 0, 0
        link_cost = np.zeros(len(names), np.int64)
        for j in range(1, len(names)):
            if not deliver[j]:
                continue            # head-only degrade: the hop never ran
            link = (f"{sid}:{names[j]}", head)
            if not sent[j]:
                meta.skipped.append(link)       # budget skip
                self.registry.inc("budget_skips_total", 1,
                                  src=link[0], dst=link[1])
                continue
            rung = int(rungs[j])
            codec = ladder[rung] if rung >= 0 else None
            bits = (int(codec.wire_bits(shape)) if codec is not None
                    else 32 * shape[0] * shape[1])
            self.log.send_bits(link[0], link[1], "score_block", bits)
            bits_total += bits
            link_cost[j] = bits
            if budgeted and rung >= 0:
                self.registry.inc("hops_by_rung_total", 1, rung=rung)
            if plan.privacy is not None:
                meta.accountant.record(names[j])
                # session-prefixed in the fleet-wide registry, matching the
                # wire ledger's link naming (per-session epsilon stays on
                # meta.accountant)
                self.registry.inc("dp_releases_total", 1, agent=link[0])
                releases += 1
        if budgeted:
            state = self.cache.get(sid)
            state.rem_session = state.rem_session - jnp.asarray(
                min(bits_total, _INT32_MAX), jnp.int32)
            state.rem_link = state.rem_link - jnp.asarray(
                np.minimum(link_cost, _INT32_MAX), jnp.int32)
            meta.exhausted = bool(meta.exhausted or bool(res.exhausted))
        meta.served += 1
        self.registry.inc("serve_requests_total", 1, session=sid)
        self.admission.book(slot.tenant, slot.decision, bits=bits_total,
                            releases=releases)
        # the single submit -> flush-complete latency stamp: one histogram
        # observation per settled request, at settle time
        t0 = self._submitted.pop(slot.request_id, None)
        if t0 is not None:
            seconds = perf_counter() - t0
            self.registry.observe("request_seconds", seconds,
                                  tenant=slot.tenant)
            if self.slo is not None:
                self.slo.observe(slot.tenant, seconds)
        return ServeOutcome(slot.request_id, sid, slot.tenant,
                            slot.decision, preds=np.asarray(res.preds),
                            bits=bits_total, releases=releases)

    def flush(self) -> dict:
        """Drain the queue through the bucketed batch programs and settle
        every request.  Returns {request_id: ServeOutcome} for requests
        completed by this flush (denied requests completed at submit).
        Settlement happens per batching wave (before the next wave runs),
        so a later request against the same budgeted session starts from
        post-spend counters — exactly like sequential serving."""
        done = {}

        def settle(slot, res):
            out = self._book(slot, res)
            self.outcomes[out.request_id] = out
            done[out.request_id] = out

        if self.telemetry is not None:
            with self.telemetry.span("flush", queued=len(self.batcher)):
                with live_installed(self.live):
                    self.batcher.flush(settle=settle)
        else:
            self.batcher.flush(settle=settle)
        return done

    # --------------------------------------------------------------- summary
    def summary(self) -> dict:
        """Fleet-level accounting: per-tenant counters, cache and batcher
        stats, per-session serve ledgers."""
        total_bits = self.log.total_bits if self.log is not None else 0
        out = {
            "tenants": self.admission.counters(),
            "cache": self.cache.stats(),
            "batcher": self.batcher.stats(),
            "sessions": {
                sid: {"served": m.served, "skipped": len(m.skipped),
                      "exhausted": m.exhausted,
                      "releases": dict(sorted(m.accountant.releases.items()))}
                for sid, m in sorted(self.sessions.items())},
            "total_bits": total_bits,
            "requests": len(self.outcomes),
        }
        if self.slo is not None:
            out["slo"] = self.slo.report()
        return out

    def close(self) -> None:
        self.cache.close()
