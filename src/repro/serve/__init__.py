"""Serve subsystem: continuous batching, resident session cache, and
per-tenant admission control for prediction traffic.

Training produced fitted protocols; this package turns them into a
*service*.  Three layers, each independently testable:

  * :mod:`repro.serve.admission` — the per-tenant gate (byte budget +
    (ε, δ) ledger) that runs BEFORE any work, with deny /
    degrade-to-head-only / accept outcomes and per-tenant counters.
  * :mod:`repro.serve.cache`     — LRU residency over servable session
    states with bit-exact checkpoint spill/restore
    (:func:`repro.train.checkpoint.save_structured`).
  * :mod:`repro.serve.batcher`   — continuous batching: requests bucket by
    (plan, shapes) into fixed-shape slots and run as ONE vmapped compiled
    serve program per bucket (:func:`repro.core.compiled.serve_batch`).

:class:`repro.serve.engine.ServeEngine` composes them behind
``submit(tenant, session_id, X_block)`` / ``flush()``; the synthetic
workload driver lives in ``repro.launch.serve_fleet``.  The load-bearing
invariant: batched serving is bit-identical to per-request serving —
predictions, booked wire bits, accountant releases
(``tests/test_serve_engine.py``).
"""
from repro.serve.admission import (ACCEPT, DEGRADE, DENY, AdmissionController,
                                   AdmissionPolicy, Decision, TenantAccount)
from repro.serve.batcher import Batcher, Slot
from repro.serve.cache import ServeSessionState, SessionCache
from repro.serve.engine import ServeEngine, ServeOutcome, SessionMeta

__all__ = [
    "ACCEPT", "DEGRADE", "DENY", "AdmissionController", "AdmissionPolicy",
    "Batcher", "Decision", "ServeEngine", "ServeOutcome",
    "ServeSessionState", "SessionCache", "SessionMeta", "Slot",
    "TenantAccount",
]
