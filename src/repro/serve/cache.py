"""Resident-session cache: LRU over servable session states with
checkpoint spill/restore.

A serve fleet holds many fitted sessions but only ``capacity`` of them
resident (device arrays alive); the rest are spilled to disk through the
structured checkpoint writer (:func:`repro.train.checkpoint.save_structured`
— the same template-free npz + manifest format protocol SessionState uses)
and restored on next touch.  The array roundtrip is bit-exact, so a
spilled-and-restored session serves *identically* to one that stayed
resident — predictions, booked wire bits, accountant releases — which
``tests/test_serve_engine.py`` pins.

Only the per-session *array* state spills (:class:`ServeSessionState`);
static host metadata (the compiled :class:`~repro.core.compiled.SessionPlan`,
endpoint names) stays in the engine's registry — it is tiny, and plans are
frozen dataclasses that key compiled-program caches, so they must stay the
*same object* across spill cycles anyway.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from collections import OrderedDict
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.train.checkpoint import (exists_structured, restore_structured,
                                    save_structured)


@dataclass
class ServeSessionState:
    """The array half of one servable session — everything the traced serve
    step consumes, in spillable form.

    ``params``/``alphas``/``valid`` are the fitted session's stacked
    per-round trees (``SessionResult`` fields); ``key_data`` is the evolved
    session PRNG key as raw uint32 (``jax.random.key_data`` — typed key
    arrays don't survive npz, their data words do, bit for bit);
    ``rem_session``/``rem_link`` are the live remaining-budget counters
    (int32; INT32_MAX = uncapped) that advance as requests are served.
    """
    params: tuple
    alphas: jnp.ndarray
    valid: jnp.ndarray
    key_data: jnp.ndarray
    rem_session: jnp.ndarray
    rem_link: jnp.ndarray

    @property
    def key(self):
        # key_data never mutates for a live state, so wrap once (the serve
        # hot loop reads this per submit)
        if getattr(self, "_key", None) is None:
            self._key = jax.random.wrap_key_data(jnp.asarray(self.key_data))
        return self._key

    def tree(self) -> dict:
        return {"params": self.params, "alphas": self.alphas,
                "valid": self.valid, "key_data": self.key_data,
                "rem_session": self.rem_session, "rem_link": self.rem_link}

    @classmethod
    def from_tree(cls, tree: dict) -> "ServeSessionState":
        return cls(params=tree["params"], alphas=tree["alphas"],
                   valid=tree["valid"], key_data=tree["key_data"],
                   rem_session=tree["rem_session"],
                   rem_link=tree["rem_link"])


class SessionCache:
    """LRU cache of :class:`ServeSessionState` with disk spill.

    ``put`` admits (or refreshes) a session; ``get`` returns it resident,
    restoring from spill on a miss; both evict the least-recently-used
    resident session to disk when the cache runs over ``capacity``.
    ``evict`` forces a session out (the memory-pressure path the
    spill-parity test drives).  Stats: ``hits`` (resident touches),
    ``restores`` (spill round-trips back in), ``spills`` (evictions that
    wrote disk) — tallied in the telemetry registry as
    ``cache_events_total{event}`` (the engine shares its registry; a
    standalone cache keeps a private one) and read back through the
    same-named properties.
    """

    def __init__(self, capacity: int = 8,
                 spill_dir: str | None = None, registry=None) -> None:
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._own_dir = spill_dir is None
        self.spill_dir = (tempfile.mkdtemp(prefix="repro_serve_spill_")
                          if spill_dir is None else spill_dir)
        os.makedirs(self.spill_dir, exist_ok=True)
        self._resident: OrderedDict[str, ServeSessionState] = OrderedDict()
        if registry is None:
            from repro.telemetry.registry import MetricsRegistry
            registry = MetricsRegistry()
        self.registry = registry

    def _event(self, event: str) -> None:
        self.registry.inc("cache_events_total", 1, event=event)

    @property
    def hits(self) -> int:
        return self.registry.value("cache_events_total", event="hit")

    @property
    def restores(self) -> int:
        return self.registry.value("cache_events_total", event="restore")

    @property
    def spills(self) -> int:
        return self.registry.value("cache_events_total", event="spill")

    # ------------------------------------------------------------- internals
    def _dir(self, session_id: str) -> str:
        return os.path.join(self.spill_dir, str(session_id))

    def _spill_lru(self) -> None:
        while len(self._resident) > self.capacity:
            sid, state = self._resident.popitem(last=False)
            save_structured(self._dir(sid), 0, state.tree(), max_keep=1)
            self._event("spill")

    # ------------------------------------------------------------------- api
    def __contains__(self, session_id: str) -> bool:
        return (session_id in self._resident
                or exists_structured(self._dir(session_id)))

    def __len__(self) -> int:
        return len(self._resident)

    @property
    def resident_ids(self) -> tuple:
        return tuple(self._resident)

    def put(self, session_id: str, state: ServeSessionState) -> None:
        self._resident[session_id] = state
        self._resident.move_to_end(session_id)
        self._spill_lru()

    def get(self, session_id: str) -> ServeSessionState:
        if session_id in self._resident:
            self._resident.move_to_end(session_id)
            self._event("hit")
            return self._resident[session_id]
        if not exists_structured(self._dir(session_id)):
            raise KeyError(f"unknown session {session_id!r} (never put, "
                           f"or spill directory lost)")
        tree, _, _ = restore_structured(self._dir(session_id))
        state = ServeSessionState.from_tree(tree)
        self._event("restore")
        self.put(session_id, state)
        return state

    def evict(self, session_id: str) -> None:
        """Force one session out to disk (memory pressure)."""
        if session_id not in self._resident:
            return
        state = self._resident.pop(session_id)
        save_structured(self._dir(session_id), 0, state.tree(), max_keep=1)
        self._event("spill")

    def stats(self) -> dict:
        return {"capacity": self.capacity, "resident": len(self._resident),
                "hits": self.hits, "restores": self.restores,
                "spills": self.spills}

    def close(self) -> None:
        """Drop the spill directory (only if this cache created it)."""
        if self._own_dir:
            shutil.rmtree(self.spill_dir, ignore_errors=True)
