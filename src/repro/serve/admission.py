"""Per-tenant admission control for prediction traffic.

A serve fleet fields requests from many tenants against many resident
sessions; every accepted request spends two metered resources the moment it
is served — wire bits (the encoded ScoreBlockMsg traffic the transport
ledger prices) and, under a DP serve channel, one (ε, δ) release per
non-head agent.  Admission gates on BOTH ledgers *before any work is done*
(no block is computed, no session state is touched for a denied request),
with three outcomes:

  * ``ACCEPT``  — both gates pass: the request serves the full protocol
    prediction (every agent's block crosses the serve channel).
  * ``DEGRADE`` — a gate fails and the policy allows degradation: the
    request serves *head-only* (``deliver = [True, False, ...]`` on the
    traced serve step) — no block crosses the wire, so it costs zero bits
    and zero releases.  Accuracy degrades; the ledgers don't move.
  * ``DENY``    — a gate fails and the policy forbids degradation: the
    request is refused outright.

The byte gate asks whether the tenant can afford the *cheapest* full serve
(the coarsest serve-ladder rung for every non-head block): the in-channel
degrade-then-skip walk already handles everything between best and
cheapest, so admission only needs to know the request can ship at all.
Accepted requests *reserve* that cheapest cost (and their DP releases)
until ``book`` settles them with what the wire ledger actually charged —
a burst of submits inside one batch window gates against in-flight
reservations, not just booked spend.
The privacy gate asks whether recording the full serve's releases would
push the tenant past its ε cap under basic composition — the same
per-release arithmetic :class:`repro.comm.privacy.PrivacyAccountant`
reports.

Counters (``served`` / ``degraded`` / ``denied``) live in the telemetry
registry as ``admission_outcomes_total{tenant, outcome}`` — one sink shared
with the wire ledger and the cache/batcher counters — and ``counters()``
assembles the per-tenant summary the serve-fleet driver surfaces from it
(same keys as before the registry existed).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.comm.budget import TenantBudget
from repro.telemetry.registry import MetricsRegistry

ACCEPT = "accept"
DEGRADE = "degrade"
DENY = "deny"


@dataclass(frozen=True)
class AdmissionPolicy:
    """What the gate does when a tenant's ledger can't cover a request.

    ``allow_degrade`` picks between the DEGRADE and DENY outcomes;
    ``epsilon_cap`` is the per-tenant total ε budget under basic
    composition (None = no privacy gate — bits-only admission)."""
    allow_degrade: bool = True
    epsilon_cap: float | None = None

    def __post_init__(self):
        if self.epsilon_cap is not None and self.epsilon_cap <= 0:
            raise ValueError(
                f"epsilon cap must be positive, got {self.epsilon_cap}")


@dataclass(frozen=True)
class Decision:
    """One admission verdict: the outcome, why (for the fleet log), and
    what the gate *reserved* against the tenant's ledgers — an accepted
    request in a batch window holds its cheapest-rung cost until ``book``
    settles it, so a burst of submits cannot oversubscribe the cap before
    the first flush lands."""
    outcome: str
    reason: str = ""
    reserved_bits: int = 0
    reserved_releases: int = 0

    @property
    def admitted(self) -> bool:
        return self.outcome in (ACCEPT, DEGRADE)


@dataclass
class TenantAccount:
    """The gating state for one tenant: the bit ledger view, the release
    tally, and in-flight reservations.  Outcome *counts* (served/degraded/
    denied) are observability, not gating state — they live in the
    controller's telemetry registry."""
    budget: TenantBudget = field(default_factory=TenantBudget)
    released: int = 0               # DP releases charged to this tenant
    reserved_bits: int = 0          # held by admitted, not-yet-booked reqs
    pending_releases: int = 0


class AdmissionController:
    """The per-tenant gate in front of the serve engine.

    ``tenant_bits`` seeds every new tenant's :class:`TenantBudget` cap
    (None = uncapped); ``mechanism`` is the serve channel's
    :class:`~repro.comm.privacy.GaussianMechanism` (None = no privacy
    gate).  ``admit`` runs the gates and returns a :class:`Decision`;
    ``book`` settles the request afterwards with what it *actually* cost —
    the engine charges the encoded bits the transport ledger booked, so the
    tenant view and the wire ledger can never drift.
    """

    def __init__(self, policy: AdmissionPolicy | None = None, *,
                 tenant_bits: int | None = None, mechanism=None,
                 registry: MetricsRegistry | None = None) -> None:
        self.policy = policy if policy is not None else AdmissionPolicy()
        self.tenant_bits = tenant_bits
        self.mechanism = mechanism
        self.accounts: dict[str, TenantAccount] = {}
        # outcome counters live here (a private registry when the serve
        # engine doesn't share its own) — one sink for every serve counter
        self.registry = registry if registry is not None else MetricsRegistry()
        # optional repro.telemetry.slo.SLOTracker: a denial is an SLO
        # violation (the tenant never got an answer), booked at the same
        # settle point as the outcome counter (the serve engine wires this)
        self.slo = None

    def account(self, tenant: str) -> TenantAccount:
        if tenant not in self.accounts:
            self.accounts[tenant] = TenantAccount(
                budget=TenantBudget(bits=self.tenant_bits))
        return self.accounts[tenant]

    def admit(self, tenant: str, *, min_full_bits: int,
              releases: int) -> Decision:
        """Gate one request BEFORE any work: ``min_full_bits`` is the
        cheapest-rung full-serve wire cost, ``releases`` the DP releases a
        full serve would record (0 without a privacy channel)."""
        acct = self.account(tenant)
        reasons = []
        if not acct.budget.affordable(min_full_bits + acct.reserved_bits):
            reasons.append(
                f"bits: need >= {min_full_bits}, remaining "
                f"{acct.budget.remaining - acct.reserved_bits}")
        if (self.policy.epsilon_cap is not None and self.mechanism is not None
                and releases > 0):
            spent = (acct.released + acct.pending_releases
                     + releases) * self.mechanism.epsilon
            if spent > self.policy.epsilon_cap:
                reasons.append(
                    f"epsilon: {releases} releases would spend "
                    f"{spent:.3g} > cap {self.policy.epsilon_cap:.3g}")
        if not reasons:
            acct.reserved_bits += min_full_bits
            acct.pending_releases += releases
            return Decision(ACCEPT, reserved_bits=min_full_bits,
                            reserved_releases=releases)
        reason = "; ".join(reasons)
        if self.policy.allow_degrade:
            return Decision(DEGRADE, reason)
        return Decision(DENY, reason)

    def book(self, tenant: str, decision: Decision, *, bits: int = 0,
             releases: int = 0) -> None:
        """Settle one decided request: denied requests only bump the
        counter; admitted ones release their reservation and charge the
        bits actually booked on the wire ledger and the releases actually
        recorded."""
        acct = self.account(tenant)
        acct.reserved_bits -= decision.reserved_bits
        acct.pending_releases -= decision.reserved_releases
        if decision.outcome == DENY:
            self.registry.inc("admission_outcomes_total", 1, tenant=tenant,
                              outcome="denied")
            if self.slo is not None:
                self.slo.record_denial(tenant)
            return
        acct.budget.charge(int(bits))
        acct.released += int(releases)
        outcome = "degraded" if decision.outcome == DEGRADE else "served"
        self.registry.inc("admission_outcomes_total", 1, tenant=tenant,
                          outcome=outcome)

    def counters(self) -> dict:
        """{tenant: {served, degraded, denied, bits, released}} in
        deterministic tenant order — the serve-fleet summary payload,
        outcome counts read back from the telemetry registry."""
        out = {}
        for t in sorted(self.accounts):
            acct = self.accounts[t]
            out[t] = {outcome: self.registry.value(
                          "admission_outcomes_total", tenant=t,
                          outcome=outcome)
                      for outcome in ("served", "degraded", "denied")}
            out[t]["bits"] = acct.budget.spent
            out[t]["released"] = acct.released
        return out
