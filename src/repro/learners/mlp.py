"""Weighted 3-layer neural network (the paper's Fashion-MNIST learner,
Section VI-B) fitted with AdamW on the w-weighted cross-entropy.

Implemented as a pure :class:`~repro.learners.base.LearnerCore` shared by
the eager wrapper and the compiled session program.  Per the core contract,
``init`` and ``fit`` receive the same per-fit key: ``init`` uses
``split(key)[1]`` and ``fit`` uses ``split(key)[0]`` for minibatch draws —
the exact key discipline of the original monolithic fit, so eager and
compiled trajectories stay bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.learners.base import Learner, LearnerCore, jitted_fresh_fit
from repro.optim.optimizers import adamw


def _init_mlp(key, dims):
    params = []
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / d_in)
        params.append({"w": jax.random.normal(sub, (d_in, d_out)) * scale,
                       "b": jnp.zeros((d_out,))})
    return params


def _forward(params, X):
    h = X
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


def _weighted_ce(params, X, onehot, w):
    logits = _forward(params, X)
    ll = jnp.sum(onehot * logits, axis=-1) - jax.nn.logsumexp(logits, axis=-1)
    return -jnp.sum(w * ll) / jnp.maximum(jnp.sum(w), 1e-12)


@dataclass(frozen=True)
class MLPCore(LearnerCore):
    num_classes: int
    hidden: tuple[int, ...] = (128, 64)
    steps: int = 400
    lr: float = 3e-3
    batch_size: int | None = None

    def init(self, key, shapes):
        _, init_key = jax.random.split(key)
        dims = (shapes[0],) + tuple(self.hidden) + (self.num_classes,)
        return _init_mlp(init_key, dims)

    def fit(self, params, key, X, onehot, w):
        key, _ = jax.random.split(key)      # the minibatch key (init took [1])
        opt = adamw(self.lr)
        opt_state = opt.init(params)
        grad_fn = jax.grad(_weighted_ce)
        n = X.shape[0]
        bs = self.batch_size or n

        def body(i, carry):
            params, opt_state = carry
            if bs < n:
                idx = jax.random.randint(jax.random.fold_in(key, i), (bs,), 0, n)
                xb, ob, wb = X[idx], onehot[idx], w[idx]
            else:
                xb, ob, wb = X, onehot, w
            grads = grad_fn(params, xb, ob, wb)
            return opt.update(grads, opt_state, params, i)

        params, _ = jax.lax.fori_loop(0, self.steps, body, (params, opt_state))
        return params

    def logits(self, params, X):
        return _forward(params, X)


@dataclass(frozen=True)
class MLP(Learner):
    hidden: tuple[int, ...] = (128, 64)   # 3 layers total with the output
    steps: int = 400
    lr: float = 3e-3
    batch_size: int | None = None         # None => full batch

    functional = True

    def core(self, num_classes: int) -> MLPCore:
        return MLPCore(num_classes, tuple(self.hidden), self.steps, self.lr,
                       self.batch_size)

    def fit(self, key, X, classes, w, num_classes):
        core = self.core(num_classes)
        onehot = jax.nn.one_hot(classes, num_classes)
        return jitted_fresh_fit(core, X.shape[1:])(key, X, onehot, w)

    def predict(self, params, X):
        return jnp.argmax(_forward(params, X), axis=-1)
