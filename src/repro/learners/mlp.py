"""Weighted 3-layer neural network (the paper's Fashion-MNIST learner,
Section VI-B) fitted with AdamW on the w-weighted cross-entropy."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.learners.base import Learner
from repro.optim.optimizers import adamw


def _init_mlp(key, dims):
    params = []
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / d_in)
        params.append({"w": jax.random.normal(sub, (d_in, d_out)) * scale,
                       "b": jnp.zeros((d_out,))})
    return params


def _forward(params, X):
    h = X
    for layer in params[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


def _weighted_ce(params, X, onehot, w):
    logits = _forward(params, X)
    ll = jnp.sum(onehot * logits, axis=-1) - jax.nn.logsumexp(logits, axis=-1)
    return -jnp.sum(w * ll) / jnp.maximum(jnp.sum(w), 1e-12)


@dataclass(frozen=True)
class MLP(Learner):
    hidden: tuple[int, ...] = (128, 64)   # 3 layers total with the output
    steps: int = 400
    lr: float = 3e-3
    batch_size: int | None = None         # None => full batch

    def fit(self, key, X, classes, w, num_classes):
        key, init_key = jax.random.split(key)
        dims = (X.shape[-1],) + tuple(self.hidden) + (num_classes,)
        params = _init_mlp(init_key, dims)
        onehot = jax.nn.one_hot(classes, num_classes)
        opt = adamw(self.lr)
        opt_state = opt.init(params)
        grad_fn = jax.grad(_weighted_ce)
        n = X.shape[0]
        bs = self.batch_size or n

        def body(i, carry):
            params, opt_state = carry
            if bs < n:
                idx = jax.random.randint(jax.random.fold_in(key, i), (bs,), 0, n)
                xb, ob, wb = X[idx], onehot[idx], w[idx]
            else:
                xb, ob, wb = X, onehot, w
            grads = grad_fn(params, xb, ob, wb)
            return opt.update(grads, opt_state, params, i)

        params, _ = jax.lax.fori_loop(0, self.steps, body, (params, opt_state))
        return params

    def predict(self, params, X):
        return jnp.argmax(_forward(params, X), axis=-1)
