"""Weighted random forest: vmapped bootstrap of the JAX decision tree.

The paper uses random forests for the Blob experiments (Figs. 3a/4a).
Bootstrapping is expressed as a Poisson(1)-style multiplicative resampling
of the sample weights (weight-space bootstrap) so that every tree fit is a
fixed-shape jittable computation, and feature bagging as a random column
subset per tree — both vmap cleanly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.learners.base import Learner
from repro.learners.tree import fit_tree, predict_tree


@partial(jax.jit, static_argnames=("num_trees", "depth", "num_thresholds",
                                   "num_classes", "num_feats"))
def _fit_forest(key, X, classes, w, *, num_trees, depth, num_thresholds,
                num_classes, num_feats):
    n, p = X.shape

    def fit_one(key):
        boot_key, feat_key = jax.random.split(key)
        # weight-space bootstrap: Poisson(1) counts ~ bootstrap resampling
        # (jax.random.multinomial does not exist on this JAX version; the
        # Poisson limit is the standard bootstrap approximation)
        counts = jax.random.poisson(boot_key, 1.0, (n,)).astype(w.dtype)
        wb = w * counts
        cols = jax.random.permutation(feat_key, p)[:num_feats]
        params = fit_tree(X[:, cols], classes, wb, depth=depth,
                          num_thresholds=num_thresholds,
                          num_classes=num_classes)
        return params, cols

    keys = jax.random.split(key, num_trees)
    return jax.vmap(fit_one)(keys)


@partial(jax.jit, static_argnames=("depth", "num_classes"))
def _predict_forest(params, X, *, depth, num_classes):
    tree_params, cols = params

    def predict_one(tp, c):
        return predict_tree(tp, X[:, c], depth=depth)

    votes = jax.vmap(predict_one)(tree_params, cols)          # [T, n]
    hist = jnp.sum(jax.nn.one_hot(votes, num_classes), axis=0)
    return jnp.argmax(hist, axis=-1)


@dataclass(frozen=True)
class RandomForest(Learner):
    # Eager-only like DecisionTree: the bootstrap of argmin tree fits has no
    # LearnerCore; the compiled engine backend rejects it with a clear error.
    functional = False

    num_trees: int = 16
    depth: int = 4
    num_thresholds: int = 16
    feature_fraction: float = 0.7

    def fit(self, key, X, classes, w, num_classes):
        p = X.shape[-1]
        num_feats = max(1, int(round(self.feature_fraction * p)))
        params = _fit_forest(key, X, classes, w, num_trees=self.num_trees,
                             depth=self.depth,
                             num_thresholds=self.num_thresholds,
                             num_classes=num_classes, num_feats=num_feats)
        return {"params": params, "num_classes": num_classes}

    def predict(self, state, X):
        return _predict_forest(state["params"], X, depth=self.depth,
                               num_classes=state["num_classes"])
