"""Weighted decision tree in pure JAX (the paper's main learner, Figs. 3/6).

CART's sort-based inner loop is sequential and CPU-shaped; here the greedy
split search is re-expressed as a *dense argmin over a quantile threshold
grid*, level-synchronous over all nodes of a level at once — one einsum per
level, which is the MXU/TPU-friendly formulation (see DESIGN.md §2).  The
objective is the w-weighted Gini impurity, which minimizes the w-weighted
0/1 error in the sense of Prop. 1.

The tree is a fixed-depth heap: internal node i has children 2i+1/2i+2,
``feat``/``thr`` arrays of length 2^D - 1, and 2^D leaf classes.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.learners.base import Learner

_EPS = 1e-12


def _weighted_gini(hist: jnp.ndarray) -> jnp.ndarray:
    """hist[..., K] of class masses -> mass-scaled Gini  s - sum h^2/s."""
    s = jnp.sum(hist, axis=-1)
    return s - jnp.sum(jnp.square(hist), axis=-1) / jnp.maximum(s, _EPS)


@partial(jax.jit, static_argnames=("depth", "num_thresholds", "num_classes"))
def fit_tree(X: jnp.ndarray, classes: jnp.ndarray, w: jnp.ndarray,
             *, depth: int, num_thresholds: int, num_classes: int):
    n, p = X.shape
    q = num_thresholds
    # Candidate thresholds: per-feature quantile grid (interior quantiles so a
    # split is never trivially empty on a spread-out feature).
    qs = (jnp.arange(q) + 0.5) / q
    thr_cand = jnp.quantile(X, qs, axis=0).T                      # [p, q]
    class_oh = jax.nn.one_hot(classes, num_classes)               # [n, K]
    left_mask = (X[:, :, None] <= thr_cand[None, :, :])           # [n, p, q]

    feat = jnp.zeros((2 ** depth - 1,), jnp.int32)
    thr = jnp.zeros((2 ** depth - 1,), jnp.float32)
    node_of = jnp.zeros((n,), jnp.int32)     # node index within current level

    for level in range(depth):
        width = 2 ** level
        node_oh = jax.nn.one_hot(node_of, width)                  # [n, m]
        hist_tot = jnp.einsum("i,im,ik->mk", w, node_oh, class_oh)
        hist_left = jnp.einsum("i,im,ipq,ik->mpqk", w, node_oh,
                               left_mask.astype(w.dtype), class_oh)
        hist_right = hist_tot[:, None, None, :] - hist_left
        score = _weighted_gini(hist_left) + _weighted_gini(hist_right)  # [m,p,q]
        flat = score.reshape(width, p * q)
        best = jnp.argmin(flat, axis=-1)
        best_f = best // q
        best_q = best % q
        best_thr = thr_cand[best_f, best_q]
        offset = 2 ** level - 1
        # explicit casts: under JAX_ENABLE_X64 best_f/best_thr promote to
        # 64-bit and the mixed-dtype scatter is deprecated (future error)
        feat = feat.at[offset:offset + width].set(best_f.astype(feat.dtype))
        thr = thr.at[offset:offset + width].set(best_thr.astype(thr.dtype))
        go_right = X[jnp.arange(n), best_f[node_of]] > best_thr[node_of]
        node_of = 2 * node_of + go_right.astype(jnp.int32)

    # Leaf classes: weighted majority, backed off to the global majority for
    # empty leaves.
    leaf_oh = jax.nn.one_hot(node_of, 2 ** depth)
    leaf_hist = jnp.einsum("i,il,ik->lk", w, leaf_oh, class_oh)
    global_hist = jnp.einsum("i,ik->k", w, class_oh)
    leaf_hist = leaf_hist + _EPS * global_hist[None, :]
    leaf_class = jnp.argmax(leaf_hist, axis=-1).astype(jnp.int32)
    return {"feat": feat, "thr": thr, "leaf": leaf_class}


@partial(jax.jit, static_argnames=("depth",))
def predict_tree(params, X: jnp.ndarray, *, depth: int) -> jnp.ndarray:
    n = X.shape[0]
    node = jnp.zeros((n,), jnp.int32)        # heap index
    for _ in range(depth):
        f = params["feat"][node]
        t = params["thr"][node]
        go_right = X[jnp.arange(n), f] > t
        node = 2 * node + 1 + go_right.astype(jnp.int32)
    leaf = node - (2 ** depth - 1)
    return params["leaf"][leaf]


@dataclass(frozen=True)
class DecisionTree(Learner):
    depth: int = 4
    num_thresholds: int = 16

    # Eager-only: the greedy argmin split search is not a fixed-shape
    # differentiable update, so trees stay on the eager engine backend
    # (Learner.functional = False) rather than implementing LearnerCore.
    functional = False

    def fit(self, key, X, classes, w, num_classes):
        del key  # deterministic
        return fit_tree(X, classes, w, depth=self.depth,
                        num_thresholds=self.num_thresholds,
                        num_classes=num_classes)

    def predict(self, params, X):
        return predict_tree(params, X, depth=self.depth)
