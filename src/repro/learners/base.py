"""Learner interface: the model class F_0^(m) an agent brings to ASCII.

Every learner implements weighted supervised training (Algorithm 2 / WST):
``fit(key, X, classes, w) -> params`` minimizing the w-weighted training
loss, plus ``predict(params, X) -> class indices``.  Learners are stateless
objects; fitted parameters are plain pytrees so they jit/vmap/shard cleanly.

Per Prop. 1, minimizing the weighted exponential loss over F_0 is equivalent
to minimizing the w-weighted 0/1 classification error; trees do this
directly, while differentiable learners (logistic / MLP / neural backbones)
use the w-weighted cross-entropy as the standard smooth surrogate — the same
choice as the paper's own neural-network experiments (Section VI-B).
"""
from __future__ import annotations

import abc
from typing import Any

import jax.numpy as jnp

PyTree = Any


class Learner(abc.ABC):
    """A private model class F_0 held by a single agent."""

    @abc.abstractmethod
    def fit(self, key, X: jnp.ndarray, classes: jnp.ndarray,
            w: jnp.ndarray, num_classes: int) -> PyTree:
        """Weighted supervised training (Algorithm 2, line 1)."""

    @abc.abstractmethod
    def predict(self, params: PyTree, X: jnp.ndarray) -> jnp.ndarray:
        """Hard class predictions, shape [n]."""

    def reward(self, params: PyTree, X: jnp.ndarray,
               classes: jnp.ndarray) -> jnp.ndarray:
        """Prop. 1 reward r_i = I{g(x_i) = y_i} (Algorithm 2, line 2)."""
        return (self.predict(params, X) == classes).astype(jnp.float32)

    def endpoint(self, agent_id: int, X: jnp.ndarray, name: str = ""):
        """Wrap this learner + its private feature block as a protocol
        AgentEndpoint (see repro.core.engine)."""
        from repro.core.engine import AgentEndpoint
        return AgentEndpoint(agent_id, self, X, name=name)
