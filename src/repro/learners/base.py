"""Learner interface: the model class F_0^(m) an agent brings to ASCII.

Every learner implements weighted supervised training (Algorithm 2 / WST):
``fit(key, X, classes, w) -> params`` minimizing the w-weighted training
loss, plus ``predict(params, X) -> class indices``.  Learners are stateless
objects; fitted parameters are plain pytrees so they jit/vmap/shard cleanly.

Per Prop. 1, minimizing the weighted exponential loss over F_0 is equivalent
to minimizing the w-weighted 0/1 classification error; trees do this
directly, while differentiable learners (logistic / MLP / neural backbones)
use the w-weighted cross-entropy as the standard smooth surrogate — the same
choice as the paper's own neural-network experiments (Section VI-B).
"""
from __future__ import annotations

import abc
import functools
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@functools.lru_cache(maxsize=256)
def jitted_fresh_fit(core: "LearnerCore", shapes: tuple):
    """Cached jit of the fresh-fit composition ``fit(init(key), key, ...)``
    (cores are hashable frozen dataclasses, so they key the cache).

    Eager ``Learner.fit`` wrappers route through this so the eager engine
    runs the exact XLA program the compiled session scan embeds — init and
    fit traced together — which, not luck, is what keeps the two backends
    bit-identical (op-by-op dispatch fuses differently at the last ulp)."""

    def fresh(key, X, onehot, w):
        return core.fit(core.init(key, shapes), key, X, onehot, w)

    return jax.jit(fresh)


class LearnerCore(abc.ABC):
    """Pure functional learner contract — the compilable half of a Learner.

    A core is a *static* (hashable, frozen-dataclass) bundle of pure
    functions over fixed-shape pytree params, so a whole ASCII session can
    be lowered into one ``lax.scan`` program (``core/compiled.py``) and
    vmapped across session fleets:

      * ``init(key, shapes) -> params``    — fresh params for feature shape
        ``shapes`` (e.g. ``(p,)``), fixed pytree structure.
      * ``fit(params, key, X, onehot, w) -> params`` — Algorithm 2 / WST:
        minimize the w-weighted loss starting from ``params``.
      * ``logits(params, X) -> [n, K]``    — class scores.
      * ``predict(params, X) -> [n]``      — argmax of ``logits``.

    Key discipline: ``init`` and ``fit`` both receive the *same* per-fit
    key and derive any sub-keys internally, such that

        core.fit(core.init(key, X.shape[1:]), key, X, onehot, w)

    reproduces the matching eager ``Learner.fit(key, X, classes, w, K)``
    bit for bit — that identity is what makes the compiled engine backend
    a drop-in for the eager one (tests/test_compiled.py).
    """

    @abc.abstractmethod
    def init(self, key, shapes: tuple[int, ...]) -> PyTree:
        """Fresh fixed-shape params for feature shape ``shapes``."""

    @abc.abstractmethod
    def fit(self, params: PyTree, key, X: jnp.ndarray, onehot: jnp.ndarray,
            w: jnp.ndarray) -> PyTree:
        """Weighted supervised training from ``params`` (Algorithm 2)."""

    @abc.abstractmethod
    def logits(self, params: PyTree, X: jnp.ndarray) -> jnp.ndarray:
        """Class scores, shape [n, K]."""

    def predict(self, params: PyTree, X: jnp.ndarray) -> jnp.ndarray:
        return jnp.argmax(self.logits(params, X), axis=-1)


class Learner(abc.ABC):
    """A private model class F_0 held by a single agent."""

    #: Adapter flag: True when :meth:`core` returns a functional
    #: LearnerCore, i.e. the learner can ride the compiled engine backend.
    #: Eager-only learners (decision tree / random forest, whose fits are
    #: argmin/argmax programs rather than fixed-shape differentiable
    #: updates) keep the default False and stay on the eager path.
    functional = False

    @abc.abstractmethod
    def fit(self, key, X: jnp.ndarray, classes: jnp.ndarray,
            w: jnp.ndarray, num_classes: int) -> PyTree:
        """Weighted supervised training (Algorithm 2, line 1)."""

    @abc.abstractmethod
    def predict(self, params: PyTree, X: jnp.ndarray) -> jnp.ndarray:
        """Hard class predictions, shape [n]."""

    def core(self, num_classes: int) -> LearnerCore | None:
        """The pure functional core of this learner, or None when the
        learner is eager-only (``functional = False``)."""
        return None

    def reward(self, params: PyTree, X: jnp.ndarray,
               classes: jnp.ndarray) -> jnp.ndarray:
        """Prop. 1 reward r_i = I{g(x_i) = y_i} (Algorithm 2, line 2)."""
        return (self.predict(params, X) == classes).astype(jnp.float32)

    def endpoint(self, agent_id: int, X: jnp.ndarray, name: str = ""):
        """Wrap this learner + its private feature block as a protocol
        AgentEndpoint (see repro.core.engine)."""
        from repro.core.engine import AgentEndpoint
        return AgentEndpoint(agent_id, self, X, name=name)
