"""Neural-backbone ASCII agent: wraps any assigned architecture (via the
classifier head) as a Learner, fitting it with the w-weighted cross-entropy
per Algorithm 2.  Tabular features are linearly projected into d_model and
treated as a length-1 'sequence'; token inputs pass straight through.

The fit lives in :class:`NeuralCore` (pure LearnerCore contract, compiled-
backend-ready); the eager Learner delegates to it.  ``init`` consumes
``split(key, 3)[:2]`` — the same draws as the original monolithic fit —
and ``fit`` itself is deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.learners.base import Learner, LearnerCore, jitted_fresh_fit
from repro.models import classifier
from repro.models.layers import he_init
from repro.optim.optimizers import adamw


def _logits(params, X, cfg):
    # features -> a short pseudo-sequence of d_model embeddings
    emb = jnp.einsum("np,pd->nd", X, params["proj"])[:, None, :]
    batch = {"tokens": jnp.zeros((X.shape[0], 1), jnp.int32)}
    x = emb + classifier.transformer.embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, unit_params):
        h, aux = carry
        h, _, aux_u = classifier.transformer._unit_forward(
            unit_params, h, cfg, positions)
        return (h, aux + aux_u), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["layers"])
    x = classifier.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    pooled = jnp.mean(x, axis=1)
    return jnp.einsum("bd,dk->bk", pooled.astype(jnp.float32),
                      params["cls_head"]["w"].astype(jnp.float32))


@dataclass(frozen=True)
class NeuralCore(LearnerCore):
    num_classes: int
    cfg: ArchConfig = None
    steps: int = 200
    lr: float = 1e-3

    def init(self, key, shapes):
        k1, k2, _ = jax.random.split(key, 3)
        params = classifier.init_params(k1, self.cfg, self.num_classes)
        params["proj"] = he_init(k2, (shapes[0], self.cfg.d_model),
                                 jnp.float32)
        return params

    def fit(self, params, key, X, onehot, w):
        del key  # full-batch fit is deterministic
        opt = adamw(self.lr)
        opt_state = opt.init(params)

        def loss_fn(p):
            logits = _logits(p, X, self.cfg)
            ll = jnp.sum(onehot * logits, -1) - jax.nn.logsumexp(logits, -1)
            return -jnp.sum(w * ll) / jnp.maximum(jnp.sum(w), 1e-12)

        def step(carry, i):
            p, s = carry
            grads = jax.grad(loss_fn)(p)
            p, s = opt.update(grads, s, p, i)
            return (p, s), None

        (params, _), _ = jax.lax.scan(step, (params, opt_state),
                                      jnp.arange(self.steps))
        return params

    def logits(self, params, X):
        return _logits(params, X, self.cfg)


@dataclass(frozen=True)
class NeuralBackbone(Learner):
    cfg: ArchConfig = None
    steps: int = 200
    lr: float = 1e-3

    functional = True

    def core(self, num_classes: int) -> NeuralCore:
        return NeuralCore(num_classes, self.cfg, self.steps, self.lr)

    def fit(self, key, X, classes, w, num_classes):
        core = self.core(num_classes)
        onehot = jax.nn.one_hot(classes, num_classes)
        return jitted_fresh_fit(core, X.shape[1:])(key, X, onehot, w)

    def predict(self, params, X):
        return jnp.argmax(_logits(params, X, self.cfg), axis=-1)
