"""Weighted multinomial logistic regression, fitted with full-batch AdamW.

Small-data workhorse used by the paper's 20-agent Blob experiment
(Section VI-C, Fig. 6a).  The fit is implemented once, as a pure
:class:`~repro.learners.base.LearnerCore` (init / fit / logits over a
fixed-shape params pytree); the eager ``Learner.fit`` is a thin wrapper so
the eager engine and the compiled session program share the exact same
computation.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.learners.base import Learner, LearnerCore, jitted_fresh_fit
from repro.optim.optimizers import adamw


def _weighted_ce(params, X, onehot, w, l2):
    logits = X @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.sum(onehot * logits, axis=-1) - logz
    reg = l2 * jnp.sum(jnp.square(params["w"]))
    return -jnp.sum(w * ll) / jnp.maximum(jnp.sum(w), 1e-12) + reg


@dataclass(frozen=True)
class LogisticCore(LearnerCore):
    num_classes: int
    steps: int = 300
    lr: float = 0.1
    l2: float = 1e-4

    def init(self, key, shapes):
        del key  # deterministic init (zeros)
        (p,) = shapes
        return {"w": jnp.zeros((p, self.num_classes), jnp.float32),
                "b": jnp.zeros((self.num_classes,), jnp.float32)}

    def fit(self, params, key, X, onehot, w):
        del key  # full-batch fit is deterministic
        opt = adamw(self.lr)
        opt_state = opt.init(params)
        grad_fn = jax.grad(_weighted_ce)

        def body(i, carry):
            params, opt_state = carry
            grads = grad_fn(params, X, onehot, w, self.l2)
            return opt.update(grads, opt_state, params, i)

        params, _ = jax.lax.fori_loop(0, self.steps, body, (params, opt_state))
        return params

    def logits(self, params, X):
        return X @ params["w"] + params["b"]


@dataclass(frozen=True)
class LogisticRegression(Learner):
    steps: int = 300
    lr: float = 0.1
    l2: float = 1e-4

    functional = True

    def core(self, num_classes: int) -> LogisticCore:
        return LogisticCore(num_classes, self.steps, self.lr, self.l2)

    def fit(self, key, X, classes, w, num_classes):
        core = self.core(num_classes)
        onehot = jax.nn.one_hot(classes, num_classes)
        return jitted_fresh_fit(core, X.shape[1:])(key, X, onehot, w)

    def predict(self, params, X):
        return jnp.argmax(X @ params["w"] + params["b"], axis=-1)
