"""Weighted multinomial logistic regression, fitted with full-batch AdamW.

Small-data workhorse used by the paper's 20-agent Blob experiment
(Section VI-C, Fig. 6a).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.learners.base import Learner
from repro.optim.optimizers import adamw


def _weighted_ce(params, X, onehot, w, l2):
    logits = X @ params["w"] + params["b"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.sum(onehot * logits, axis=-1) - logz
    reg = l2 * jnp.sum(jnp.square(params["w"]))
    return -jnp.sum(w * ll) / jnp.maximum(jnp.sum(w), 1e-12) + reg


@dataclass(frozen=True)
class LogisticRegression(Learner):
    steps: int = 300
    lr: float = 0.1
    l2: float = 1e-4

    def fit(self, key, X, classes, w, num_classes):
        p = X.shape[-1]
        params = {"w": jnp.zeros((p, num_classes), jnp.float32),
                  "b": jnp.zeros((num_classes,), jnp.float32)}
        onehot = jax.nn.one_hot(classes, num_classes)
        opt = adamw(self.lr)
        opt_state = opt.init(params)
        grad_fn = jax.grad(_weighted_ce)

        def body(i, carry):
            params, opt_state = carry
            grads = grad_fn(params, X, onehot, w, self.l2)
            return opt.update(grads, opt_state, params, i)

        params, _ = jax.lax.fori_loop(0, self.steps, body, (params, opt_state))
        return params

    def predict(self, params, X):
        return jnp.argmax(X @ params["w"] + params["b"], axis=-1)
