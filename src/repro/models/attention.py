"""Attention: GQA (with qk-norm, sliding window) and MLA (compressed-latent
KV cache), each with full-sequence (train/prefill) and single-token decode
paths.

KV cache layouts:
  * GQA  : k/v  [B, S_cache, KV, D]  (cache_mode 'full') or [B, W, KV, D]
           ring buffer (cache_mode 'ring', SWA only — §Perf lever: the ring
           cache bounds decode memory traffic by the window instead of the
           full context).
  * MLA  : c_kv [B, S_cache, kv_lora_rank], k_rope [B, S_cache, rope_dim]
           — the compressed latents are cached, not per-head K/V; decode
           uses the absorbed-projection form so per-step FLOPs and cache
           bytes scale with the latent rank.
RoPE is applied at write time with absolute positions (relative-consistent
under the dot product), which is what makes the ring buffer sound.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, he_init, rmsnorm, rmsnorm_init


class KVCache(NamedTuple):
    k: jnp.ndarray            # GQA: [B, S, KV, D] / MLA: c_kv [B, S, R]
    v: jnp.ndarray            # GQA: [B, S, KV, D] / MLA: k_rope [B, S, Dr]


class QuantKVCache(NamedTuple):
    """int8 KV cache (kv_quant=true): per-(token, head) absmax scales.

    Halves decode HBM capacity and (with a fused dequant kernel on TPU)
    cache read traffic; the XLA dry-run path dequantizes explicitly, so the
    bytes-accessed metric does not credit the read saving — see
    EXPERIMENTS.md §Perf H3 it2 for the honest accounting.
    """
    k: jnp.ndarray            # int8 [B, S, KV, D]
    v: jnp.ndarray            # int8 [B, S, KV, D]
    k_scale: jnp.ndarray      # f32 [B, S, KV]
    v_scale: jnp.ndarray      # f32 [B, S, KV]


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[..., D] -> (int8 values, f32 absmax scale over D)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale[..., None], 1e-8)).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# =================================================================== GQA
def gqa_init(key, cfg: ArchConfig, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": he_init(ks[0], (d, h * hd), dtype),
        "wk": he_init(ks[1], (d, kv * hd), dtype),
        "wv": he_init(ks[2], (d, kv * hd), dtype),
        "wo": he_init(ks[3], (h * hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qk_norm:
        params["q_norm"] = rmsnorm_init(hd, dtype)
        params["k_norm"] = rmsnorm_init(hd, dtype)
    return params


def _project_qkv(params, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, softcap=None):
    """q [B,S,H,D] x k/v [B,T,KV,D] grouped-query attention core."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)


def causal_mask(s: int, t: int, q_offset, window: int | None) -> jnp.ndarray:
    """[1,1,1,s,t] boolean mask; q_offset = absolute position of query 0."""
    q_pos = q_offset + jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    m = k_pos <= q_pos
    if window is not None:
        m &= k_pos > q_pos - window
    return m[None, None, None]


def _sdpa_q_chunked(q, k, v, cfg: ArchConfig, chunk: int, softcap=None):
    """Query-chunked attention (§Perf lever, attn_impl='chunked'):
    processes Q in blocks of `chunk` rows via lax.scan so the score matrix
    materialized at any instant is [chunk, S] instead of [S, S] — the
    XLA-level analogue of the Pallas flash kernel for the dry-run path."""
    b, s, h, d = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, d), 1, 0)   # [nc,b,c,h,d]

    def body(_, inp):
        qi, idx = inp
        mask = causal_mask(chunk, s, idx * chunk, cfg.window)
        return None, _sdpa(qi, k, v, mask, softcap)

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)


def gqa_forward(params, x, cfg: ArchConfig, positions) -> tuple[jnp.ndarray, KVCache]:
    """Full-sequence path (train/prefill). Returns output and fresh cache."""
    s = x.shape[1]
    q, k, v = _project_qkv(params, x, cfg, positions)
    if cfg.attn_impl == "chunked" and s > cfg.attn_chunk:
        out = _sdpa_q_chunked(q, k, v, cfg, cfg.attn_chunk, cfg.logit_softcap)
    else:
        mask = causal_mask(s, s, 0, cfg.window)
        out = _sdpa(q, k, v, mask, cfg.logit_softcap)
    out = jnp.einsum("bse,ed->bsd", out.reshape(*out.shape[:2], -1),
                     params["wo"])
    return out, KVCache(k=k, v=v)


def gqa_decode(params, x, cache, pos, cfg: ArchConfig,
               cache_mode: str = "full"):
    """Single-token decode. x: [B,1,d]; pos: scalar absolute position.
    cache: KVCache or QuantKVCache (int8)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)
    s_cache = cache.k.shape[1]
    if cache_mode == "ring":
        slot = pos % s_cache
    else:
        slot = pos
    quant = isinstance(cache, QuantKVCache)
    if quant:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache = QuantKVCache(
            k=jax.lax.dynamic_update_slice_in_dim(cache.k, kq, slot, axis=1),
            v=jax.lax.dynamic_update_slice_in_dim(cache.v, vq, slot, axis=1),
            k_scale=jax.lax.dynamic_update_slice_in_dim(
                cache.k_scale, ks, slot, axis=1),
            v_scale=jax.lax.dynamic_update_slice_in_dim(
                cache.v_scale, vs, slot, axis=1))
        k = dequantize_kv(new_cache.k, new_cache.k_scale, k_new.dtype)
        v = dequantize_kv(new_cache.v, new_cache.v_scale, v_new.dtype)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    idx = jnp.arange(s_cache)
    if cache_mode == "ring":
        # entry at slot i holds absolute position: reconstructible but we
        # only need validity: entries written so far and within the window.
        age = (slot - idx) % s_cache          # 0 = just written
        valid = (age <= jnp.minimum(pos, s_cache - 1))
        if cfg.window is not None:
            valid &= age < cfg.window
        mask = valid[None, None, None, None, :]
    else:
        valid = idx <= pos
        if cfg.window is not None:
            valid &= idx > pos - cfg.window
        mask = valid[None, None, None, None, :]
    out = _sdpa(q, k, v, mask, cfg.logit_softcap)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), params["wo"])
    return out, (new_cache if quant else KVCache(k=k, v=v))


# =================================================================== MLA
def mla_init(key, cfg: ArchConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    d_nope, d_rope, d_v = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": he_init(ks[0], (d, r_q), dtype),
        "q_a_norm": rmsnorm_init(r_q, dtype),
        "wq_b": he_init(ks[1], (r_q, h * (d_nope + d_rope)), dtype, fan_in=r_q),
        "wkv_a": he_init(ks[2], (d, r_kv + d_rope), dtype),
        "kv_a_norm": rmsnorm_init(r_kv, dtype),
        "wk_b": he_init(ks[3], (r_kv, h * d_nope), dtype, fan_in=r_kv),
        "wv_b": he_init(ks[4], (r_kv, h * d_v), dtype, fan_in=r_kv),
        "wo": he_init(ks[5], (h * d_v, d), dtype, fan_in=h * d_v),
    }


def _mla_q(params, x, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    d_nope, d_rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = rmsnorm(params["q_a_norm"], jnp.einsum("bsd,dr->bsr", x, params["wq_a"]),
                cfg.norm_eps)
    q = jnp.einsum("bsr,re->bse", q, params["wq_b"]).reshape(b, s, h,
                                                             d_nope + d_rope)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latents(params, x, cfg: ArchConfig, positions):
    r_kv, d_rope = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = rmsnorm(params["kv_a_norm"], kv[..., :r_kv], cfg.norm_eps)
    k_rope = apply_rope(kv[..., r_kv:][..., None, :], positions,
                        cfg.rope_theta)[..., 0, :]           # shared head
    return c_kv, k_rope


def mla_forward(params, x, cfg: ArchConfig, positions) -> tuple[jnp.ndarray, KVCache]:
    """Full-sequence MLA (expanded form). Caches latents only."""
    b, s, _ = x.shape
    h, d_nope, d_v = cfg.num_heads, cfg.qk_nope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    c_kv, k_rope = _mla_latents(params, x, cfg, positions)
    k_nope = jnp.einsum("btr,re->bte", c_kv, params["wk_b"]).reshape(
        b, s, h, d_nope)
    v = jnp.einsum("btr,re->bte", c_kv, params["wv_b"]).reshape(b, s, h, d_v)
    scale = 1.0 / jnp.sqrt(d_nope + cfg.qk_rope_head_dim)

    def block(qn, qr, q_offset, c):
        scores = (jnp.einsum("bshd,bthd->bhst", qn, k_nope)
                  + jnp.einsum("bshd,btd->bhst", qr, k_rope)
                  ).astype(jnp.float32) * scale
        mask = causal_mask(c, s, q_offset, cfg.window)[:, :, 0]  # [1,1,c,t]
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)

    if cfg.attn_impl == "chunked" and s > cfg.attn_chunk:
        # query-chunked (§Perf lever): [chunk, S] scores instead of [S, S]
        c = cfg.attn_chunk
        nc = s // c
        qn = jnp.moveaxis(q_nope.reshape(b, nc, c, h, d_nope), 1, 0)
        qr = jnp.moveaxis(q_rope.reshape(b, nc, c, h, cfg.qk_rope_head_dim),
                          1, 0)

        def body(_, inp):
            qn_i, qr_i, idx = inp
            return None, block(qn_i, qr_i, idx * c, c)

        _, out = jax.lax.scan(body, None, (qn, qr, jnp.arange(nc)))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, -1)
    else:
        out = block(q_nope, q_rope, 0, s).reshape(b, s, -1)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"])
    return out, KVCache(k=c_kv, v=k_rope)


def mla_decode(params, x, cache: KVCache, pos, cfg: ArchConfig,
               cache_mode: str = "full") -> tuple[jnp.ndarray, KVCache]:
    """Absorbed-projection decode: score via latents, never materializing
    per-head K/V for the whole cache."""
    b = x.shape[0]
    h, d_nope, d_v = cfg.num_heads, cfg.qk_nope_head_dim, cfg.v_head_dim
    r_kv = cfg.kv_lora_rank
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(params, x, cfg, positions)        # [b,1,h,*]
    c_new, kr_new = _mla_latents(params, x, cfg, positions)
    s_cache = cache.k.shape[1]
    slot = pos % s_cache if cache_mode == "ring" else pos
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.k, c_new, slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.v, kr_new, slot, axis=1)
    # absorb W_uk into the query: q_abs [b,h,r_kv]
    wk_b = params["wk_b"].reshape(r_kv, h, d_nope)
    q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b)[:, 0]  # [b,h,r]
    scores = (jnp.einsum("bhr,btr->bht", q_abs, c_kv)
              + jnp.einsum("bshd,btd->bht", q_rope, k_rope)).astype(jnp.float32)
    scores = scores / jnp.sqrt(d_nope + cfg.qk_rope_head_dim)
    idx = jnp.arange(s_cache)
    if cache_mode == "ring":
        age = (slot - idx) % s_cache
        valid = age <= jnp.minimum(pos, s_cache - 1)
        if cfg.window is not None:
            valid &= age < cfg.window
    else:
        valid = idx <= pos
        if cfg.window is not None:
            valid &= idx > pos - cfg.window
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    out_latent = jnp.einsum("bht,btr->bhr", probs, c_kv)      # [b,h,r]
    wv_b = params["wv_b"].reshape(r_kv, h, d_v)
    out = jnp.einsum("bhr,rhd->bhd", out_latent, wv_b).reshape(b, 1, -1)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"])
    return out, KVCache(k=c_kv, v=k_rope)


# ========================================================== Cross-attention
def cross_attn_init(key, cfg: ArchConfig, dtype) -> dict:
    return gqa_init(key, cfg, dtype)


def cross_attn(params, x, enc_kv: KVCache, cfg: ArchConfig) -> jnp.ndarray:
    """Decoder-to-encoder attention (whisper backbone). enc_kv holds the
    encoder's projected K/V (computed once at prefill)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, hd)
    t = enc_kv.k.shape[1]
    mask = jnp.ones((1, 1, 1, s, t), bool)
    out = _sdpa(q, enc_kv.k, enc_kv.v, mask, None)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), params["wo"])


def encode_kv(params, enc_out: jnp.ndarray, cfg: ArchConfig) -> KVCache:
    b, t, _ = enc_out.shape
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    k = jnp.einsum("btd,de->bte", enc_out, params["wk"]).reshape(b, t, kv, hd)
    v = jnp.einsum("btd,de->bte", enc_out, params["wv"]).reshape(b, t, kv, hd)
    return KVCache(k=k, v=v)
