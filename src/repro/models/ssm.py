"""Mamba2 SSD (state-space duality) block — arXiv:2405.21060.

TPU adaptation: the selective scan is computed in the *chunked SSD* form —
an intra-chunk quadratic (attention-like) matmul term plus an inter-chunk
state recurrence — so nearly all FLOPs are MXU matmuls and the sequential
dependency is only over S/chunk steps (lax.scan).  Jamba's Mamba-1 layers
are also implemented with this SSD formulation (state kept at 16); see
DESIGN.md §2 assumption log.

Sharding note: unlike the reference implementation's fused ``in_proj``
(one matrix emitting z|x|B|C|dt), projections here are split per stream so
tensor parallelism can shard d_inner/heads cleanly without slicing a
sharded dimension; the depthwise causal conv is channel-independent, so it
splits with them at zero cost.

Decode carries an O(1) recurrent state per layer:
  conv_{x,B,C} [B, conv-1, *]  and  ssm_state [B, H, N, P].
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import he_init, rmsnorm, rmsnorm_init


class SSMState(NamedTuple):
    conv_x: jnp.ndarray       # [B, conv-1, d_inner]
    conv_B: jnp.ndarray       # [B, conv-1, N]
    conv_C: jnp.ndarray       # [B, conv-1, N]
    ssm: jnp.ndarray          # [B, H, N, P]


def ssm_init(key, cfg: ArchConfig, dtype) -> dict:
    d, n, conv = cfg.d_model, cfg.ssm_state, cfg.ssm_conv
    d_inner, h = cfg.d_inner, cfg.ssm_heads
    ks = jax.random.split(key, 7)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba convention)
    u = jax.random.uniform(ks[5], (h,), minval=jnp.log(1e-3),
                           maxval=jnp.log(1e-1))
    dt = jnp.exp(u)
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_z": he_init(ks[0], (d, d_inner), dtype),
        "in_x": he_init(ks[1], (d, d_inner), dtype),
        "in_B": he_init(ks[2], (d, n), dtype),
        "in_C": he_init(ks[3], (d, n), dtype),
        "in_dt": he_init(ks[4], (d, h), dtype),
        "conv_x": (jax.random.normal(ks[6], (conv, d_inner)) / conv).astype(dtype),
        "conv_x_bias": jnp.zeros((d_inner,), dtype),
        "conv_B": (jax.random.normal(jax.random.fold_in(ks[6], 1), (conv, n))
                   / conv).astype(dtype),
        "conv_B_bias": jnp.zeros((n,), dtype),
        "conv_C": (jax.random.normal(jax.random.fold_in(ks[6], 2), (conv, n))
                   / conv).astype(dtype),
        "conv_C_bias": jnp.zeros((n,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": he_init(jax.random.fold_in(ks[6], 3), (d_inner, d), dtype,
                            fan_in=d_inner),
    }


def _conv_full(w, b, x, conv: int):
    """Depthwise causal conv along S, silu-activated. x [B,S,C]."""
    pad = jnp.pad(x, ((0, 0), (conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(conv))
    return jax.nn.silu(out + b)


def _conv_step(w, b, state, x_new):
    """One-token conv. state [B,conv-1,C], x_new [B,1,C] -> ([B,C], state)."""
    window = jnp.concatenate([state, x_new], axis=1)          # [B,conv,C]
    out = jnp.einsum("bcd,cd->bd", window, w) + b
    return jax.nn.silu(out), window[:, 1:, :]


def ssd_chunked(x, dt, A_log, B, C, chunk: int, h0=None):
    """Chunked SSD scan.

    x [b,s,h,p] (inputs, *not* yet dt-scaled), dt [b,s,h] f32, A_log [h],
    B/C [b,s,n] (single group).  Returns (y [b,s,h,p], H_final [b,h,n,p]).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    a = -jnp.exp(A_log.astype(jnp.float32))                  # [h], negative
    dA = dt * a                                              # [b,s,h] <= 0
    xdt = (x.astype(jnp.float32) * dt[..., None])            # dt-scaled input

    xc = xdt.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, n).astype(jnp.float32)
    cum = jnp.cumsum(dAc, axis=2)                            # [b,c,L,h]

    # --- intra-chunk (quadratic, attention-like) term
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)               # [b,c,L,L]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # [b,c,t,s,h]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask inside the exponent: for t < s the difference is positive and
    # exp overflows to inf (inf * 0 = NaN) if masked after.
    seg = jnp.where(mask[None, None, :, :, None], seg, -jnp.inf)
    m = cb[..., None] * jnp.exp(seg)                          # [b,c,t,s,h]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, xc)

    # --- chunk boundary states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)          # [b,c,L,h]
    S = jnp.einsum("bcln,bclhp,bclh->bchnp", Bc, xc, decay_to_end)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # [b,c,h]

    def step(H, inp):
        S_k, dec = inp
        H_new = H * dec[:, :, None, None] + S_k
        return H_new, H                                       # emit pre-state

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)
    S_sw = jnp.moveaxis(S, 1, 0)                             # [c,b,h,n,p]
    dec_sw = jnp.moveaxis(chunk_decay, 1, 0)                 # [c,b,h]
    H_final, H_prev = jax.lax.scan(step, h0, (S_sw, dec_sw))
    H_prev = jnp.moveaxis(H_prev, 0, 1)                      # [b,c,h,n,p]

    # --- inter-chunk term
    decay_from_start = jnp.exp(cum)                          # [b,c,L,h]
    y_inter = jnp.einsum("bcln,bchnp,bclh->bclhp", Cc, H_prev,
                         decay_from_start)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, H_final


def ssm_forward(params, x: jnp.ndarray, cfg: ArchConfig
                ) -> tuple[jnp.ndarray, SSMState]:
    """Full-sequence SSD block. x [B,S,d] -> (y [B,S,d], final state)."""
    b, s, _ = x.shape
    d_inner, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv = cfg.ssm_conv
    z = jnp.einsum("bsd,de->bse", x, params["in_z"])
    xs_raw = jnp.einsum("bsd,de->bse", x, params["in_x"])
    B_raw = jnp.einsum("bsd,dn->bsn", x, params["in_B"])
    C_raw = jnp.einsum("bsd,dn->bsn", x, params["in_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["in_dt"]).astype(jnp.float32)
        + params["dt_bias"])
    state = SSMState(conv_x=xs_raw[:, -(conv - 1):, :],
                     conv_B=B_raw[:, -(conv - 1):, :],
                     conv_C=C_raw[:, -(conv - 1):, :],
                     ssm=jnp.zeros((b, h, n, p), jnp.float32))
    xs = _conv_full(params["conv_x"], params["conv_x_bias"], xs_raw, conv)
    B = _conv_full(params["conv_B"], params["conv_B_bias"], B_raw, conv)
    C = _conv_full(params["conv_C"], params["conv_C_bias"], C_raw, conv)
    xs = xs.reshape(b, s, h, p)
    chunk = min(cfg.ssm_chunk, s)
    y, H = ssd_chunked(xs, dt, params["A_log"], B, C, chunk)
    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, state._replace(ssm=H.astype(jnp.float32))


def ssm_decode(params, x: jnp.ndarray, state: SSMState, cfg: ArchConfig
               ) -> tuple[jnp.ndarray, SSMState]:
    """Single-token recurrent step. x [B,1,d]."""
    b = x.shape[0]
    d_inner, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, params["in_z"])
    xs_raw = jnp.einsum("bsd,de->bse", x, params["in_x"])
    B_raw = jnp.einsum("bsd,dn->bsn", x, params["in_B"])
    C_raw = jnp.einsum("bsd,dn->bsn", x, params["in_C"])
    dt1 = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["in_dt"]).astype(jnp.float32)
        + params["dt_bias"])[:, 0]                            # [B,H]
    xs1, cx = _conv_step(params["conv_x"], params["conv_x_bias"],
                         state.conv_x, xs_raw)
    B1, cB = _conv_step(params["conv_B"], params["conv_B_bias"],
                        state.conv_B, B_raw)
    C1, cC = _conv_step(params["conv_C"], params["conv_C_bias"],
                        state.conv_C, C_raw)
    xs1 = xs1.reshape(b, h, p).astype(jnp.float32)
    B1 = B1.astype(jnp.float32)
    C1 = C1.astype(jnp.float32)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * a)                                   # [B,H]
    upd = jnp.einsum("bn,bhp,bh->bhnp", B1, xs1, dt1)
    H = state.ssm * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C1, H)
    y = y + params["D"][None, :, None] * xs1
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    return out, SSMState(conv_x=cx, conv_B=cB, conv_C=cC, ssm=H)
