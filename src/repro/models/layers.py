"""Shared model layers: RMSNorm, RoPE, embeddings, gated MLP.

Convention: every layer is an (init, apply) pair of pure functions over
plain dict pytrees.  Parameter leaf names are stable and pattern-matched by
sharding/rules.py to assign logical axes — keep names in sync with that
table when adding parameters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def he_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) * (2.0 / fan_in) ** 0.5).astype(dtype)


# ---------------------------------------------------------------- RMSNorm
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                      # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- Embeddings
def embedding_init(key, vocab: int, d: int, dtype) -> dict:
    return {"embedding": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(params: dict, tokens: jnp.ndarray, scale: bool = False) -> jnp.ndarray:
    x = jnp.take(params["embedding"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in the model dtype; the loss upcasts to f32 *inside* its
    reductions so no f32 [B,S,V] tensor is ever materialized."""
    return jnp.einsum("...d,vd->...v", x, params["embedding"])


def lm_head_init(key, d: int, vocab: int, dtype) -> dict:
    return {"unembedding": (jax.random.normal(key, (d, vocab)) * 0.02).astype(dtype)}


def lm_head(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,dv->...v", x, params["unembedding"])


# ------------------------------------------------------ Gated MLP (dense)
def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi_gate": he_init(k1, (d, d_ff), dtype),
            "wi_up": he_init(k2, (d, d_ff), dtype),
            "wo": he_init(k3, (d_ff, d), dtype, fan_in=d_ff)}


def mlp_apply(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    gate = jnp.einsum("...d,df->...f", x, params["wi_gate"])
    up = jnp.einsum("...d,df->...f", x, params["wi_up"])
    g = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return jnp.einsum("...f,fd->...d", g * up, params["wo"])
