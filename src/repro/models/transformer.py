"""Decoder-only model assembly (dense / MoE / SSM / hybrid / VLM), built as
``jax.lax.scan`` over stacked per-layer parameters so the lowered HLO is
layer-count independent (94-layer qwen3-moe compiles as fast as 2 layers).

Three entry points per model, all pure:
  * ``forward(params, tokens_or_embeds, cfg)``            -> logits, caches
  * ``decode_step(params, caches, token, pos, cfg)``      -> logits, caches
  * ``init_params(key, cfg)`` / ``init_cache(cfg, batch, s_cache)``

Hybrid (Jamba) stacks scan over *pattern units* (8 heterogeneous sub-layers
unrolled inside, 4 scanned repeats).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (embed, embedding_init, lm_head, lm_head_init,
                                 mlp_apply, mlp_init, rmsnorm, rmsnorm_init,
                                 unembed)

PyTree = Any


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------ block defs
def _block_kinds(cfg: ArchConfig) -> tuple[str, ...]:
    """Sub-layer kinds of one scanned unit."""
    if cfg.layer_pattern:
        return cfg.layer_pattern
    if cfg.arch_type == "ssm":
        return ("ssm",)
    return ("attn",)


def _num_units(cfg: ArchConfig) -> int:
    return cfg.num_layers // len(_block_kinds(cfg))


def _ffn_kind(cfg: ArchConfig, sub_idx: int) -> str:
    """What follows the mixer in this sub-layer: moe | mlp | none."""
    if cfg.arch_type == "ssm":
        return "none"                       # pure mamba2: no FFN
    if cfg.is_moe:
        if cfg.moe_every <= 1 or (sub_idx % cfg.moe_every == 1):
            return "moe"
        return "mlp"
    return "mlp"


def _init_sub_block(key, cfg: ArchConfig, kind: str, sub_idx: int, dtype):
    ks = jax.random.split(key, 4)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.attention == "mla":
            p["attn"] = attn.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.gqa_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = ssm_lib.ssm_init(ks[0], cfg, dtype)
    ffn = _ffn_kind(cfg, sub_idx)
    if ffn != "none":
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if ffn == "moe":
            p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _seq_shard(x, cfg: ArchConfig):
    """Megatron sequence parallelism (cfg.seq_parallel): constrain the
    residual stream to S-sharded over ``model`` so XLA converts the TP
    activation all-reduces into reduce-scatter + all-gather pairs and the
    norm/residual math runs on S/|model| rows per chip."""
    if not cfg.seq_parallel:
        return x
    from repro.sharding.context import current_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    if x.shape[1] % mesh.shape["model"] != 0:
        return x
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, "model", None)))


def _sub_block_forward(p, x, cfg: ArchConfig, kind: str, sub_idx: int,
                       positions):
    """Full-seq sub-layer. Returns (x, cache_leaf, aux)."""
    aux = jnp.zeros((), jnp.float32)
    x = _seq_shard(x, cfg)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention == "mla":
            out, cache = attn.mla_forward(p["attn"], h, cfg, positions)
        else:
            out, cache = attn.gqa_forward(p["attn"], h, cfg, positions)
    else:
        out, cache = ssm_lib.ssm_forward(p["ssm"], h, cfg)
    x = x + out
    x = _seq_shard(x, cfg)
    ffn = _ffn_kind(cfg, sub_idx)
    if ffn == "moe":
        y, aux = moe_lib.moe_apply(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + y
    elif ffn == "mlp":
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
    return x, cache, aux


def _sub_block_decode(p, x, cache_leaf, pos, cfg: ArchConfig, kind: str,
                      sub_idx: int, cache_mode: str):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention == "mla":
            out, cache = attn.mla_decode(p["attn"], h, cache_leaf, pos, cfg,
                                         cache_mode)
        else:
            out, cache = attn.gqa_decode(p["attn"], h, cache_leaf, pos, cfg,
                                         cache_mode)
    else:
        out, cache = ssm_lib.ssm_decode(p["ssm"], h, cache_leaf, cfg)
    x = x + out
    ffn = _ffn_kind(cfg, sub_idx)
    if ffn == "moe":
        y, _ = moe_lib.moe_apply(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + y
    elif ffn == "mlp":
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
    return x, cache


# ------------------------------------------------------------- unit defs
def _init_unit(key, cfg: ArchConfig, dtype):
    kinds = _block_kinds(cfg)
    ks = jax.random.split(key, len(kinds))
    return {f"sub{i}": _init_sub_block(ks[i], cfg, kinds[i], i, dtype)
            for i in range(len(kinds))}


def _unit_forward(unit_params, x, cfg: ArchConfig, positions):
    kinds = _block_kinds(cfg)
    caches, aux_total = {}, jnp.zeros((), jnp.float32)
    for i, kind in enumerate(kinds):
        x, cache, aux = _sub_block_forward(unit_params[f"sub{i}"], x, cfg,
                                           kind, i, positions)
        caches[f"sub{i}"] = cache
        aux_total = aux_total + aux
    return x, caches, aux_total


def _unit_decode(unit_params, x, unit_cache, pos, cfg: ArchConfig,
                 cache_mode: str):
    kinds = _block_kinds(cfg)
    new_caches = {}
    for i, kind in enumerate(kinds):
        x, cache = _sub_block_decode(unit_params[f"sub{i}"], x,
                                     unit_cache[f"sub{i}"], pos, cfg, kind, i,
                                     cache_mode)
        new_caches[f"sub{i}"] = cache
    return x, new_caches


# --------------------------------------------------------------- model
def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = _dtype(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    units = _num_units(cfg)
    unit_keys = jax.random.split(k_layers, units)
    layers = jax.vmap(lambda k: _init_unit(k, cfg, dtype))(unit_keys)
    params = {
        "embed": embedding_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = lm_head_init(k_head, cfg.d_model, cfg.vocab_size,
                                         dtype)
    return params


def _logits(params, x, cfg: ArchConfig):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return lm_head(params["lm_head"], x)


def embed_inputs(params, batch: dict, cfg: ArchConfig) -> jnp.ndarray:
    """Token embeddings, with modality-frontend stub embeddings prepended
    for VLM/audio archs (the one sanctioned stub — DESIGN.md §2)."""
    x = embed(params["embed"], batch["tokens"], cfg.embed_scale)
    if cfg.frontend == "vision" and "patch_emb" in batch:
        x = jnp.concatenate([batch["patch_emb"].astype(x.dtype), x], axis=1)
    return x


def forward(params, batch: dict, cfg: ArchConfig):
    """Full-sequence forward (train / prefill).

    batch: {"tokens": [B,S]} (+ "patch_emb" [B,Timg,d] for VLM).
    Returns (logits [B,S_total,V], caches, aux_loss).
    """
    x = embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, unit_params):
        x, aux = carry
        x, caches, aux_u = _unit_forward(unit_params, x, cfg, positions)
        return (x, aux + aux_u), caches

    body_fn = jax.checkpoint(body) if cfg.remat == "block" else body
    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(
            body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        # Unrolled path: identical math/params, used by the dry-run cost
        # extraction (XLA cost_analysis counts a scan body only once).
        carry = (x, jnp.zeros((), jnp.float32))
        cache_list = []
        for i in range(_num_units(cfg)):
            unit = jax.tree.map(lambda a: a[i], params["layers"])
            carry, c = body_fn(carry, unit)
            cache_list.append(c)
        x, aux = carry
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    return _logits(params, x, cfg), caches, aux


def decode_step(params, caches, tokens: jnp.ndarray, pos, cfg: ArchConfig,
                cache_mode: str = "full"):
    """One-token decode. tokens [B,1]; pos scalar int32 (absolute position,
    frontend tokens included for VLM). Returns (logits [B,1,V], caches)."""
    x = embed(params["embed"], tokens, cfg.embed_scale)

    def body(x, inp):
        unit_params, unit_cache = inp
        x, new_cache = _unit_decode(unit_params, x, unit_cache, pos, cfg,
                                    cache_mode)
        return x, new_cache

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        cache_list = []
        for i in range(_num_units(cfg)):
            unit = jax.tree.map(lambda a: a[i], params["layers"])
            cache_u = jax.tree.map(lambda a: a[i], caches)
            x, c = body(x, (unit, cache_u))
            cache_list.append(c)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    return _logits(params, x, cfg), new_caches


def init_cache(cfg: ArchConfig, batch: int, s_cache: int,
               dtype=None) -> PyTree:
    """Zero-initialized decode cache matching the scan layout [U, ...]."""
    dtype = dtype or _dtype(cfg)
    units = _num_units(cfg)
    kinds = _block_kinds(cfg)

    def leaf(kind):
        if kind == "attn":
            if cfg.attention == "mla":
                # (MLA latents are already rank-compressed; int8 not applied)
                return attn.KVCache(
                    k=jnp.zeros((units, batch, s_cache, cfg.kv_lora_rank), dtype),
                    v=jnp.zeros((units, batch, s_cache, cfg.qk_rope_head_dim),
                                dtype))
            kv_shape = (units, batch, s_cache, cfg.num_kv_heads, cfg.head_dim)
            if cfg.kv_quant:
                return attn.QuantKVCache(
                    k=jnp.zeros(kv_shape, jnp.int8),
                    v=jnp.zeros(kv_shape, jnp.int8),
                    k_scale=jnp.zeros(kv_shape[:-1], jnp.float32),
                    v_scale=jnp.zeros(kv_shape[:-1], jnp.float32))
            return attn.KVCache(k=jnp.zeros(kv_shape, dtype),
                                v=jnp.zeros(kv_shape, dtype))
        return ssm_lib.SSMState(
            conv_x=jnp.zeros((units, batch, cfg.ssm_conv - 1, cfg.d_inner),
                             dtype),
            conv_B=jnp.zeros((units, batch, cfg.ssm_conv - 1, cfg.ssm_state),
                             dtype),
            conv_C=jnp.zeros((units, batch, cfg.ssm_conv - 1, cfg.ssm_state),
                             dtype),
            ssm=jnp.zeros((units, batch, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), jnp.float32))

    return {f"sub{i}": leaf(kind) for i, kind in enumerate(kinds)}


def cache_length(cfg: ArchConfig, seq_len: int) -> int:
    """Decode-cache length: ring buffer when SWA is active (§Perf lever —
    bounds both memory and per-step attention traffic by the window)."""
    if cfg.window is not None and cfg.window < seq_len:
        return cfg.window
    return seq_len


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
