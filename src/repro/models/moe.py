"""Mixture-of-Experts: top-k router + expert FFNs.

Implementations (cfg.moe_impl):
  * ``dense`` — every expert computes every token, mask-combined.  Exact
    oracle used by smoke tests and as the numerical reference; FLOPs are
    E/k-fold inflated, so never used for roofline numbers.
  * ``gmm``   — grouped matmul: tokens are sorted by expert and processed
    with ``jax.lax.ragged_dot`` against stacked expert weights (the
    megablocks/MaxText formulation; on TPU this lowers to the grouped MXU
    matmul).  Default for training and the dry-run: HLO FLOPs reflect only
    *activated* experts.
  * ``ep_a2a`` — expert-parallel shard_map with fixed-capacity all_to_all
    (see sharding/ep.py); a §Perf lever wired in by the launcher.

Router: softmax over experts, top-k, renormalized among the chosen k
(Qwen3/Mixtral convention), plus the standard load-balance auxiliary loss
(Switch: E * sum_e f_e * P_e) surfaced to the trainer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import he_init


def moe_init(key, cfg: ArchConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": he_init(ks[0], (d, e), dtype),
        "wi_gate": (jax.random.normal(ks[1], (e, d, f)) * (2.0 / d) ** 0.5).astype(dtype),
        "wi_up": (jax.random.normal(ks[2], (e, d, f)) * (2.0 / d) ** 0.5).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d)) * (2.0 / f) ** 0.5).astype(dtype),
    }


def router_topk(params, x_flat: jnp.ndarray, cfg: ArchConfig):
    """x_flat [T, d] -> (probs [T, k], idx [T, k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(probs_full, cfg.top_k)
    probs = probs / jnp.maximum(jnp.sum(probs, -1, keepdims=True), 1e-9)
    # Switch-style load-balance loss.
    e = cfg.num_experts
    frac_tokens = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e), axis=1), axis=0)       # f_e
    frac_probs = jnp.mean(probs_full, axis=0)                  # P_e
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return probs.astype(x_flat.dtype), idx, aux


def _expert_ffn_dense(params, x_flat, probs, idx, cfg: ArchConfig):
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    gate = jnp.einsum("td,edf->tef", x_flat, params["wi_gate"])
    up = jnp.einsum("td,edf->tef", x_flat, params["wi_up"])
    h = act(gate) * up
    y_all = jnp.einsum("tef,efd->ted", h, params["wo"])        # [T, E, d]
    combine = jnp.zeros((x_flat.shape[0], cfg.num_experts), x_flat.dtype)
    combine = jax.vmap(lambda c, p, i: c.at[i].add(p))(combine, probs, idx)
    return jnp.einsum("te,ted->td", combine, y_all)


def _expert_ffn_gmm(params, x_flat, probs, idx, cfg: ArchConfig):
    t, d = x_flat.shape
    k, e = cfg.top_k, cfg.num_experts
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    flat_expert = idx.reshape(-1)                              # [T*k]
    order = jnp.argsort(flat_expert)                           # stable
    token_of = order // k
    x_sorted = x_flat[token_of]                                # [T*k, d]
    group_sizes = jnp.bincount(flat_expert, length=e).astype(jnp.int32)
    gate = jax.lax.ragged_dot(x_sorted, params["wi_gate"], group_sizes)
    up = jax.lax.ragged_dot(x_sorted, params["wi_up"], group_sizes)
    h = act(gate) * up
    y = jax.lax.ragged_dot(h, params["wo"], group_sizes)       # [T*k, d]
    p_sorted = probs.reshape(-1)[order][:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[token_of].add(y * p_sorted)
    return out.astype(x_flat.dtype)


def moe_apply(params, x: jnp.ndarray, cfg: ArchConfig,
              impl: str | None = None):
    """x [B, S, d] -> (y [B, S, d], aux_loss)."""
    impl = impl or cfg.moe_impl
    if impl == "ep_a2a":
        # routing happens inside the shard_map block (per data shard)
        from repro.sharding.ep import moe_apply_ep_a2a
        return moe_apply_ep_a2a(params, x, cfg)
    b, s, d = x.shape
    x_flat = x.reshape(-1, d)
    probs, idx, aux = router_topk(params, x_flat, cfg)
    if impl == "dense":
        y = _expert_ffn_dense(params, x_flat, probs, idx, cfg)
    elif impl == "gmm":
        y = _expert_ffn_gmm(params, x_flat, probs, idx, cfg)
    else:
        raise ValueError(f"unknown moe_impl {impl!r}")
    return y.reshape(b, s, d), aux
