"""Encoder-decoder backbone (whisper-tiny).

The mel-spectrogram + conv feature extractor is the sanctioned frontend
stub: ``batch["frames"]`` carries precomputed frame embeddings
[B, encoder_seq, d] (input_specs provides them).  Encoder = bidirectional
attention blocks; decoder = causal self-attention + cross-attention + MLP,
scanned over layers.  Decode caches: per-layer self KV plus the encoder
cross K/V projected once at prefill.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (embed, embedding_init, mlp_apply, mlp_init,
                                 rmsnorm, rmsnorm_init, unembed)

PyTree = Any


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.gqa_init(k1, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def _init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "self_attn": attn.gqa_init(k1, cfg, dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "cross_attn": attn.cross_attn_init(k2, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)}


def init_params(key, cfg: ArchConfig) -> PyTree:
    dtype = _dtype(cfg)
    ke, kd, kt = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.encoder_layers)
    dec_keys = jax.random.split(kd, cfg.num_layers)
    return {
        "embed": embedding_init(kt, cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(enc_keys),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dec_keys),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def encode(params, frames: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    x = frames.astype(_dtype(cfg))

    def body(x, p):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        q, k, v = attn._project_qkv(p["attn"], h, cfg, positions)
        mask = jnp.ones((1, 1, 1, t, t), bool)                # bidirectional
        out = attn._sdpa(q, k, v, mask)
        x = x + jnp.einsum("bse,ed->bsd", out.reshape(b, t, -1), p["attn"]["wo"])
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
        return x, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
    else:
        for i in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_layers"]))
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def cross_kv(params, enc_out: jnp.ndarray, cfg: ArchConfig) -> attn.KVCache:
    """Project encoder output to per-decoder-layer K/V (done once)."""
    def body(_, p):
        return None, attn.encode_kv(p["cross_attn"], enc_out, cfg)
    if cfg.scan_layers:
        _, kv = jax.lax.scan(body, None, params["dec_layers"])
        return kv                                              # [L, B, T, kv, d]
    kvs = [body(None, jax.tree.map(lambda a: a[i], params["dec_layers"]))[1]
           for i in range(cfg.num_layers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)


def _dec_layer_forward(p, x, cfg, positions, enc_kv_l):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    out, cache = attn.gqa_forward(p["self_attn"], h, cfg, positions)
    x = x + out
    h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
    x = x + attn.cross_attn(p["cross_attn"], h, enc_kv_l, cfg)
    x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
    return x, cache


def forward(params, batch: dict, cfg: ArchConfig):
    """Teacher-forced training / prefill.

    batch: {"frames": [B,T,d], "tokens": [B,S]}.
    Returns (logits, {"self": caches, "cross": enc_kv}, aux=0).
    """
    enc_out = encode(params, batch["frames"], cfg)
    enc_kv = cross_kv(params, enc_out, cfg)
    x = embed(params["embed"], batch["tokens"], cfg.embed_scale)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, inp):
        p, kv_l = inp
        x, cache = _dec_layer_forward(p, x, cfg, positions, kv_l)
        return x, cache

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, (params["dec_layers"], enc_kv))
    else:
        cs = []
        for i in range(cfg.num_layers):
            x, c = body(x, (jax.tree.map(lambda a: a[i], params["dec_layers"]),
                            jax.tree.map(lambda a: a[i], enc_kv)))
            cs.append(c)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {"self": caches, "cross": enc_kv}, jnp.zeros((), jnp.float32)


def decode_step(params, caches, tokens: jnp.ndarray, pos, cfg: ArchConfig,
                cache_mode: str = "full"):
    """One decoder token; attends to the full self cache + encoder memory."""
    x = embed(params["embed"], tokens, cfg.embed_scale)
    b = x.shape[0]

    def body(x, inp):
        p, cache_l, kv_l = inp
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        out, new_cache = attn.gqa_decode(p["self_attn"], h, cache_l, pos, cfg,
                                         cache_mode)
        x = x + out
        h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        x = x + attn.cross_attn(p["cross_attn"], h, kv_l, cfg)
        x = x + mlp_apply(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
        return x, new_cache

    if cfg.scan_layers:
        x, new_self = jax.lax.scan(body, x,
                                   (params["dec_layers"], caches["self"],
                                    caches["cross"]))
    else:
        cs = []
        for i in range(cfg.num_layers):
            x, c = body(x, (jax.tree.map(lambda a: a[i], params["dec_layers"]),
                            jax.tree.map(lambda a: a[i], caches["self"]),
                            jax.tree.map(lambda a: a[i], caches["cross"])))
            cs.append(c)
        new_self = jax.tree.map(lambda *xs: jnp.stack(xs), *cs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)
    return logits, {"self": new_self, "cross": caches["cross"]}


def init_cache(cfg: ArchConfig, batch: int, s_cache: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    t = cfg.encoder_seq
    return {
        "self": attn.KVCache(
            k=jnp.zeros((L, batch, s_cache, kv, hd), dtype),
            v=jnp.zeros((L, batch, s_cache, kv, hd), dtype)),
        "cross": attn.KVCache(
            k=jnp.zeros((L, batch, t, kv, hd), dtype),
            v=jnp.zeros((L, batch, t, kv, hd), dtype)),
    }
