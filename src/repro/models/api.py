"""Unified model API over all 10 architectures, plus the ignorance-weighted
loss that makes every backbone a WST-capable ASCII agent (Algorithm 2: the
per-sample ignorance score enters the train step as ``batch['sample_weight']``).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, transformer

PyTree = Any


def is_encdec(cfg: ArchConfig) -> bool:
    return cfg.cross_attention


def init_params(key, cfg: ArchConfig) -> PyTree:
    return (encdec if is_encdec(cfg) else transformer).init_params(key, cfg)


def forward(params, batch, cfg: ArchConfig):
    return (encdec if is_encdec(cfg) else transformer).forward(params, batch, cfg)


def decode_step(params, caches, tokens, pos, cfg: ArchConfig,
                cache_mode: str = "full"):
    return (encdec if is_encdec(cfg) else transformer).decode_step(
        params, caches, tokens, pos, cfg, cache_mode)


def init_cache(cfg: ArchConfig, batch: int, s_cache: int, dtype=None):
    return (encdec if is_encdec(cfg) else transformer).init_cache(
        cfg, batch, s_cache, dtype)


def cache_length(cfg: ArchConfig, seq_len: int) -> int:
    return transformer.cache_length(cfg, seq_len)


def pad_prefill_cache(caches, cfg: ArchConfig, s_cache: int):
    """Grow the prefill caches (length = prompt) to decode capacity.

    KV caches are padded along the sequence axis (axis 2 in the scanned
    [U, B, S, ...] layout); SSM recurrent states are O(1) and pass through.
    The whisper cross K/V is encoder-length and also passes through.
    """
    from repro.models.attention import KVCache, QuantKVCache, quantize_kv
    from repro.models.ssm import SSMState

    def pad_axis2(a):
        if a.shape[2] >= s_cache:
            return a
        widths = [(0, 0)] * a.ndim
        widths[2] = (0, s_cache - a.shape[2])
        return jnp.pad(a, widths)

    def walk(node, key=None):
        if isinstance(node, QuantKVCache):
            return QuantKVCache(*(pad_axis2(a) for a in node))
        if isinstance(node, KVCache):
            if key == "cross":
                return node
            return KVCache(pad_axis2(node.k), pad_axis2(node.v))
        if isinstance(node, SSMState):
            return node
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        raise TypeError(type(node))

    return walk(caches)


def count_params(params) -> int:
    return transformer.count_params(params)


# ------------------------------------------------------------------ loss
def weighted_next_token_loss(logits: jnp.ndarray, batch: dict,
                             cfg: ArchConfig) -> jnp.ndarray:
    """Ignorance-weighted next-token cross-entropy.

    ``batch['sample_weight']`` [B] is the ASCII ignorance score w_t for each
    collated sample (sequence); defaults to uniform.  For VLM archs the
    frontend positions are stripped before the shift; loss is on text only.
    """
    tokens = batch["tokens"]
    if cfg.frontend == "vision" and "patch_emb" in batch:
        logits = logits[:, batch["patch_emb"].shape[1]:, :]
    pred = logits[:, :-1, :].astype(jnp.float32)   # upcast fuses into reductions
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(pred, axis=-1)
    # gold logit via one-hot contraction, not take_along_axis: a gather over
    # a model-sharded vocab axis would force an all-gather of the logits;
    # the contraction keeps the reduction local + one small all-reduce.
    onehot = jax.nn.one_hot(targets, pred.shape[-1], dtype=pred.dtype)
    gold = jnp.einsum("bsv,bsv->bs", pred, onehot)
    nll = logz - gold                                          # [B, S-1]
    tok_mask = batch.get("loss_mask")
    if tok_mask is None:
        tok_mask = jnp.ones_like(nll)
    else:
        tok_mask = tok_mask[:, 1:].astype(nll.dtype)
    w = batch.get("sample_weight")
    if w is None:
        w = jnp.ones((tokens.shape[0],), nll.dtype)
    w = w[:, None] * tok_mask
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1e-9)


# ------------------------------------------------------------ step builders
def make_train_step(cfg: ArchConfig, optimizer) -> Callable:
    """(params, opt_state, batch, step) -> (params, opt_state, metrics)."""

    def loss_fn(p, mb):
        logits, _, aux = forward(p, mb, cfg)
        loss = weighted_next_token_loss(logits, mb, cfg)
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux
        return loss, aux

    def train_step(params, opt_state, batch, step):
        m = cfg.microbatches
        if m <= 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # gradient accumulation: peak activation memory scales with
            # B/m while the optimizer update stays per-global-batch.
            mbs = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def body(carry, mb):
                gsum, lsum, asum = carry
                (l, a), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l, asum + a), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (gsum, lsum, asum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss, aux = lsum / m, asum / m
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, {"loss": loss, "aux_loss": aux}

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        logits, caches, _ = forward(params, batch, cfg)
        return logits[:, -1:, :], caches
    return prefill_step


def make_serve_step(cfg: ArchConfig, cache_mode: str = "full") -> Callable:
    """One decode step: greedy next token given the running cache."""

    def serve_step(params, caches, tokens, pos):
        logits, caches = decode_step(params, caches, tokens, pos, cfg,
                                     cache_mode)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, caches

    return serve_step


def quantize_cache(caches, cfg: ArchConfig):
    """Convert a prefill KVCache tree to int8 (kv_quant serving path)."""
    from repro.models.attention import KVCache, QuantKVCache, quantize_kv
    from repro.models.ssm import SSMState

    def walk(node, key=None):
        if isinstance(node, KVCache):
            if key == "cross" or cfg.attention == "mla":
                return node
            kq, ks = quantize_kv(node.k)
            vq, vs = quantize_kv(node.v)
            return QuantKVCache(kq, vq, ks, vs)
        if isinstance(node, SSMState):
            return node
        if isinstance(node, dict):
            return {k: walk(v, k) for k, v in node.items()}
        raise TypeError(type(node))

    return walk(caches)
