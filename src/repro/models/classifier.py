"""Sequence classifier head over any backbone: mean-pooled final hidden
states -> K-class logits.  This is what turns an assigned architecture into
an ASCII agent's model class F_0^(m) (DESIGN.md §2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.layers import he_init, rmsnorm


def init_params(key, cfg: ArchConfig, num_classes: int):
    k1, k2 = jax.random.split(key)
    params = transformer.init_params(k1, cfg)
    params["cls_head"] = {"w": he_init(k2, (cfg.d_model, num_classes),
                                       jnp.dtype(cfg.dtype))}
    return params


def apply(params, batch: dict, cfg: ArchConfig) -> jnp.ndarray:
    """batch {"tokens": [B,S]} (or embeddings) -> class logits [B,K]."""
    x = transformer.embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(carry, unit_params):
        x, aux = carry
        x, _, aux_u = transformer._unit_forward(unit_params, x, cfg, positions)
        return (x, aux + aux_u), None

    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    pooled = jnp.mean(x, axis=1)
    return jnp.einsum("bd,dk->bk", pooled.astype(jnp.float32),
                      params["cls_head"]["w"].astype(jnp.float32))
