"""Blocked online-softmax (flash) attention with causal + sliding-window
masking — Pallas TPU kernel.

TPU adaptation of the FlashAttention idea: instead of warp-level tiling,
blocks are sized to the MXU/VREG geometry — BQ x D and BK x D tiles staged
in VMEM, scores computed as [BQ, BK] MXU matmuls, with the online max/sum
recurrence in f32 VMEM scratch that persists across the (innermost,
sequential) KV grid walk.  Sliding-window support makes this the
sub-quadratic pathway for the long_500k shape on SWA archs: KV tiles wholly
outside the window are predicated away with pl.when, so compute scales with
S*W rather than S^2.

Layouts: q [B, H, S, D]; k/v [B, KV, T, D] with H % KV == 0 (GQA: the KV
head for query head h is h * KV // H).  Queries are right-aligned against
the key axis (offset T - S), matching both prefill (T == S) and
cached-suffix decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 bq: int, bk: int, nk: int, s: int, t: int, causal: bool,
                 window: int | None, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)
    offset = t - s                         # right-aligned queries
    q_lo = qi * bq + offset                # absolute pos of first query row
    q_hi = q_lo + bq - 1
    k_lo = ki * bk

    # Tile-level predication: skip KV tiles fully above the diagonal or
    # fully below the window.
    live = True
    if causal:
        live = k_lo <= q_hi
    if window is not None:
        live = jnp.logical_and(live, k_lo + bk - 1 > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)            # [bk, d]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale    # [bq, bk]
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        scores = jnp.where(mask, scores, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                              "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jnp.ndarray:
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    assert h % kv == 0
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    nq, nk = s // bq, t // bk
    group = h // kv

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, nk=nk, s=s, t=t, causal=causal,
        window=window, scale=1.0 / (d ** 0.5))

    return pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, qi, ki: (bh // h, (bh % h) // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, qi, ki: (bh // h, (bh % h) // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bh, qi, ki: (bh // h, bh % h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running denominator
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
