"""Flash-decode — single-token attention against a long KV cache, with
optional fused int8 dequantization (the kernel-level realization of the
§Perf H3 it2 finding: the XLA path must materialize a dequantized f32 cache
copy, this kernel never does — int8 tiles are dequantized in VMEM registers
between the load and the MXU dot).

One query row per (batch, head); the KV walk is the innermost sequential
grid dimension with the online-softmax recurrence in VMEM scratch.  Tiles
outside the valid range (pos, window) are predicated away, so ring-buffer
SWA decode touches only ceil(W/BK) tiles.

Layouts: q [B, H, D]; k/v [B, KV, S, D] (GQA; int8 when scales given);
k_scale/v_scale [B, KV, S] f32.  Output [B, H, D].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bk: int, nk: int, window: int | None,
            scale: float, quant: bool):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    k_lo = ki * bk
    live = k_lo <= pos
    if window is not None:
        live = jnp.logical_and(live, k_lo + bk - 1 > pos - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)[None, :]          # [1, D]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)                   # [bk, D]
        if quant:
            k = k * ks_ref[0, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, 0].astype(jnp.float32)[:, None]
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale            # [1, bk]
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        valid = cols <= pos
        if window is not None:
            valid &= cols > pos - window
        scores = jnp.where(valid, scores, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom)[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 pos: jnp.ndarray, *, k_scale: jnp.ndarray | None = None,
                 v_scale: jnp.ndarray | None = None,
                 window: int | None = None, bk: int = DEFAULT_BK,
                 interpret: bool = False) -> jnp.ndarray:
    b, h, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    assert h % kv == 0
    group = h // kv
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    nk = s // bk
    quant = k_scale is not None
    if not quant:           # dummy scale operands keep one kernel signature
        k_scale = jnp.ones((b, kv, s), jnp.float32)
        v_scale = jnp.ones((b, kv, s), jnp.float32)
    pos_arr = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (1,))

    kernel = functools.partial(_kernel, bk=bk, nk=nk, window=window,
                               scale=1.0 / (d ** 0.5), quant=quant)
    return pl.pallas_call(
        kernel,
        grid=(b * h, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ki: (0,)),           # pos
            pl.BlockSpec((1, 1, d), lambda bh, ki: (bh // h, bh % h, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, ki: (bh // h, (bh % h) // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bh, ki: (bh // h, (bh % h) // group, ki, 0)),
            pl.BlockSpec((1, 1, bk),
                         lambda bh, ki: (bh // h, (bh % h) // group, ki)),
            pl.BlockSpec((1, 1, bk),
                         lambda bh, ki: (bh // h, (bh % h) // group, ki)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, ki: (bh // h, bh % h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),         # running max
            pltpu.VMEM((1,), jnp.float32),         # running denominator
            pltpu.VMEM((1, d), jnp.float32),       # accumulator
        ],
        interpret=interpret,
    )(pos_arr, q, k, v, k_scale, v_scale)
