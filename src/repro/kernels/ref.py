"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_ce(logits: jnp.ndarray, labels: jnp.ndarray,
                weights: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-token weighted NLL and the log-sum-exp (forward residual).

    logits [T, V] (any float dtype; math in f32), labels [T], weights [T].
    Returns (loss [T], lse [T]).
    """
    x = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(x, axis=-1)
    gold = jnp.take_along_axis(x, labels[:, None], axis=-1)[:, 0]
    return weights * (lse - gold), lse


def weighted_ce_grad(logits: jnp.ndarray, labels: jnp.ndarray,
                     weights: jnp.ndarray, lse: jnp.ndarray,
                     g: jnp.ndarray) -> jnp.ndarray:
    """dL/dlogits for loss_t = w_t * (lse_t - logit_t[label]), scaled by the
    upstream cotangent g [T]."""
    x = logits.astype(jnp.float32)
    probs = jnp.exp(x - lse[:, None])
    onehot = jax.nn.one_hot(labels, x.shape[-1], dtype=jnp.float32)
    return ((weights * g)[:, None] * (probs - onehot)).astype(logits.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True,
                    window: int | None = None) -> jnp.ndarray:
    """Reference attention.  q [B,H,S,D]; k/v [B,KV,T,D] (grouped-query:
    H % KV == 0); returns [B,H,S,D]."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    qg = q.reshape(b, kv, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(d)
    t = k.shape[2]
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos + (t - s)       # right-aligned queries
    if window is not None:
        mask &= k_pos > q_pos + (t - s) - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def ignorance_update(w: jnp.ndarray, r: jnp.ndarray,
                     alpha: jnp.ndarray) -> jnp.ndarray:
    """Eqs. (10)/(12): w * exp(alpha (1 - r)), renormalized."""
    w_new = w * jnp.exp(alpha * (1.0 - r))
    return w_new / jnp.maximum(jnp.sum(w_new), 1e-12)


def quantize_dequant(x: jnp.ndarray, u: jnp.ndarray, qmax,
                     bn: int = 1024):
    """Reference per-tile symmetric quantize-dequant (the wire codec oracle).

    x [n], u [n] in [0,1) (stochastic-rounding draws; 0.5 = round-half-up),
    qmax scalar (127 for int8, 7 for int4).  Tiles of ``bn`` (one global
    tile when bn doesn't divide n — ``quantize.tile_for``, the same rule
    the Pallas kernel applies).  Returns (xhat [n] f32, q [n] int8,
    scales [nt] f32).
    """
    from repro.kernels.quantize import tile_for
    n = x.shape[0]
    bn = tile_for(n, bn)
    nt = n // bn
    qmax = jnp.asarray(qmax, jnp.float32)
    xt = x.astype(jnp.float32).reshape(nt, bn)
    ut = u.astype(jnp.float32).reshape(nt, bn)
    scale = jnp.maximum(jnp.max(jnp.abs(xt), axis=1), 1e-12) / qmax
    q = jnp.clip(jnp.floor(xt / scale[:, None] + ut), -qmax, qmax)
    return ((q * scale[:, None]).reshape(n), q.astype(jnp.int8).reshape(n),
            scale)


def quantize_dequant_block(x: jnp.ndarray, u: jnp.ndarray, qmax,
                           bn: int = 1024):
    """Reference row-major tiled quantize-dequant for [n, k] score blocks.

    The 2-D sibling of :func:`quantize_dequant`: tiles of
    ``quantize.rows_for(n, k, bn)`` rows share one fp32 scale (absmax over
    the [rows, k] slab).  Returns (xhat [n, k] f32, q [n, k] int8,
    scales [nt] f32) — bit-identical to the Pallas block kernel.
    """
    from repro.kernels.quantize import rows_for
    n, k = x.shape
    br = rows_for(n, k, bn)
    nt = n // br
    qmax = jnp.asarray(qmax, jnp.float32)
    xt = x.astype(jnp.float32).reshape(nt, br * k)
    ut = u.astype(jnp.float32).reshape(nt, br * k)
    scale = jnp.maximum(jnp.max(jnp.abs(xt), axis=1), 1e-12) / qmax
    q = jnp.clip(jnp.floor(xt / scale[:, None] + ut), -qmax, qmax)
    return ((q * scale[:, None]).reshape(n, k),
            q.astype(jnp.int8).reshape(n, k), scale)


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """Reference int4 wire packing (the `quantize.pack_int4` oracle): two
    sign-extended nibbles per int8 byte, row-major element order, odd
    element counts padded with a 0 high nibble.  Returns a flat int8 array
    of ceil(numel/2) bytes."""
    flat = q.reshape(-1).astype(jnp.int8)
    if flat.shape[0] % 2:
        flat = jnp.concatenate([flat, jnp.zeros((1,), jnp.int8)])
    pairs = flat.reshape(-1, 2)
    lo = pairs[:, 0] & jnp.int8(0x0F)
    hi = pairs[:, 1] & jnp.int8(0x0F)
    return lo | (hi << 4)


def unpack_int4(packed: jnp.ndarray, n: int) -> jnp.ndarray:
    """Reference inverse of :func:`pack_int4`: n int8-carried int4 values
    (flat), nibbles sign-extended via arithmetic shifts."""
    p = packed.astype(jnp.int8)
    lo = (p << 4) >> 4
    hi = p >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]


def flash_decode(q, k, v, pos, *, k_scale=None, v_scale=None, window=None):
    """Reference single-token attention vs a (possibly int8) cache.

    q [B,H,D]; k/v [B,KV,S,D]; scales [B,KV,S]; returns [B,H,D]."""
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[..., None]
        v = v.astype(jnp.float32) * v_scale[..., None]
    b, h, d = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bktd->bkgt", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(d)
    idx = jnp.arange(s)
    valid = idx <= pos
    if window is not None:
        valid &= idx > pos - window
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
