"""Fused quantize-dequant for wire codecs — Pallas TPU kernel.

The comm subsystem (`repro.comm.codecs`) ships ignorance scores as int8/int4
integers with one fp32 scale per tile.  What the protocol trajectory sees is
the *dequantized* vector — quantize and dequantize back-to-back — so the two
halves fuse into one VMEM pass: per-tile absmax, scale, stochastic round,
clip, and the dequantized product, without materializing the integer wire
array in HBM first.  The integer values and per-tile scales are emitted too
(they ARE the wire format, and the byte ledger prices them).

Stochastic rounding takes the uniform draws as an *input* (``u`` in [0, 1),
``floor(x/scale + u)``) instead of an in-kernel PRNG: the same draws feed the
host reference (`kernels.ref.quantize_dequant`), which keeps kernel-vs-host
bit-identical on every backend and keeps the codec a pure function of its
PRNG key — the property the eager/compiled engine pin rests on.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 1024
_EPS = 1e-12


def tile_for(n: int, bn: int = DEFAULT_BN) -> int:
    """The tile size actually used for a length-n vector: ``bn`` when it
    divides evenly, else one global tile (ragged tails would complicate the
    grid for no win at protocol sizes).  The host reference uses the same
    rule, so kernel and reference always agree on the scale granularity."""
    return bn if (n >= bn and n % bn == 0) else n


def rows_for(n: int, k: int, bn: int = DEFAULT_BN) -> int:
    """Row tile for an [n, k] row-major block (ScoreBlockMsg payloads):
    keep the per-scale granularity at ~``bn`` elements by tiling
    ``bn // k`` rows when that divides n evenly, else one global tile —
    the same degenerate rule as :func:`tile_for`, shared with the host
    reference so kernel and reference agree on scale boundaries."""
    return tile_for(n, max(1, bn // k))


def _kernel(qmax_ref, x_ref, u_ref, xhat_ref, q_ref, scale_ref):
    qmax = qmax_ref[0]
    x = x_ref[...]
    scale = jnp.maximum(jnp.max(jnp.abs(x)), _EPS) / qmax
    q = jnp.clip(jnp.floor(x / scale + u_ref[...]), -qmax, qmax)
    xhat_ref[...] = q * scale
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[0] = scale


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def quantize_dequant_tiles(x: jnp.ndarray, u: jnp.ndarray,
                           qmax: jnp.ndarray, *, bn: int = DEFAULT_BN,
                           interpret: bool = False):
    """Per-tile symmetric quantization of a length-n vector.

    Returns ``(xhat [n] f32, q [n] int8, scales [n/bn] f32)`` where
    ``xhat = q * scale`` and ``q = clip(floor(x/scale + u), -qmax, qmax)``
    with ``scale = max(|x_tile|)/qmax``.  ``u`` in [0, 1) selects the
    rounding mode: uniform draws give unbiased stochastic rounding, a
    constant 0.5 gives round-half-up.  ``qmax`` may be a traced scalar
    (e.g. 127 for int8, 7 for int4) so codec sweeps can vmap over it.
    """
    n = x.shape[0]
    bn = tile_for(n, bn)
    nt = n // bn
    qmax_arr = jnp.broadcast_to(jnp.asarray(qmax, jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # qmax (replicated)
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int8),
            jax.ShapeDtypeStruct((nt,), jnp.float32),
        ],
        interpret=interpret,
    )(qmax_arr, x.astype(jnp.float32), u.astype(jnp.float32))


# ------------------------------------------------------------- int4 packing
def _pack_kernel(q_ref, p_ref):
    # two int4 values (int8 carrier, [-8, 7]) per output byte: element 2i in
    # the low nibble, 2i+1 in the high nibble
    pairs = q_ref[...].reshape(-1, 2)
    lo = pairs[:, 0] & jnp.int8(0x0F)
    hi = pairs[:, 1] & jnp.int8(0x0F)
    p_ref[...] = lo | (hi << 4)


def _unpack_kernel(p_ref, q_ref):
    p = p_ref[...]
    lo = (p << 4) >> 4                 # arithmetic shifts sign-extend the
    hi = p >> 4                        # nibbles back to int8 [-8, 7]
    q_ref[...] = jnp.stack([lo, hi], axis=-1).reshape(-1)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def pack_int4(q: jnp.ndarray, *, bn: int = DEFAULT_BN,
              interpret: bool = False) -> jnp.ndarray:
    """Pack int4 values carried in an int8 array into real 4-bit wire bytes.

    ``q`` is any-shape int8 holding values in [-8, 7] (the int4 codec emits
    [-7, 7]); the result is a flat int8 array of ``ceil(numel/2)`` bytes,
    two sign-extended nibbles per byte in row-major element order (odd
    element counts pad the trailing high nibble with 0).  The inverse is
    :func:`unpack_int4`; the pair is pinned bit-identical to the host
    reference (`kernels.ref.pack_int4`/``unpack_int4``) and exactly
    round-trips every carrier value.
    """
    flat = q.reshape(-1).astype(jnp.int8)
    m = flat.shape[0]
    if m % 2:
        flat = jnp.concatenate([flat, jnp.zeros((1,), jnp.int8)])
    mp = flat.shape[0] // 2
    tp = tile_for(mp, bn)
    return pl.pallas_call(
        _pack_kernel,
        grid=(mp // tp,),
        in_specs=[pl.BlockSpec((2 * tp,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.int8),
        interpret=interpret,
    )(flat)


@functools.partial(jax.jit, static_argnames=("n", "bn", "interpret"))
def unpack_int4(packed: jnp.ndarray, n: int, *, bn: int = DEFAULT_BN,
                interpret: bool = False) -> jnp.ndarray:
    """Unpack :func:`pack_int4` wire bytes back to ``n`` int8-carried int4
    values (flat; callers reshape)."""
    mp = packed.shape[0]
    if mp != (n + 1) // 2:
        raise ValueError(f"{mp} packed bytes cannot hold {n} int4 values")
    tp = tile_for(mp, bn)
    out = pl.pallas_call(
        _unpack_kernel,
        grid=(mp // tp,),
        in_specs=[pl.BlockSpec((tp,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2 * tp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((2 * mp,), jnp.int8),
        interpret=interpret,
    )(packed.astype(jnp.int8))
    return out[:n]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def quantize_dequant_block(x: jnp.ndarray, u: jnp.ndarray,
                           qmax: jnp.ndarray, *, bn: int = DEFAULT_BN,
                           interpret: bool = False):
    """Row-major tiled quantization of an [n, k] score block.

    The 2-D sibling of :func:`quantize_dequant_tiles` for prediction-time
    ScoreBlockMsg payloads: tiles of ``rows_for(n, k, bn)`` rows share one
    fp32 scale (per-tile absmax over the whole [rows, k] slab), reusing the
    exact same kernel body — per-tile absmax, stochastic round, clip,
    dequantized product in one VMEM pass.  Returns
    ``(xhat [n, k] f32, q [n, k] int8, scales [n/rows] f32)``.
    """
    n, k = x.shape
    br = rows_for(n, k, bn)
    nt = n // br
    qmax_arr = jnp.broadcast_to(jnp.asarray(qmax, jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # qmax (replicated)
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((br, k), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, k), jnp.float32),
            jax.ShapeDtypeStruct((n, k), jnp.int8),
            jax.ShapeDtypeStruct((nt,), jnp.float32),
        ],
        interpret=interpret,
    )(qmax_arr, x.astype(jnp.float32), u.astype(jnp.float32))
