"""Fused ignorance-score update (paper eqs. 10/12) — Pallas TPU kernel.

The interchange hot-path op: w * exp(alpha * (1 - r)) fused with the
partial-sum reduction for the renormalization, one VMEM pass over the
length-n score vector instead of three HBM round-trips (mul, exp, sum).
The final scalar divide happens in the jitted wrapper (ops.py) after the
cross-device psum — the normalizer must be global across the data-sharded
score anyway, so the kernel emits per-tile partial sums.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 1024


def tiles_evenly(n: int, bn: int = DEFAULT_BN) -> bool:
    """Whether a length-n score tiles the kernel grid — the single
    eligibility predicate shared by the eager MeshRingTransport and the
    compiled backend's reweight choice, so the two can never drift."""
    return n % min(bn, n) == 0


def _kernel(alpha_ref, w_ref, r_ref, out_ref, psum_ref):
    alpha = alpha_ref[0]
    w_new = w_ref[...] * jnp.exp(alpha * (1.0 - r_ref[...]))
    out_ref[...] = w_new
    psum_ref[0] = jnp.sum(w_new)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def ignorance_update_unnormalized(w: jnp.ndarray, r: jnp.ndarray,
                                  alpha: jnp.ndarray, *,
                                  bn: int = DEFAULT_BN,
                                  interpret: bool = False):
    """Returns (w * exp(alpha(1-r)) [n], per-tile partial sums [n/bn])."""
    n = w.shape[0]
    bn = min(bn, n)
    assert n % bn == 0, (n, bn)
    nt = n // bn
    alpha_arr = jnp.broadcast_to(alpha.astype(jnp.float32), (1,))
    return pl.pallas_call(
        _kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # alpha (replicated)
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((nt,), jnp.float32),
        ],
        interpret=interpret,
    )(alpha_arr, w.astype(jnp.float32), r.astype(jnp.float32))
