"""Fused ignorance-weighted softmax cross-entropy — Pallas TPU kernel.

Motivation (DESIGN.md §2): for large-vocab archs (gemma-7b V=256k) the
[T, V] logits tensor dominates loss-path HBM traffic.  The unfused XLA path
materializes softmax intermediates and reads the logits twice (lse + gather);
this kernel streams each logits row tile-by-tile through VMEM once,
computing the online max/denominator and the gold-logit gather in the same
pass, with the ASCII sample weight fused into the final scale.  The backward
kernel recomputes probabilities from the saved LSE (flash-style residual)
instead of storing them.

Grid: (T/BT, V/BV), V innermost => the VMEM scratch (running max m, running
sum l, gold accumulator) persists across the V walk of one row tile.
Block shapes are (BT, BV) with BV a multiple of 128 (lane width) and BT a
multiple of 8 (sublane), so loads hit the VREG tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 128
DEFAULT_BV = 512


def _fwd_kernel(labels_ref, weights_ref, logits_ref, loss_ref, lse_ref,
                m_ref, l_ref, gold_ref, *, bv: int, nv: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)

    x = logits_ref[...].astype(jnp.float32)              # [bt, bv]
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    # rescale the running denominator, add this tile's contribution
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(
        jnp.exp(x - m_new[:, None]), axis=-1)
    m_ref[...] = m_new
    # gold logit: the label column may fall inside this tile
    cols = vi * bv + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    hit = cols == labels_ref[...][:, None]
    gold_ref[...] += jnp.sum(jnp.where(hit, x, 0.0), axis=-1)

    @pl.when(vi == nv - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(l_ref[...])
        lse_ref[...] = lse
        loss_ref[...] = weights_ref[...] * (lse - gold_ref[...])


@functools.partial(jax.jit, static_argnames=("bt", "bv", "interpret"))
def weighted_ce_fwd(logits: jnp.ndarray, labels: jnp.ndarray,
                    weights: jnp.ndarray, *, bt: int = DEFAULT_BT,
                    bv: int = DEFAULT_BV, interpret: bool = False):
    t, v = logits.shape
    bt = min(bt, t)
    bv = min(bv, v)
    assert t % bt == 0 and v % bv == 0, (t, v, bt, bv)
    nt, nv = t // bt, v // bv
    loss, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, bv=bv, nv=nv),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),           # labels
            pl.BlockSpec((bt,), lambda i, j: (i,)),           # weights
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),      # logits
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t,), jnp.float32),          # loss
            jax.ShapeDtypeStruct((t,), jnp.float32),          # lse
        ],
        scratch_shapes=[
            pltpu.VMEM((bt,), jnp.float32),                   # running max
            pltpu.VMEM((bt,), jnp.float32),                   # running sum
            pltpu.VMEM((bt,), jnp.float32),                   # gold logit
        ],
        interpret=interpret,
    )(labels, weights, logits)
    return loss, lse


def _bwd_kernel(labels_ref, wg_ref, lse_ref, logits_ref, dlogits_ref, *,
                bv: int):
    vi = pl.program_id(1)
    x = logits_ref[...].astype(jnp.float32)
    probs = jnp.exp(x - lse_ref[...][:, None])
    cols = vi * bv + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    onehot = (cols == labels_ref[...][:, None]).astype(jnp.float32)
    dlogits_ref[...] = (wg_ref[...][:, None] * (probs - onehot)
                        ).astype(dlogits_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "bv", "interpret"))
def weighted_ce_bwd(logits, labels, weights, lse, g, *, bt: int = DEFAULT_BT,
                    bv: int = DEFAULT_BV, interpret: bool = False):
    t, v = logits.shape
    bt = min(bt, t)
    bv = min(bv, v)
    nt, nv = t // bt, v // bv
    wg = (weights * g).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, bv=bv),
        grid=(nt, nv),
        in_specs=[
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt,), lambda i, j: (i,)),
            pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bt, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, v), logits.dtype),
        interpret=interpret,
    )(labels, wg, lse, logits)
