"""Jit'd public wrappers for the Pallas kernels.

On this CPU build box kernels run in interpret mode (the Pallas body
executed in Python); on TPU pass interpret=False (default resolves by
backend).  ``weighted_ce`` wires the forward/backward kernels into a
custom_vjp so the fused loss is a drop-in for training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import flash_decode as _fd
from repro.kernels import ignorance as _ig
from repro.kernels import weighted_ce as _wce


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------------ weighted CE
@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def weighted_ce(logits, labels, weights, interpret: bool | None = None):
    """Per-token ignorance-weighted NLL [T] (fused Pallas kernel)."""
    interp = _default_interpret() if interpret is None else interpret
    loss, _ = _wce.weighted_ce_fwd(logits, labels, weights, interpret=interp)
    return loss


def _wce_fwd(logits, labels, weights, interpret):
    interp = _default_interpret() if interpret is None else interpret
    loss, lse = _wce.weighted_ce_fwd(logits, labels, weights, interpret=interp)
    return loss, (logits, labels, weights, lse)


def _wce_bwd(interpret, res, g):
    logits, labels, weights, lse = res
    interp = _default_interpret() if interpret is None else interpret
    dlogits = _wce.weighted_ce_bwd(logits, labels, weights, lse, g,
                                   interpret=interp)
    return dlogits, None, None


weighted_ce.defvjp(_wce_fwd, _wce_bwd)


# --------------------------------------------------------- flash attention
def flash_attention(q, k, v, *, causal=True, window=None,
                    interpret: bool | None = None):
    interp = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=interp)


# --------------------------------------------------------- ignorance update
def ignorance_update(w, r, alpha, *, axis_name: str | None = None,
                     interpret: bool | None = None):
    """Fused eqs. (10)/(12).  Under shard_map pass axis_name to make the
    normalizer global across the data-sharded score vector."""
    interp = _default_interpret() if interpret is None else interpret
    w_new, psums = _ig.ignorance_update_unnormalized(w, r, alpha,
                                                     interpret=interp)
    total = jnp.sum(psums)
    if axis_name is not None:
        total = jax.lax.psum(total, axis_name)
    return w_new / jnp.maximum(total, 1e-12)


def quantize_dequant(x, u, qmax, *, bn: int = 1024,
                     interpret: bool | None = None):
    """Fused per-tile quantize-dequant for wire codecs (repro.comm.codecs):
    returns (dequantized [n], int8 wire values [n], per-tile scales)."""
    interp = _default_interpret() if interpret is None else interpret
    from repro.kernels import quantize as _q
    return _q.quantize_dequant_tiles(x, u, qmax, bn=bn, interpret=interp)


def quantize_dequant_block(x, u, qmax, *, bn: int = 1024,
                           interpret: bool | None = None):
    """Row-major tiled quantize-dequant for [n, k] score blocks (the
    prediction-time ScoreBlockMsg wire codec): returns (dequantized [n, k],
    int8 wire values [n, k], per-row-tile scales)."""
    interp = _default_interpret() if interpret is None else interpret
    from repro.kernels import quantize as _q
    return _q.quantize_dequant_block(x, u, qmax, bn=bn, interpret=interp)


def pack_int4(q, *, bn: int = 1024, interpret: bool | None = None):
    """Pack int8-carried int4 values into real 4-bit wire bytes: two
    sign-extended nibbles per int8 byte (flat, ceil(numel/2) long) — the
    int4 codec's actual wire array (repro.comm.codecs)."""
    interp = _default_interpret() if interpret is None else interpret
    from repro.kernels import quantize as _q
    return _q.pack_int4(q, bn=bn, interpret=interp)


def unpack_int4(packed, n: int, *, bn: int = 1024,
                interpret: bool | None = None):
    """Inverse of :func:`pack_int4`: n int8-carried int4 values (flat)."""
    interp = _default_interpret() if interpret is None else interpret
    from repro.kernels import quantize as _q
    return _q.unpack_int4(packed, n, bn=bn, interpret=interp)


def flash_decode(q, k, v, pos, *, k_scale=None, v_scale=None, window=None,
                 interpret: bool | None = None):
    """Single-token flash attention vs a long (optionally int8) KV cache."""
    interp = _default_interpret() if interpret is None else interpret
    return _fd.flash_decode(q, k, v, pos, k_scale=k_scale, v_scale=v_scale,
                            window=window, interpret=interp)
