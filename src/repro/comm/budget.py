"""Bit budgets: the byte ledger as an *active* constraint, not accounting.

PR 1 made every message meterable; this module makes the meter enforceable.
A :class:`BudgetSpec` caps how many bits a session (and optionally each
directed src->dst link) may spend, and :class:`BudgetedTransport` enforces
it per hop with a two-stage response:

  1. **degrade** — walk the codec ladder (best-first) and ship the hop with
     the first codec whose wire cost still fits the remaining budget;
  2. **defer/skip** — when not even the cheapest codec fits, the hop is
     dropped: the receiving agent proceeds with its stale ignorance score
     (the fit and its boosting component still happen — only the score
     transfer is lost).  A skip caused by the *session* budget marks the
     transport ``exhausted``, and the engine stops scheduling further
     rounds (``Session.step`` checks it at round entry) — budget-aware
     round scheduling.

The same ladder walk runs inside the compiled session scan
(`core/compiled.py` carries spent-bit counters through the ``lax.scan``),
so eager and compiled budgeted runs pick identical codecs hop for hop and
book identical ledgers.

Ladder codecs must be stateless (error-feedback residuals can't migrate
between codecs mid-run); setup messages (labels/sample IDs) count against
the session budget, interchange hops against both session and link budgets.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.comm.codecs import Codec, Fp16Codec, Fp32Codec, QuantCodec
from repro.core.engine import MeteredTransport

#: The scalar ModelWeightMsg that accompanies every shipped hop.
MODEL_WEIGHT_BITS = 32

DEFAULT_LADDER = (Fp32Codec(), Fp16Codec(), QuantCodec(bits=8),
                  QuantCodec(bits=4))


@dataclass(frozen=True)
class BudgetSpec:
    """Bit caps plus the degradation ladder (best codec first).

    ``session_bits`` caps everything the transport books; ``link_bits`` caps
    each directed (src, dst) interchange link.  Either may be None
    (uncapped).  Hashable frozen dataclass — a valid jit static argument,
    so the compiled backend takes it verbatim."""
    session_bits: int | None = None
    link_bits: int | None = None
    ladder: tuple = DEFAULT_LADDER

    def __post_init__(self):
        if not self.ladder:
            raise ValueError("budget ladder must hold at least one codec")
        for c in self.ladder:
            if not isinstance(c, Codec) or c.stateful:
                raise ValueError(
                    f"budget ladder entries must be stateless Codecs, got "
                    f"{c!r} (error-feedback state cannot migrate between "
                    f"ladder rungs)")
        for cap in (self.session_bits, self.link_bits):
            if cap is not None and cap <= 0:
                raise ValueError(f"budget caps must be positive, got {cap}")

    def hop_costs(self, n: int) -> tuple:
        """Per-ladder-rung cost of one hop for a length-n score: the encoded
        IgnoranceMsg plus the scalar ModelWeightMsg."""
        return tuple(c.wire_bits(n) + MODEL_WEIGHT_BITS for c in self.ladder)

    def payload_costs(self, shape) -> tuple:
        """Per-ladder-rung encoded size of one bare payload of ``shape`` —
        no accompanying ModelWeightMsg: serve-path ScoreBlockMsgs and
        protocol-variant traffic (GradientMsg / ResidualMsg) alike."""
        return tuple(c.wire_bits(shape) for c in self.ladder)

    def serve_costs(self, shape) -> tuple:
        """Per-ladder-rung cost of one prediction-time ScoreBlockMsg for an
        [n, K] block — no accompanying ModelWeightMsg on the serve path."""
        return self.payload_costs(shape)

    def choose_costs(self, costs, remaining_session: float,
                     remaining_link: float, floor: int = 0) -> int | None:
        """First ladder index affordable under both remaining budgets, or
        None when the hop must be skipped — the single decision rule both
        engine backends implement, for training hops and serve blocks
        alike.  ``floor`` is the adaptive controller's rung (the walk never
        picks a *finer* rung than the policy asked for; the budget may
        still degrade past it — ladder costs descend, so the floor never
        changes when a hop is skippable)."""
        remaining = min(remaining_session, remaining_link)
        for i in range(floor, len(costs)):
            if costs[i] <= remaining:
                return i
        return None

    def choose(self, n: int, remaining_session: float,
               remaining_link: float, floor: int = 0) -> int | None:
        """:meth:`choose_costs` over the training-hop cost table."""
        return self.choose_costs(self.hop_costs(n), remaining_session,
                                 remaining_link, floor)


@dataclass
class TenantBudget:
    """A per-tenant view of serve-traffic spend: one running bit ledger per
    tenant against an optional cap, shared across every session and request
    that tenant submits.

    :class:`BudgetSpec` caps one *session*; a serve fleet fields a stream
    of requests from many tenants against many sessions, and the admission
    layer (:mod:`repro.serve.admission`) needs a per-tenant aggregate to
    gate on *before* any work is done.  ``charge`` books the encoded bits a
    request actually shipped (the same numbers the transport ledger prices),
    so the view and the ledger can never drift."""
    bits: int | None = None         # cap; None = uncapped
    spent: int = 0

    def __post_init__(self):
        if self.bits is not None and self.bits <= 0:
            raise ValueError(f"tenant bit cap must be positive, got "
                             f"{self.bits}")

    @property
    def remaining(self) -> float:
        return math.inf if self.bits is None else self.bits - self.spent

    def affordable(self, cost: int) -> bool:
        return cost <= self.remaining

    def charge(self, bits: int) -> None:
        if isinstance(bits, bool) or not isinstance(bits, int):
            raise TypeError(f"bits must be an integer, got {bits!r}")
        if bits < 0:
            raise ValueError(f"bits must be >= 0, got {bits}")
        self.spent += bits


class BudgetedTransport(MeteredTransport):
    """Byte-metered transport that *enforces* a :class:`BudgetSpec` —
    degrade down the codec ladder, then defer/skip hops (see module
    docstring).  ``exhausted`` flips when the session budget can no longer
    afford even the cheapest rung; the engine stops scheduling rounds."""

    def __init__(self, budget: BudgetSpec, log=None, privacy=None,
                 controller=None, accountant=None, serve_controller=None):
        if controller is not None and \
                tuple(controller.ladder) != tuple(budget.ladder):
            raise ValueError(
                "an adaptive controller on a budgeted transport must share "
                "the budget's ladder (its rung is a floor on the same walk); "
                f"got {controller.ladder} vs {budget.ladder}")
        if serve_controller is not None and \
                tuple(serve_controller.ladder) != tuple(budget.ladder):
            raise ValueError(
                "a serve controller on a budgeted transport must share the "
                "budget's ladder (its rung is a floor on the serve walk); "
                f"got {serve_controller.ladder} vs {budget.ladder}")
        super().__init__(log=log,
                         codec=None if controller is not None
                         else budget.ladder[0],
                         privacy=privacy, controller=controller,
                         accountant=accountant,
                         serve_controller=serve_controller)
        self.budget = budget
        self.link_spent: dict = {}      # (src, dst) -> bits
        self.skipped: list = []         # (src, dst) of dropped hops
        self.exhausted = False
        # rung chosen by the most recent ladder walk, consumed by the next
        # wire-priced booking (_on_send stamps it onto the ledger entry so a
        # late-attached registry can backfill hops_by_rung_total)
        self._pending_rung: int | None = None
        # bits a paused run already spent against the session cap (restored
        # from SessionState.comm on resume; this process's log starts empty)
        self.carryover_bits = 0

    # ------------------------------------------------------- budget ledger
    # Every skip and every spend — eager ladder walks here, compiled ledger
    # replays in Protocol._replay_traffic/_replay_serve and the scenario
    # _replay — goes through these two methods, so the telemetry registry
    # (attached to self.log) sees identical budget traffic on both backends.

    def record_skip(self, link) -> None:
        """Book one dropped hop on ``link`` = (src, dst)."""
        self.skipped.append(link)
        registry = getattr(self.log, "registry", None)
        if registry is not None:
            registry.inc("budget_skips_total", 1, src=link[0], dst=link[1])

    def record_spend(self, link, cost: int, rung: int) -> None:
        """Book ``cost`` bits of link spend for a hop shipped at ladder
        index ``rung``.  Arms ``_pending_rung`` so the wire-priced booking
        that follows (eager: the send inside ``super().interchange`` /
        ``serve_block`` / ``ship``; compiled: the replayed send right after
        this call) records the rung on its ledger entry.  Also degrades
        ``codec`` to the chosen rung — the single place both backends set
        it, so a replayed run ends with the same last-used codec as the
        eager walk."""
        self.codec = self.budget.ladder[int(rung)]
        self.link_spent[link] = self.link_spent.get(link, 0) + cost
        self._pending_rung = int(rung)
        registry = getattr(self.log, "registry", None)
        if registry is not None:
            registry.inc("hops_by_rung_total", 1, rung=int(rung))

    def _choose_codec(self, w_prev, w_out) -> None:
        # rung choice already happened in interchange (the controller floor
        # feeds the ladder walk); the base-class per-hop hook must not run
        # the controller a second time
        pass

    @property
    def effective_serve_codec(self):
        # the budget ladder drives serve codec choice: serve_block walks it
        # and sets ``codec`` to the chosen rung before shipping.  The base
        # property's controller bypass (serve raw under a controller) must
        # not apply here — it would ship raw blocks at encoded prices and
        # break eager==compiled serve parity (the compiled serve_ladder is
        # the budget ladder too).
        return self.serve_codec if self.serve_codec is not None else self.codec

    def interchange(self, src, dst, w, r, alpha, reweight,
                    standard=True, *, key=None, codec_state=None):
        n = int(w.shape[0])
        floor, w_out = 0, None
        if self.controller is not None:
            # observe the hop the way the base hook would: the controller
            # statistic reads the outgoing (post-reweight) vector, computed
            # once here and threaded through to the base interchange
            w_out = self._execute_update(w, r, alpha, reweight, standard)
            floor = self._controller_rung(w, w_out)
        costs = self.budget.hop_costs(n)
        link = (src.name, dst.name)
        rem_s = (math.inf if self.budget.session_bits is None
                 else self.budget.session_bits - self.log.total_bits
                 - self.carryover_bits)
        rem_l = (math.inf if self.budget.link_bits is None
                 else self.budget.link_bits - self.link_spent.get(link, 0))
        idx = self.budget.choose(n, rem_s, rem_l, floor)
        if idx is None:
            # defer/skip: the hop is dropped, the receiver keeps its stale
            # score; a session-budget skip ends round scheduling
            if rem_s < min(costs):
                self.exhausted = True
            self.record_skip(link)
            return w, codec_state
        self.record_spend(link, costs[idx], idx)   # degrades codec too
        return super().interchange(src, dst, w, r, alpha, reweight,
                                   standard, key=key,
                                   codec_state=codec_state, _w_out=w_out)

    def serve_block(self, src, dst, block, *, key=None):
        """Budgeted serve hop: the same degrade-then-skip ladder walk as
        :meth:`interchange`, applied to the [n, K] ScoreBlockMsg.  A skipped
        block is simply not delivered — the head agent predicts without this
        agent's votes (head-only degradation) and no bits are booked; a
        session-budget skip flips ``exhausted`` exactly like a training
        hop."""
        shape = tuple(block.shape)
        costs = self.budget.serve_costs(shape)
        floor = 0
        if self.serve_controller is not None:
            # the serve policy's rung floors the walk, exactly like the
            # training controller on interchange hops: the budget may
            # degrade coarser than the policy asked for, never finer
            from repro.control.adaptive import jitted_serve_controller
            floor = int(jitted_serve_controller(self.serve_controller)(block))
        link = (src.name, dst.name)
        rem_s = (math.inf if self.budget.session_bits is None
                 else self.budget.session_bits - self.log.total_bits
                 - self.carryover_bits)
        rem_l = (math.inf if self.budget.link_bits is None
                 else self.budget.link_bits - self.link_spent.get(link, 0))
        idx = self.budget.choose_costs(costs, rem_s, rem_l, floor)
        if idx is None:
            if rem_s < min(costs):
                self.exhausted = True
            self.record_skip(link)
            return None
        self.record_spend(link, costs[idx], idx)   # degrades codec too
        return super().serve_block(src, dst, block, key=key)

    def barrier_release(self, head, w_bar, *, key=None, codec_state=None):
        """Budgeted async-barrier release: one *session-level* ladder walk
        over the bare payload costs (the per-agent alpha messages book raw
        before this reads the ledger; link caps don't apply — the barrier
        is a broadcast, not a directed link).  A skip leaves the published
        score stale and flips ``exhausted``, ending round scheduling —
        per-barrier budget metering on the one ledger."""
        n = int(w_bar.shape[0])
        costs = self.budget.payload_costs(n)
        rem_s = (math.inf if self.budget.session_bits is None
                 else self.budget.session_bits - self.log.total_bits
                 - self.carryover_bits)
        idx = self.budget.choose_costs(costs, rem_s, math.inf)
        link = ("barrier", head.name)
        if idx is None:
            if rem_s < min(costs):
                self.exhausted = True
            self.record_skip(link)
            return None, codec_state
        self.record_spend(link, costs[idx], idx)   # degrades codec too
        return super().barrier_release(head, w_bar, key=key,
                                       codec_state=codec_state)

    def ship(self, src, dst, payload, wrap, *, key=None):
        """Budgeted protocol-variant hop (GradientMsg / ResidualMsg): the
        same degrade-then-skip ladder walk as :meth:`interchange`, priced
        at the bare encoded payload.  A skipped hop returns None — the
        receiver keeps its stale state (FedAvg: the server averages without
        this client; AL: the next agent fits yesterday's residual) — and a
        session-budget skip flips ``exhausted`` so the engine stops
        scheduling rounds."""
        shape = tuple(payload.shape)
        costs = self.budget.payload_costs(shape)
        link = (src.name, dst.name)
        rem_s = (math.inf if self.budget.session_bits is None
                 else self.budget.session_bits - self.log.total_bits
                 - self.carryover_bits)
        rem_l = (math.inf if self.budget.link_bits is None
                 else self.budget.link_bits - self.link_spent.get(link, 0))
        idx = self.budget.choose_costs(costs, rem_s, rem_l)
        if idx is None:
            if rem_s < min(costs):
                self.exhausted = True
            self.record_skip(link)
            return None
        self.record_spend(link, costs[idx], idx)   # degrades codec too
        return super().ship(src, dst, payload, wrap, key=key)
