"""Wire-format subsystem: what crosses an agent boundary, in how many bits,
at what precision, and with what privacy noise.

Sits between agents and transports (`repro.core.engine`):

  * :mod:`repro.comm.codecs`  — pure encode/decode pairs (fp32/fp16,
    int8/int4 stochastic quantization via the fused Pallas kernel, top-k
    sparsification with per-link error feedback).
  * :mod:`repro.comm.budget`  — per-link / per-session bit budgets and the
    degrade-then-skip :class:`~repro.comm.budget.BudgetedTransport`.
  * :mod:`repro.comm.privacy` — the Gaussian mechanism on outgoing
    ignorance vectors with per-agent epsilon accounting.

All three ride both engine backends: eager transports and the compiled
session scan run the same traced channel, so trajectories and byte ledgers
stay bit-identical across backends for every codec.

The policy layer above this subsystem — adaptive per-hop codec selection,
budget-aware round scheduling, RDP privacy accounting — lives in
:mod:`repro.control`.
"""
from repro.comm.codecs import (CODECS, Codec, Fp16Codec, Fp32Codec,
                               QuantCodec, TopKCodec, channel_apply,
                               jitted_channel, make_codec, serve_key)
from repro.comm.privacy import GaussianMechanism, PrivacyAccountant

__all__ = [
    "CODECS", "Codec", "Fp16Codec", "Fp32Codec", "QuantCodec", "TopKCodec",
    "channel_apply", "jitted_channel", "make_codec", "serve_key",
    "GaussianMechanism", "PrivacyAccountant",
    # lazy (avoids importing the engine on package import):
    "BudgetSpec", "BudgetedTransport", "DEFAULT_LADDER", "MODEL_WEIGHT_BITS",
    "TenantBudget",
]


def __getattr__(name):      # PEP 562: budget pulls in the engine; keep lazy
    if name in ("BudgetSpec", "BudgetedTransport", "DEFAULT_LADDER",
                "MODEL_WEIGHT_BITS", "TenantBudget"):
        from repro.comm import budget
        return getattr(budget, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
