"""Wire codecs: what an ignorance vector becomes on the way to another agent.

A :class:`Codec` is a pure ``encode``/``decode`` pair over length-n float
arrays.  ``encode`` produces the wire representation (what the byte ledger
prices — see :meth:`Codec.wire_bits`), ``decode`` reconstructs what the
receiving agent sees, and ``roundtrip`` fuses the two — that composition is
the *channel*: the protocol trajectory continues from the decoded array, so
a lossy codec genuinely degrades the interchange rather than merely
relabeling its byte count.

Codecs are hashable frozen dataclasses of pure fixed-shape functions, the
same discipline as :class:`~repro.learners.base.LearnerCore`: a codec is a
valid jit static argument, rides inside the compiled session scan
(`core/compiled.py`), and vmaps across session fleets.  Both engine
backends run the exact same traced channel (`jitted_channel` here), which
is what keeps eager and compiled trajectories bit-identical with a codec
active.

Implemented codecs:

  ===========  =======================  ============================
  name         wire format              bits for a length-n vector
  ===========  =======================  ============================
  ``fp32``     raw float32              32n
  ``fp16``     IEEE float16             16n
  ``int8``     int8 + fp32 tile scales  8n + 32·ceil(n/bn)
  ``int4``     packed int4 (two         8·ceil(n/2) + 32·ceil(n/bn)
               nibbles per wire byte)
               + fp32 tile scales
  ``topk``     top-k values + indices   k·(32 + ceil(log2 n))
  ===========  =======================  ============================

The int codecs run the fused quantize-dequant Pallas kernel
(`kernels/quantize.py`); ``topk`` keeps a per-link error-feedback residual
(carried in ``SessionState.codec_state``) so the mass it drops is re-offered
on the next hop instead of lost.
"""
from __future__ import annotations

import abc
import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

# fold_in tags deriving the per-hop channel keys from the per-fit key: the
# channel consumes no PRNG state of its own, so attaching (or removing) a
# codec never shifts the fit keys — fp32 sessions stay bit-identical to
# pre-comm trajectories.
COMM_FOLD = 0x434F4D        # "COM"
PRIVACY_FOLD = 0
CODEC_FOLD = 1
# serve-path fold tag: prediction-time ScoreBlockMsg channel keys derive as
# fold_in(fit_key, SERVE_FOLD) then fold_in(., agent_index) — again no PRNG
# state consumed, so serving never shifts the fit stream, and both engine
# backends derive identical serve draws.
SERVE_FOLD = 0x535256       # "SRV"

SCALE_BITS = 32             # one fp32 scale per quantization tile


def numel(shape) -> int:
    """Element count of a wire payload shape: codecs accept either an int n
    (the PR-3 length-n ignorance vector) or a shape tuple (the [n, K]
    prediction-time score block)."""
    if isinstance(shape, (tuple, list)):
        out = 1
        for s in shape:
            out *= int(s)
        return out
    return int(shape)


@dataclass(frozen=True)
class Codec(abc.ABC):
    """A pure encode/decode pair over float arrays: length-n ignorance
    vectors (training interchange) and [n, K] score blocks (prediction
    serve traffic) alike — every method is shape-generic."""

    #: Codecs with per-link state (error-feedback residuals) return it from
    #: ``init_state``; stateless codecs leave this False and pass None.
    stateful = False

    @abc.abstractmethod
    def wire_bits(self, shape) -> int:
        """Encoded size in bits of a payload (static).  ``shape`` is an int
        n (length-n vector) or a shape tuple like (n, K)."""

    def init_state(self, shape):
        """Fresh per-link codec state (None for stateless codecs)."""
        return None

    @abc.abstractmethod
    def encode(self, x: jnp.ndarray, key=None, state=None):
        """x -> (wire pytree, new_state)."""

    @abc.abstractmethod
    def decode(self, wire) -> jnp.ndarray:
        """wire -> reconstructed x_hat (what the receiver sees)."""

    def roundtrip(self, x: jnp.ndarray, key=None, state=None):
        """decode(encode(x)) fused; subclasses may override with a fused
        kernel, but must stay bit-identical to the encode/decode pair."""
        wire, state = self.encode(x, key, state)
        return self.decode(wire), state


@dataclass(frozen=True)
class Fp32Codec(Codec):
    """Passthrough: the PR-1 wire format, 32 bits per element."""

    def wire_bits(self, shape) -> int:
        return 32 * numel(shape)

    def encode(self, x, key=None, state=None):
        return x.astype(jnp.float32), state

    def decode(self, wire):
        return wire


@dataclass(frozen=True)
class Fp16Codec(Codec):
    """IEEE half precision: 2x cheaper, ~3 decimal digits kept."""

    def wire_bits(self, shape) -> int:
        return 16 * numel(shape)

    def encode(self, x, key=None, state=None):
        return x.astype(jnp.float16), state

    def decode(self, wire):
        return wire.astype(jnp.float32)


@dataclass(frozen=True)
class QuantCodec(Codec):
    """Symmetric int quantization with per-tile fp32 scales.

    ``bits`` integer bits per element (8 or 4; int4 travels in an int8
    carrier but is priced at 4 bits).  ``stochastic`` selects unbiased
    stochastic rounding (needs the hop key) vs deterministic round-half-up.
    ``roundtrip`` runs the fused Pallas kernel (kernels/quantize.py);
    ``encode``/``decode`` expose the wire halves and are pinned bit-identical
    to the kernel by tests/test_comm.py.
    """
    bits: int = 8
    stochastic: bool = True
    bn: int = 1024

    @property
    def qmax(self) -> float:
        return float(2 ** (self.bits - 1) - 1)

    def _tiles(self, shape) -> int:
        from repro.kernels.quantize import rows_for, tile_for
        if isinstance(shape, (tuple, list)) and len(shape) == 2:
            n, k = int(shape[0]), int(shape[1])
            return n // rows_for(n, k, self.bn)
        n = numel(shape)
        return n // tile_for(n, self.bn)

    def wire_bits(self, shape) -> int:
        m = numel(shape)
        if self.bits == 4:
            # real 4-bit carriers: two nibbles per int8 wire byte (odd
            # element counts pad the trailing high nibble), so the priced
            # payload is whole bytes, not a fictional 4·m
            payload = 8 * ((m + 1) // 2)
        else:
            payload = self.bits * m
        return payload + SCALE_BITS * self._tiles(shape)

    def _u(self, x, key):
        if self.stochastic:
            if key is None:
                raise ValueError("stochastic QuantCodec needs a hop key")
            return jax.random.uniform(key, x.shape, jnp.float32)
        return jnp.full(x.shape, 0.5, jnp.float32)

    def roundtrip(self, x, key=None, state=None, qmax=None):
        from repro.kernels import ops
        qd = ops.quantize_dequant_block if x.ndim == 2 else ops.quantize_dequant
        xhat, _, _ = qd(x, self._u(x, key),
                        self.qmax if qmax is None else qmax, bn=self.bn)
        return xhat, state

    def encode(self, x, key=None, state=None):
        from repro.kernels import ops, ref
        qd = ref.quantize_dequant_block if x.ndim == 2 else ref.quantize_dequant
        _, q, scales = qd(x, self._u(x, key), self.qmax, bn=self.bn)
        if self.bits == 4:
            # the wire array is a real 4-bit carrier: two nibbles per int8
            # byte (the Pallas pack pass); shape rides the wire tuple so
            # decode can unpack odd element counts exactly
            return (ops.pack_int4(q), scales, tuple(q.shape)), state
        return (q, scales), state

    def decode(self, wire):
        if self.bits == 4:
            from repro.kernels import ops
            packed, scales, shape = wire
            q = ops.unpack_int4(packed, numel(shape)).reshape(shape)
        else:
            q, scales = wire
        if q.ndim == 2:
            n, k = q.shape
            br = n // scales.shape[0]
            return (q.astype(jnp.float32).reshape(-1, br, k)
                    * scales[:, None, None]).reshape(n, k)
        n = q.shape[0]
        bn = n // scales.shape[0]
        return (q.astype(jnp.float32).reshape(-1, bn)
                * scales[:, None]).reshape(n)


@dataclass(frozen=True)
class TopKCodec(Codec):
    """Top-k sparsification with per-link error feedback.

    Ships the k = ceil(fraction·n) largest-magnitude entries as
    (value, index) pairs.  The mass not shipped accumulates in a per-link
    residual (EF-SGD style): each encode sees x + residual, and the new
    residual is what decode failed to reconstruct — dropped ignorance is
    deferred to the next hop on that link, not lost.  The residual rides in
    ``SessionState.codec_state`` (eager) / the session scan carry (compiled)
    and is checkpointed with the session.
    """
    fraction: float = 0.25

    stateful = True

    def k_for(self, n: int) -> int:
        """Entries shipped for an n-element payload (n = numel of the
        shape: rows for a vector, rows x classes for a score block)."""
        return max(1, int(math.ceil(self.fraction * numel(n))))

    def wire_bits(self, shape) -> int:
        m = numel(shape)
        idx_bits = max(1, math.ceil(math.log2(max(m, 2))))
        return self.k_for(m) * (32 + idx_bits)

    def init_state(self, shape):
        if isinstance(shape, (tuple, list)):
            return jnp.zeros(tuple(int(s) for s in shape), jnp.float32)
        return jnp.zeros((int(shape),), jnp.float32)

    def encode(self, x, key=None, state=None):
        shape = tuple(x.shape)
        if state is None:
            state = self.init_state(shape)
        y = (x.astype(jnp.float32) + state).reshape(-1)
        m = y.shape[0]
        _, idx = jax.lax.top_k(jnp.abs(y), self.k_for(m))
        vals = y[idx]
        dense = jnp.zeros((m,), jnp.float32).at[idx].set(vals)
        return (vals, idx, shape), (y - dense).reshape(shape)

    def decode(self, wire):
        vals, idx, shape = wire
        m = numel(shape)
        dense = jnp.zeros((m,), jnp.float32).at[idx].set(vals)
        return dense.reshape(shape)


CODECS = {
    "fp32": Fp32Codec,
    "fp16": Fp16Codec,
    "int8": lambda **kw: QuantCodec(bits=8, **kw),
    "int4": lambda **kw: QuantCodec(bits=4, **kw),
    "topk": TopKCodec,
}


def make_codec(name: str, **kw) -> Codec:
    """Codec registry lookup for CLI / benchmark sweep names."""
    if name not in CODECS:
        raise ValueError(f"unknown codec {name!r}; expected {sorted(CODECS)}")
    return CODECS[name](**kw)


def serve_key(state_key, request=None):
    """The serve-channel key for one prediction call: the session's (evolved)
    PRNG key folded with the SERVE tag, then — for request-keyed serving
    (the serve engine's stream of independent queries against one resident
    session) — with the integer request tag.  Pure fold_ins: no PRNG state
    is consumed, so serving never shifts the fit stream, repeated serves of
    the same request are deterministic, and distinct requests draw
    independent channel noise.  Both engine backends and the batched serve
    engine derive their keys here, which is what makes a batched slot
    bit-identical to a standalone ``predict_distributed(request=...)``."""
    key = jax.random.fold_in(state_key, SERVE_FOLD)
    if request is not None:
        if not isinstance(request, jax.Array):
            request = int(request)      # trace-safe: tracers pass through
        key = jax.random.fold_in(key, request)
    return key


# ===================================================================== channel
def channel_apply(codec, privacy, w, hop_key, state, qmax=None):
    """One hop through the wire: DP noise on the outgoing vector, then the
    codec roundtrip.  ``hop_key`` is the per-fit subkey; the privacy and
    codec keys are folded from it with fixed tags, so the channel consumes
    no PRNG state and both engine backends derive identical draws.  Pure and
    fixed-shape: jits, scans, and vmaps.  ``qmax`` optionally overrides a
    QuantCodec's static clipping level with a traced scalar (codec sweeps;
    see ``core.compiled.quant_sweep_run``)."""
    if privacy is not None:
        w = privacy.apply(w, jax.random.fold_in(
            jax.random.fold_in(hop_key, COMM_FOLD), PRIVACY_FOLD))
    if codec is not None:
        ck = jax.random.fold_in(
            jax.random.fold_in(hop_key, COMM_FOLD), CODEC_FOLD)
        if qmax is not None:
            w, state = codec.roundtrip(w, ck, state, qmax=qmax)
        else:
            w, state = codec.roundtrip(w, ck, state)
    return w, state


def quant_bits_per_element(qmax) -> int:
    """Wire bits per element for a symmetric integer range [-qmax, qmax]
    (the inverse of QuantCodec.qmax): 127 -> 8, 7 -> 4."""
    return max(1, math.ceil(math.log2(2 * int(qmax) + 2)))


@functools.lru_cache(maxsize=64)
def jitted_channel(codec, privacy):
    """Cached jit of ``channel_apply`` for a (codec, privacy) pair — the
    eager transports route through this so the eager engine runs the exact
    XLA program the compiled session scan embeds (the same trick as
    ``learners.base.jitted_fresh_fit``, and for the same reason: op-by-op
    dispatch fuses differently at the last ulp)."""
    return jax.jit(functools.partial(channel_apply, codec, privacy))
