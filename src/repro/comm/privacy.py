"""Differentially private ignorance interchange: the Gaussian mechanism on
outgoing score vectors, with per-agent epsilon accounting.

The ignorance vector w is a per-sample hardness profile — it leaks which of
the collated samples an agent's model gets wrong, which is exactly the kind
of per-record signal DP is for (cf. the cost-of-decentralization-under-
privacy analysis of Jose & Simeone 2021).  Before each hop the sender clips
its outgoing vector to an L2 ball of radius ``clip`` and adds
N(0, sigma^2 I) with the standard Gaussian-mechanism calibration

    sigma = clip * sqrt(2 ln(1.25/delta)) / epsilon,

so each release is (epsilon, delta)-DP with respect to a one-sample change
in the clipped vector.  The noised vector is clamped at zero afterwards
(post-processing — free under DP) because every downstream formula assumes
nonnegative ignorance mass.

Accounting is per *agent*: every release an agent makes spends one
(epsilon, delta) under basic sequential composition, tallied by
:class:`PrivacyAccountant` on the transport (eager) or replayed from the
compiled session result (`Protocol._fit_compiled`) — both paths produce the
same ledger.  Tighter (advanced / RDP) composition is an open item in
ROADMAP.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GaussianMechanism:
    """Per-release Gaussian mechanism on a clipped vector.

    Hashable frozen dataclass: a valid jit static argument, so it rides the
    compiled session scan exactly like a codec."""
    epsilon: float = 1.0
    delta: float = 1e-5
    clip: float = 1.0
    # ignorance scores are nonnegative mass, so the default clamps the
    # noised vector at zero (post-processing, free under DP); signed
    # payloads (FedAvg model deltas, Assisted-Learning residuals) set
    # nonneg=False and keep the raw noised vector
    nonneg: bool = True

    def __post_init__(self):
        if self.epsilon <= 0 or not (0 < self.delta < 1) or self.clip <= 0:
            raise ValueError(
                f"need epsilon > 0, 0 < delta < 1, clip > 0; got "
                f"({self.epsilon}, {self.delta}, {self.clip})")

    @property
    def sigma(self) -> float:
        return self.clip * math.sqrt(2.0 * math.log(1.25 / self.delta)) \
            / self.epsilon

    def apply(self, x: jnp.ndarray, key) -> jnp.ndarray:
        """Clip to the L2 ball, add calibrated noise, clamp at zero (when
        the payload is nonnegative mass)."""
        x = x.astype(jnp.float32)
        norm = jnp.sqrt(jnp.sum(x * x))
        x = x * jnp.minimum(1.0, self.clip / jnp.maximum(norm, 1e-12))
        noised = x + self.sigma * jax.random.normal(key, x.shape,
                                                    jnp.float32)
        if not self.nonneg:
            return noised
        return jnp.maximum(noised, 0.0)


@dataclass
class PrivacyAccountant:
    """Per-agent (epsilon, delta) tally under basic composition: one
    (mechanism.epsilon, mechanism.delta) per release of that agent's
    ignorance vector."""
    releases: dict = field(default_factory=dict)   # agent name -> count

    # optional repro.telemetry MetricsRegistry.  Class attribute, not a
    # dataclass field: the RDP accountants (control/accounting.py) subclass
    # this dataclass and add their own defaulted fields, so a new field here
    # would reorder their signatures.  Telemetry sets it per instance; the
    # inherited ``record`` then emits for every accountant flavor.
    registry = None

    def record(self, agent: str) -> None:
        self.releases[agent] = self.releases.get(agent, 0) + 1
        if self.registry is not None:
            self.registry.inc("dp_releases_total", 1, agent=agent)

    def spent(self, agent: str, mechanism: GaussianMechanism
              ) -> tuple[float, float]:
        """Cumulative (epsilon, delta) spent by ``agent``."""
        k = self.releases.get(agent, 0)
        return k * mechanism.epsilon, k * mechanism.delta

    def report(self, mechanism: GaussianMechanism) -> dict:
        """{agent: {releases, epsilon, delta}} in deterministic name order."""
        return {name: {"releases": self.releases[name],
                       "epsilon": self.releases[name] * mechanism.epsilon,
                       "delta": self.releases[name] * mechanism.delta}
                for name in sorted(self.releases)}
