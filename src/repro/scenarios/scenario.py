"""Adversarial-reality scenario knobs: who shows up, with what data, when.

A :class:`Scenario` is a frozen, hashable bundle of deployment pathologies
layered over any protocol variant (ASCII / FedAvg / Assisted Learning)
without touching the round rules:

  * **subsample** — per-round client subsampling: only a seeded fraction of
    the roster participates each round (FedAvg's C parameter; unlocks the
    subsampled-RDP accountant in :mod:`repro.control.accounting`).
  * **straggle** — per-(round, agent) transient misses: the agent skips the
    round and returns later.
  * **dropout** — permanent churn: each round an agent survives with
    probability 1 - dropout; once gone, gone.
  * **partition / skew** — non-IID horizontal shards
    (:mod:`repro.data.partition`): each agent fits only on its shard's rows
    (fit weights masked + renormalized) while collation, rewards, and
    prediction stay global.
  * **clock_skew** — per-agent staleness (ASCII async barrier only): agent
    m trains against the broadcast from ``clock_skew[m]`` barriers ago.

Everything is a pure function of (scenario, rounds, roster size): the
participation schedule and shard assignment are recomputed identically on
fresh runs, resumes, and the compiled FedAvg lowering — determinism is the
contract that makes churn replayable and checkpointable.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.data.partition import dirichlet_label_partition, quantity_partition

PARTITIONS = ("iid", "dirichlet", "quantity")


@dataclass(frozen=True)
class Scenario:
    """One named bundle of deployment-reality knobs (see module docstring).

    Frozen and hashable, so it can parameterize lru-cached schedules and
    ride compiled plans as a static argument."""
    name: str = "clean"
    subsample: float | None = None      # fraction of roster per round
    dropout: float = 0.0                # per-round permanent-departure prob
    straggle: float = 0.0               # per-(round, agent) miss prob
    partition: str = "iid"              # iid | dirichlet | quantity
    skew: float = 0.5                   # dirichlet alpha / quantity exponent
    clock_skew: tuple = ()              # per-agent barrier lag (ASCII async)
    seed: int = 0

    def __post_init__(self):
        if self.subsample is not None and not (0.0 < self.subsample <= 1.0):
            raise ValueError(
                f"subsample must be in (0, 1], got {self.subsample}")
        if not (0.0 <= self.dropout < 1.0):
            raise ValueError(f"dropout must be in [0, 1), got {self.dropout}")
        if not (0.0 <= self.straggle < 1.0):
            raise ValueError(
                f"straggle must be in [0, 1), got {self.straggle}")
        if self.partition not in PARTITIONS:
            raise ValueError(f"unknown partition {self.partition!r}; "
                             f"expected {PARTITIONS}")
        if any(int(s) < 0 for s in self.clock_skew):
            raise ValueError(f"clock_skew lags must be >= 0, "
                             f"got {self.clock_skew}")
        object.__setattr__(self, "clock_skew",
                           tuple(int(s) for s in self.clock_skew))

    # ---- coherence ---------------------------------------------------------
    @property
    def trivial(self) -> bool:
        """True when every knob is at its neutral value (the scenario does
        not perturb the run at all)."""
        return (self.subsample is None and self.dropout == 0.0
                and self.straggle == 0.0 and self.partition == "iid"
                and not any(self.clock_skew))

    @property
    def has_churn(self) -> bool:
        return (self.subsample is not None or self.dropout > 0.0
                or self.straggle > 0.0)

    def validate(self, num_agents: int, scheduler, variant) -> None:
        """Reject incoherent combinations up front — a silently degenerate
        run (empty every round, skew on a scheduler that cannot express it)
        is worse than an error."""
        if self.subsample is not None \
                and int(round(self.subsample * num_agents)) < 1:
            raise ValueError(
                f"subsample={self.subsample} of {num_agents} agents rounds "
                f"to an empty round every round; raise subsample to at "
                f"least {0.5 / num_agents:.3f} or enlarge the roster")
        if any(self.clock_skew):
            if not getattr(scheduler, "stale", False):
                raise ValueError(
                    "clock_skew models agents reading stale barrier "
                    "broadcasts; it needs the async scheduler "
                    "(AsyncStaleScheduler / --variant async), not a "
                    "sequential chain where every hop is synchronous")
            if getattr(variant, "name", "ascii") != "ascii":
                raise ValueError(
                    "clock_skew is defined on the ASCII async barrier; "
                    f"protocol variant {getattr(variant, 'name', '?')!r} "
                    f"does not run one")
            if len(self.clock_skew) != num_agents:
                raise ValueError(
                    f"clock_skew names {len(self.clock_skew)} agents but "
                    f"the roster has {num_agents}")

    # ---- deterministic schedules -------------------------------------------
    def participation(self, rounds: int, num_agents: int) -> np.ndarray:
        """The [rounds, num_agents] bool participation mask: dropout first
        (permanent), stragglers second (transient), subsampling last (among
        whoever is left).  A pure seeded function — replays and resumes
        reproduce it exactly, and the compiled FedAvg lowering consumes the
        identical mask."""
        return _participation(self, int(rounds), int(num_agents)).copy()

    def shard_weights(self, classes, num_agents: int):
        """[num_agents, n] float32 fit-weight masks for the non-IID
        partition, or None under IID (the untouched default path)."""
        if self.partition == "iid":
            return None
        classes = np.asarray(classes)
        n = int(classes.shape[0])
        if self.partition == "dirichlet":
            shards = dirichlet_label_partition(self.seed, classes,
                                               num_agents, alpha=self.skew)
        else:
            shards = quantity_partition(self.seed, n, num_agents,
                                        skew=self.skew)
        masks = np.zeros((num_agents, n), np.float32)
        for m, idx in enumerate(shards):
            masks[m, idx] = 1.0
        return jnp.asarray(masks)


@functools.lru_cache(maxsize=256)
def _participation(scenario: Scenario, rounds: int,
                   num_agents: int) -> np.ndarray:
    rng = np.random.default_rng(scenario.seed)
    mask = np.ones((rounds, num_agents), bool)
    # draw order is fixed (dropout, straggle, subsample) regardless of which
    # knobs are active, so adding a knob never reshuffles another's draws
    if scenario.dropout > 0.0:
        # per-agent geometric departure round
        u = rng.random((rounds, num_agents))
        for m in range(num_agents):
            gone = np.flatnonzero(u[:, m] < scenario.dropout)
            if gone.size:
                mask[gone[0]:, m] = False
    if scenario.straggle > 0.0:
        mask &= rng.random((rounds, num_agents)) >= scenario.straggle
    if scenario.subsample is not None:
        want = max(1, int(round(scenario.subsample * num_agents)))
        for t in range(rounds):
            avail = np.flatnonzero(mask[t])
            if avail.size > want:
                keep = rng.choice(avail, size=want, replace=False)
                mask[t] = False
                mask[t, keep] = True
    mask.setflags(write=False)
    return mask


#: Named presets the CLI and benchmarks share.
PRESETS = {
    "clean": Scenario("clean"),
    "noniid": Scenario("noniid", partition="dirichlet", skew=0.3, seed=1),
    "churn": Scenario("churn", straggle=0.25, dropout=0.05, seed=2),
    "subsample": Scenario("subsample", subsample=0.5, seed=3),
}
