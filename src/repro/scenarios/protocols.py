"""FedAvg and Assisted-Learning protocol variants on the ASCII wire.

The paper's baselines are usually reported from separate codebases with
separate (often absent) communication accounting.  Here they are
:class:`~repro.core.engine.ProtocolVariant`\\ s driven by the *same* session
loop, shipping their traffic through the *same* transports — codecs, bit
budgets (degrade-then-skip ladder), DP noise, and privacy accountants — so
the byte ledger and the epsilon ledger of "ASCII vs FedAvg vs AL at equal
budget" are directly comparable numbers, not apples and oranges:

  * :class:`FedAvgVariant` — one global model over a homogeneous roster.
    Each round every participating client warm-starts a local fit from the
    broadcast flat params ``g`` and uplinks its delta as a
    :class:`~repro.core.engine.GradientMsg` (DP-noised, codec-encoded,
    budget-walked via :meth:`Transport.ship`); the server (agent 0, whose
    own delta never crosses a wire) averages the deltas that actually
    arrived and broadcasts the new ``g`` raw.  Homogeneous rounds lower
    into a single ``lax.scan`` (:mod:`repro.scenarios.compiled`), pinned
    bit-identical to the eager loop.
  * :class:`AssistedLearningVariant` — residual-fitting rounds (Xian et al.
    2020's assisted learning, the paper's closest relative): the label
    one-hot starts as the residual ``R``; each agent in the ring fits a
    closed-form weighted ridge of ``R`` on its private feature block, keeps
    the fitted block as a boosting component, and ships the shrunk residual
    to the next agent as a :class:`~repro.core.engine.ResidualMsg`.  A
    budget-skipped hop leaves the receiver fitting yesterday's residual.
    Eager-only (the ring is data-dependent per round).

Both variants respect the engine's scenario knobs: churned agents skip the
round, non-IID shards mask the fit weights, and the deterministic
participation schedule replays identically across resume boundaries.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.engine import (ASCIIVariant, Component, GradientMsg,
                               ProtocolVariant, ResidualMsg,
                               SequentialScheduler)

#: fold_in tag deriving FedAvg's global-init key off the session key, so
#: model init never consumes PRNG state the per-round splits would see
#: (same discipline as the comm channel's COMM/SERVE tags).
FEDAVG_INIT_FOLD = 0x0FEDA6


# ============================================================= shared programs
# The pure expressions below are the single definitions both the eager round
# loop (via cached jits) and the compiled lax.scan lowering
# (repro.scenarios.compiled, traced inline) execute — the same trick as
# learners.base.jitted_fresh_fit, and for the same reason: sharing the
# composition is what keeps the two backends bit-identical.

@functools.lru_cache(maxsize=256)
def _param_template(core, shapes: tuple):
    """(flat param dim, unravel closure) for a core at feature ``shapes`` —
    the fixed flattening every GradientMsg payload uses."""
    params0 = core.init(jax.random.key(0), shapes)
    flat, unravel = ravel_pytree(params0)
    return int(flat.size), unravel


def fedavg_init_flat(core, shapes: tuple, key) -> jnp.ndarray:
    """The flat global init ``g0``: core init under the FEDAVG_INIT_FOLD
    tag, raveled."""
    params = core.init(jax.random.fold_in(key, FEDAVG_INIT_FOLD), shapes)
    return ravel_pytree(params)[0]


def fedavg_local_delta(core, shapes: tuple, g, key, X, onehot,
                       w) -> jnp.ndarray:
    """One client update: warm-start the core's WST fit from the broadcast
    flat params and return the flat delta (the GradientMsg payload)."""
    _, unravel = _param_template(core, shapes)
    local = core.fit(unravel(g), key, X, onehot, w)
    return ravel_pytree(local)[0] - g


def fedavg_combine(g, stack, mask, lr) -> jnp.ndarray:
    """The server's round merge: average the deltas that actually arrived
    (mask [M] bool over stack [M, d]) and step ``g`` by ``lr`` times it."""
    cnt = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    delta = jnp.sum(jnp.where(mask[:, None], stack, 0.0), axis=0)
    return g + jnp.asarray(lr, jnp.float32) * delta / cnt


@functools.lru_cache(maxsize=256)
def jitted_fedavg_init(core, shapes: tuple):
    return jax.jit(functools.partial(fedavg_init_flat, core, shapes))


@functools.lru_cache(maxsize=256)
def jitted_fedavg_fit(core, shapes: tuple):
    return jax.jit(functools.partial(fedavg_local_delta, core, shapes))


@functools.lru_cache(maxsize=64)
def jitted_fedavg_combine(lr: float):
    return jax.jit(lambda g, stack, mask: fedavg_combine(g, stack, mask, lr))


@functools.lru_cache(maxsize=256)
def jitted_fedavg_eval(core, shapes: tuple, num_agents: int):
    """Mean of the global model's logits over the agents' feature blocks —
    FedAvg's prediction rule here.  The roster is vertically partitioned
    (each block holds *different* columns of the holistic matrix), which a
    single averaged model cannot exploit; evaluating it on every block and
    averaging is the best a FedAvg deployment can do without moving raw
    features, and is exactly the handicap the ASCII comparison measures."""
    _, unravel = _param_template(core, shapes)

    def fn(g, Xs):
        params = unravel(g)
        total = core.logits(params, Xs[0])
        for X in Xs[1:]:
            total = total + core.logits(params, X)
        return total / float(num_agents)

    return jax.jit(fn)


def fedavg_train_acc(core, shapes: tuple, g, Xs, classes) -> float:
    """Round-history accuracy through the one shared eval program, so eager
    records and compiled-replay records carry identical floats."""
    logits = jitted_fedavg_eval(core, shapes, len(Xs))(g, tuple(Xs))
    preds = jnp.argmax(logits, axis=-1)
    return float(jnp.mean((preds == classes).astype(jnp.float32)))


def fedavg_fit_weights(classes, num_agents: int, scenario=None) -> jnp.ndarray:
    """[M, n] per-client fit-weight table: uniform rows, masked to the
    scenario's non-IID shard and renormalized (the same arithmetic as
    ``Session.fit_weight`` on a uniform base).  Computed once and passed to
    both backends as data, so they consume identical weights."""
    n = int(np.asarray(classes).shape[0])
    base = jnp.full((n,), 1.0 / n, jnp.float32)
    masks = (None if scenario is None
             else scenario.shard_weights(classes, num_agents))
    if masks is None:
        return jnp.stack([base] * num_agents)
    rows = []
    for m in range(num_agents):
        wm = base * masks[m]
        rows.append(wm / jnp.maximum(jnp.sum(wm), 1e-12))
    return jnp.stack(rows)


def _homogeneous_core(endpoints, num_classes: int):
    """FedAvg averages parameters, so the roster must be homogeneous: every
    agent a functional learner with the same core config and feature shape."""
    cores, shapes = [], []
    for ep in endpoints:
        if not getattr(ep.learner, "functional", False):
            raise ValueError(
                f"fedavg averages model parameters; endpoint {ep.name!r}'s "
                f"{type(ep.learner).__name__} has no functional LearnerCore "
                f"(trees are eager-only) — use logistic/mlp learners")
        cores.append(ep.learner.core(num_classes))
        shapes.append(tuple(ep.X.shape[1:]))
    if any(c != cores[0] for c in cores[1:]):
        raise ValueError(
            "fedavg requires one shared model: all agents must hold "
            f"identically-configured learners, got {sorted(set(map(repr, cores)))}")
    if any(s != shapes[0] for s in shapes[1:]):
        raise ValueError(
            "fedavg averages one global model over a fixed feature shape; "
            f"agents hold blocks of shapes {shapes} — pad or re-split the "
            "vertical partition into equal widths")
    return cores[0], shapes[0]


# ================================================================ FedAvg
@dataclass
class FittedFedAvg:
    """FedAvg's trained result: the flat global params, predicting by
    averaging the model's logits over the agents' feature blocks."""
    core: object
    shapes: tuple
    g: jnp.ndarray
    num_classes: int
    history: list = field(default_factory=list)

    def decision_scores(self, Xs) -> jnp.ndarray:
        return jitted_fedavg_eval(self.core, self.shapes,
                                  len(Xs))(self.g, tuple(Xs))

    def predict(self, Xs) -> jnp.ndarray:
        return jnp.argmax(self.decision_scores(Xs), axis=-1)

    @property
    def num_rounds(self) -> int:
        return len(self.history)


@dataclass
class FedAvgVariant(ProtocolVariant):
    """Federated averaging over the shared channel stack (McMahan et al.
    2017): uplink deltas through ``Transport.ship`` (codec + DP + budget
    ladder), raw model broadcast back, server-side delta averaging.

    ``server_lr`` scales the averaged delta (1.0 = plain FedAvg).  Agent 0
    is the server: its own delta joins the average without crossing a wire
    (no codec loss, no DP release, no budget charge — the standard trusted
    aggregator running its own local shard).
    """
    server_lr: float = 1.0

    name = "fedavg"

    def bind(self, session) -> None:
        core, shapes = _homogeneous_core(session.endpoints,
                                         session.cfg.num_classes)
        session.vctx["core"] = core
        session.vctx["shapes"] = shapes
        session.vctx["onehot"] = jax.nn.one_hot(session.classes,
                                                session.cfg.num_classes)
        session.vctx["fit_w"] = fedavg_fit_weights(session.classes,
                                                   len(session.endpoints),
                                                   session.scenario)
        if session.state.proto is None:
            # fresh session: bind runs before any per-round key splits, so
            # the fold off state.key here and off the fit key in the
            # compiled lowering see the identical key
            session.state.proto = {
                "g": jitted_fedavg_init(core, shapes)(session.state.key)}

    def run_round(self, session, order: list[int], rec: dict) -> bool:
        st = session.state
        eps = {ep.agent_id: ep for ep in session.endpoints}
        core = session.vctx["core"]
        shapes = session.vctx["shapes"]
        onehot, fit_w = session.vctx["onehot"], session.vctx["fit_w"]
        head = session.endpoints[0]
        num = len(session.endpoints)
        part = set(order)
        g = st.proto["g"]
        rows, mask = [], []
        for j in range(num):
            # one split per roster slot, participating or not: the key
            # stream is then a pure function of (round, slot), which is
            # what the compiled scan reproduces
            st.key, sub = jax.random.split(st.key)
            if j not in part:
                rows.append(None)
                mask.append(False)
                continue
            dflat = jitted_fedavg_fit(core, shapes)(
                g, sub, eps[j].X, onehot, fit_w[j])
            if j == 0:
                # the server's own delta joins the average off-wire
                rows.append(dflat)
                mask.append(True)
                continue
            d_hat = session.transport.ship(eps[j], head, dflat, GradientMsg,
                                           key=sub)
            rows.append(d_hat)
            mask.append(d_hat is not None)
        zero = jnp.zeros_like(g)
        stack = jnp.stack([r if r is not None else zero for r in rows])
        g = jitted_fedavg_combine(float(self.server_lr))(
            g, stack, jnp.asarray(mask))
        st.proto["g"] = g
        # raw fp32 broadcast of the new global model to every participating
        # client (the server's own params carry no DP obligation); priced at
        # num_elements x 32 by the ledger, counted against the session cap
        for m in order:
            if m == 0:
                continue
            session.transport.send(GradientMsg(head.name, eps[m].name, g))
        rec["train_acc"] = fedavg_train_acc(
            core, shapes, g, [ep.X for ep in session.endpoints],
            session.classes)
        return False

    def fitted(self, session) -> FittedFedAvg:
        return FittedFedAvg(session.vctx["core"], session.vctx["shapes"],
                            session.state.proto["g"],
                            session.cfg.num_classes, session.state.history)

    # ---- compiled lowering --------------------------------------------------
    def fit_compiled(self, protocol, key, endpoints, classes, validation):
        """One-program FedAvg: the homogeneous round lowers into a
        ``lax.scan`` over the participation mask
        (:mod:`repro.scenarios.compiled`), then the message ledger an eager
        run would have booked is replayed onto the live transport —
        byte-identical metering, same epsilon tally."""
        from repro.scenarios import compiled as scompiled
        cfg = protocol.cfg
        if validation is not None:
            raise ValueError("backend='compiled' does not support the CV "
                             "validation stop; use the eager backend")
        if not (isinstance(protocol.scheduler, SequentialScheduler)
                and not protocol.scheduler.stale):
            raise ValueError(
                f"fedavg's compiled lowering supports sequential scheduling "
                f"only, got {type(protocol.scheduler).__name__}")
        if not all(ep.active for ep in endpoints):
            raise ValueError("backend='compiled' assumes all endpoints "
                             "active for the whole run (scenario churn is "
                             "fine — it rides the participation mask)")
        core, shapes = _homogeneous_core(endpoints, cfg.num_classes)
        transport = protocol.transport
        scenario = protocol.scenario
        num = len(endpoints)
        mask = (np.ones((cfg.max_rounds, num), bool) if scenario is None
                else scenario.participation(cfg.max_rounds, num))
        fit_w = fedavg_fit_weights(classes, num, scenario)
        plan = scompiled.FedAvgPlan(
            core=core, num_classes=cfg.num_classes, num_agents=num,
            max_rounds=cfg.max_rounds, server_lr=float(self.server_lr),
            codec=transport.codec, privacy=transport.privacy,
            budget=getattr(transport, "budget", None))
        Xs = tuple(ep.X for ep in endpoints)
        tele = getattr(protocol, "telemetry", None)
        if tele is None:
            result = scompiled.fedavg_session(plan, key, Xs, classes,
                                              jnp.asarray(mask), fit_w)
        else:
            with tele.span("session", backend="compiled", variant=self.name,
                           agents=num):
                result = tele.fence(scompiled.fedavg_session(
                    plan, key, Xs, classes, jnp.asarray(mask), fit_w))
        self._replay(protocol, endpoints, classes, result, plan, mask)
        history = self._history(core, shapes, result, mask, Xs, classes,
                                scenario)
        protocol._compiled_ctx = None
        return FittedFedAvg(core, shapes, result.g, cfg.num_classes, history)

    @staticmethod
    def _history(core, shapes, result, mask, Xs, classes, scenario):
        """The round records an eager run writes, rebuilt from the scan's
        per-round global-param trace through the same eval program."""
        executed = np.asarray(result.executed)
        history = []
        for t in range(executed.shape[0]):
            if not executed[t]:
                continue
            rec: dict = {"round": t}
            parts = [int(j) for j in np.flatnonzero(mask[t])]
            if scenario is not None:
                rec["participants"] = parts
            if parts:
                rec["train_acc"] = fedavg_train_acc(
                    core, shapes, result.g_trace[t], Xs, classes)
            history.append(rec)
        return history

    @staticmethod
    def _replay(protocol, endpoints, classes, result, plan, mask) -> None:
        """Book the eager run's exact message ledger: collation setup, one
        GradientMsg uplink per sent (round, client) at the rung the scan
        chose, skipped links, DP releases, link spend, the raw broadcast per
        participating client — then the exhaustion flag."""
        from repro.core.engine import LabelsMsg, SampleIdsMsg
        transport = protocol.transport
        transport.bind(endpoints)
        n = int(classes.shape[0])
        head = endpoints[0]
        for ep in endpoints[1:]:
            transport.send(LabelsMsg(head.name, ep.name, n))
            transport.send(SampleIdsMsg(head.name, ep.name, n))
        d, _ = _param_template(plan.core, tuple(endpoints[0].X.shape[1:]))
        flat = np.zeros((d,), np.float32)  # ledger prices size, not values
        executed = np.asarray(result.executed)
        sent = np.asarray(result.sent)
        rungs = np.asarray(result.codec_idx)
        budget = plan.budget
        budgeted = budget is not None and hasattr(transport, "link_spent")
        costs = (None if budget is None
                 else budget.payload_costs((d,)))
        for t in range(executed.shape[0]):
            if not executed[t]:
                continue
            for j in range(1, len(endpoints)):
                if not mask[t, j]:
                    continue
                link = (endpoints[j].name, head.name)
                if not sent[t, j]:
                    if budgeted:
                        transport.record_skip(link)
                    continue
                codec = None
                if budget is not None:
                    codec = budget.ladder[int(rungs[t, j])]
                elif plan.codec is not None:
                    codec = plan.codec
                wire_bits = (int(codec.wire_bits((d,)))
                             if codec is not None else None)
                if budgeted:
                    # spend-first, like the eager ladder walk: record_spend
                    # arms _pending_rung so the booking below stamps the
                    # chosen rung onto the ledger entry
                    rung = int(rungs[t, j])
                    transport.record_spend(link, costs[rung], rung)
                transport.send(GradientMsg(endpoints[j].name, head.name,
                                           flat, wire_bits=wire_bits))
                if transport.privacy is not None:
                    transport.accountant.record(endpoints[j].name)
            for j in range(1, len(endpoints)):
                if mask[t, j]:
                    transport.send(GradientMsg(head.name, endpoints[j].name,
                                               flat))
        if budgeted:
            transport.exhausted = bool(result.exhausted)


# ====================================================== Assisted Learning
@functools.lru_cache(maxsize=64)
def jitted_ridge(l2: float, lr: float):
    """One AL hop: closed-form weighted ridge of the running residual R on
    the agent's biased feature block, and the shrunk residual it ships.

        B = (Xb' W Xb + l2 I)^-1 Xb' W R,   R' = R - lr (Xb B)

    Cached per (l2, lr) so every hop of every session runs one program."""

    def fn(X, R, w):
        Xb = jnp.concatenate(
            [X, jnp.ones((X.shape[0], 1), X.dtype)], axis=1)
        Xw = Xb * w[:, None]
        A = Xw.T @ Xb + l2 * jnp.eye(Xb.shape[1], dtype=X.dtype)
        B = jnp.linalg.solve(A, Xw.T @ R)
        R_next = R - lr * (Xb @ B)
        return R_next, B

    return jax.jit(fn)


@dataclass
class FittedAL:
    """The AL boosting ensemble: sum of each component's lr-scaled ridge
    scores on its own feature block, argmaxed."""
    components: list
    num_classes: int
    history: list = field(default_factory=list)

    def decision_scores(self, Xs) -> jnp.ndarray:
        n = Xs[0].shape[0]
        total = jnp.zeros((n, self.num_classes), jnp.float32)
        for comp in self.components:
            X = Xs[comp.agent]
            Xb = jnp.concatenate(
                [X, jnp.ones((X.shape[0], 1), X.dtype)], axis=1)
            total = total + comp.alpha * (Xb @ comp.params)
        return total

    def predict(self, Xs) -> jnp.ndarray:
        return jnp.argmax(self.decision_scores(Xs), axis=-1)

    @property
    def num_rounds(self) -> int:
        return max((c.round for c in self.components), default=-1) + 1


@dataclass
class AssistedLearningVariant(ProtocolVariant):
    """Assisted Learning's residual-fitting rounds (Xian et al. 2020): the
    running [n, K] residual circulates the ring as a ResidualMsg, each agent
    L2-boosting it down with a private closed-form ridge.  ``lr`` is the
    boosting shrinkage, ``l2`` the per-hop ridge strength.  Eager-only: the
    data-dependent ring order has no fixed-shape lowering."""
    lr: float = 0.5
    l2: float = 1e-3

    name = "al"

    def bind(self, session) -> None:
        n = int(session.classes.shape[0])
        num = len(session.endpoints)
        masks = (None if session.scenario is None
                 else session.scenario.shard_weights(session.classes, num))
        session.vctx["fit_w"] = (jnp.ones((num, n), jnp.float32)
                                 if masks is None else masks)
        if session.state.proto is None:
            session.state.proto = {
                "R": jax.nn.one_hot(session.classes,
                                    session.cfg.num_classes)}

    def run_round(self, session, order: list[int], rec: dict) -> bool:
        st = session.state
        eps = {ep.agent_id: ep for ep in session.endpoints}
        fit_w = session.vctx["fit_w"]
        t = st.round
        R = st.proto["R"]
        for j, m in enumerate(order):
            # split per hop even though the ridge is deterministic: the
            # channel (DP noise, stochastic rounding) folds off this subkey
            st.key, sub = jax.random.split(st.key)
            R_next, B = jitted_ridge(float(self.l2), float(self.lr))(
                eps[m].X, R, fit_w[m])
            st.components.append(Component(m, t, float(self.lr), B))
            dst = eps[order[(j + 1) % len(order)]]
            shipped = session.transport.ship(eps[m], dst, R_next,
                                             ResidualMsg, key=sub)
            # budget skip: the next agent keeps fitting the stale residual
            R = R if shipped is None else shipped
        st.proto["R"] = R
        rec["resid_norm"] = float(jnp.linalg.norm(R))
        rec["train_acc"] = float(jnp.mean(
            (self.fitted(session).predict([ep.X for ep in session.endpoints])
             == session.classes).astype(jnp.float32)))
        return False

    def fitted(self, session) -> FittedAL:
        return FittedAL(session.state.components, session.cfg.num_classes,
                        session.state.history)


# ===================================================================== registry
PROTOCOLS = {
    "ascii": ASCIIVariant,
    "fedavg": FedAvgVariant,
    "al": AssistedLearningVariant,
}


def make_variant(name: str, **kw) -> ProtocolVariant:
    """Protocol-variant registry lookup for CLI / benchmark names."""
    if name not in PROTOCOLS:
        raise ValueError(
            f"unknown protocol {name!r}; expected {sorted(PROTOCOLS)}")
    return PROTOCOLS[name](**kw)
