"""One-program FedAvg: the homogeneous round as a ``lax.scan``.

ASCII's compiled backend (:mod:`repro.core.compiled`) cannot lower scenario
churn — the chain's *shape* changes per round.  FedAvg's round is
star-shaped and homogeneous, so churn is just a boolean participation mask
over fixed work: every roster slot fits every round, and non-participants
are masked out of the average.  That makes the whole session one scan over
the scenario's precomputed [T, M] mask, carrying the same spent-bit /
link-bit counters and the same noise-once-then-per-rung-codec channel
decomposition as the ASCII round body — and it is pinned bit-identical to
the eager :class:`~repro.scenarios.protocols.FedAvgVariant` loop
(tests/test_scenarios.py), skipped hops, exhaustion round, and all.

Key discipline mirrors the eager loop exactly: one split per roster slot
per *live* round (a round every participant churned out of — or one after
budget exhaustion — advances no PRNG state, because the eager engine never
enters ``run_round`` for it).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiled import _INT32_MAX, ladder_walk, rung_select
from repro.scenarios.protocols import (fedavg_combine, fedavg_init_flat,
                                       fedavg_local_delta,
                                       fedavg_fit_weights, _param_template)

#: A raw fp32 broadcast element (the downlink GradientMsg is never encoded).
_RAW_BITS = 32


@dataclass(frozen=True)
class FedAvgPlan:
    """Everything static about one compiled FedAvg run — hashable, so it
    keys the cached program.  ``codec``/``privacy``/``budget`` are the
    *same* objects the eager transport holds (a budgeted plan nulls
    ``codec``: the ladder drives rung choice, as in ``plan_for``)."""
    core: object
    num_classes: int
    num_agents: int
    max_rounds: int
    server_lr: float = 1.0
    codec: object = None
    privacy: object = None
    budget: object = None

    def __post_init__(self):
        if self.budget is not None:
            object.__setattr__(self, "codec", None)

    @property
    def ladder(self) -> tuple:
        if self.budget is not None:
            return self.budget.ladder
        return (self.codec,)

    @property
    def has_channel(self) -> bool:
        return (self.codec is not None or self.privacy is not None
                or self.budget is not None)


class FedAvgResult(NamedTuple):
    """Everything the replay + history rebuild needs, all fixed-shape."""
    g: jnp.ndarray          # [d] final flat global params
    g_trace: jnp.ndarray    # [T, d] post-round global params
    executed: jnp.ndarray   # [T] bool: round entered (not yet stopped)
    sent: jnp.ndarray       # [T, M] bool: uplink actually crossed the wire
    codec_idx: jnp.ndarray  # [T, M] int32 ladder rung per uplink (-1 = none)
    exhausted: jnp.ndarray  # [] bool: session budget can't afford min rung


def make_fedavg_fn(plan: FedAvgPlan, feature_shape: tuple):
    """Lower ``plan`` into a pure callable

        fedavg_fn(key, Xs, classes, mask, fit_w) -> FedAvgResult

    — one ``lax.scan`` over rounds with the [T, M] participation mask as
    the scanned input, roster slots unrolled in the body.  ``fit_w`` is the
    [M, n] fit-weight table (non-IID shard masks ride it as data, so one
    program serves every scenario of the same shape)."""
    core = plan.core
    k = plan.num_classes
    num = plan.num_agents
    codec, privacy, budget = plan.codec, plan.privacy, plan.budget
    ladder = plan.ladder
    has_channel = plan.has_channel
    d, _ = _param_template(core, tuple(feature_shape))
    if budget is not None:
        for cap in (budget.session_bits, budget.link_bits):
            if cap is not None and cap >= _INT32_MAX:
                raise ValueError(f"budget caps must fit int32 (the scan's "
                                 f"spent-bit counters), got {cap}")
        if max(budget.payload_costs((d,))) >= _INT32_MAX:
            raise ValueError("uplink payload costs must fit int32")

    def fedavg_fn(key: jax.Array, Xs: tuple, classes: jnp.ndarray,
                  mask: jnp.ndarray, fit_w: jnp.ndarray) -> FedAvgResult:
        from repro.comm.codecs import channel_apply
        classes = classes.astype(jnp.int32)
        n = classes.shape[0]
        onehot = jax.nn.one_hot(classes, k)
        g0 = fedavg_init_flat(core, feature_shape, key)
        if budget is not None:
            costs = tuple(jnp.asarray(c, jnp.int32)
                          for c in budget.payload_costs((d,)))
            min_cost = min(budget.payload_costs((d,)))
            from repro.core.engine import LabelsMsg, SampleIdsMsg
            setup_bits = (num - 1) * (LabelsMsg("", "", n).bits
                                      + SampleIdsMsg("", "", n).bits)
        bcast_bits = d * _RAW_BITS

        def round_body(carry, mask_t):
            key, g, stopped = carry["key"], carry["g"], carry["stopped"]
            executed = jnp.logical_not(stopped)
            # a round all participants churned out of advances nothing —
            # the eager engine never enters run_round for it
            live = executed & jnp.any(mask_t)
            kj = key
            rows, pmask, sent_l, rung_l = [], [], [], []
            for j in range(num):
                kj, sub = jax.random.split(kj)
                part = mask_t[j] & live
                dflat = fedavg_local_delta(core, feature_shape, g, sub,
                                           Xs[j], onehot, fit_w[j])
                if j == 0:
                    # the server's own delta joins the average off-wire
                    rows.append(dflat)
                    pmask.append(part)
                    sent_l.append(jnp.zeros((), bool))
                    rung_l.append(jnp.asarray(-1, jnp.int32))
                    continue
                if not has_channel:
                    rows.append(dflat)
                    pmask.append(part)
                    sent_l.append(part)
                    rung_l.append(jnp.where(part, 0, -1).astype(jnp.int32))
                    continue
                # ---- the wire: budget rung choice, DP noise, codec — the
                # same walk and traced channel the eager Transport.ship runs
                if budget is not None:
                    rem = jnp.asarray(_INT32_MAX, jnp.int32)
                    if budget.session_bits is not None:
                        rem_s = (jnp.asarray(budget.session_bits, jnp.int32)
                                 - carry["spent"])
                        rem = jnp.minimum(rem, rem_s)
                    if budget.link_bits is not None:
                        rem = jnp.minimum(
                            rem, jnp.asarray(budget.link_bits, jnp.int32)
                            - carry["link"][j])
                    rung = ladder_walk(costs, rem)
                    sendable = rung >= 0
                else:
                    rung = jnp.asarray(0, jnp.int32)
                    sendable = jnp.ones((), bool)
                # privacy noise is rung-independent: apply once, then
                # codec-only roundtrips per rung — bit-identical to the
                # eager fused channel (keys fold from `sub` only)
                noised, _ = channel_apply(None, privacy, dflat, sub, None)
                pairs = [channel_apply(c, None, noised, sub, None)[0]
                         for c in ladder]
                d_hat = rung_select(rung, pairs, dflat)
                sent = part & sendable
                rows.append(jnp.where(sent, d_hat, dflat))
                pmask.append(sent)
                sent_l.append(sent)
                rung_l.append(jnp.where(sent, rung, -1))
                if budget is not None:
                    cost = jnp.select(
                        [rung == i for i in range(len(ladder))],
                        list(costs), jnp.asarray(0, jnp.int32))
                    add = jnp.where(sent, cost, 0)
                    carry["spent"] = carry["spent"] + add
                    carry["link"] = carry["link"].at[j].add(add)
                    if budget.session_bits is not None:
                        carry["exhausted"] = carry["exhausted"] | (
                            part & (rem_s < min_cost))
            g_new = fedavg_combine(g, jnp.stack(rows), jnp.stack(pmask),
                                   plan.server_lr)
            g = jnp.where(live, g_new, g)
            if budget is not None:
                # raw broadcast to each participating client, counted
                # against the session cap (booked via transport.send in the
                # eager loop; links are never charged for the downlink)
                nb = jnp.sum(jnp.stack([mask_t[j] & live
                                        for j in range(1, num)]
                                       ).astype(jnp.int32))
                carry["spent"] = carry["spent"] + jnp.where(
                    live, nb * jnp.asarray(bcast_bits, jnp.int32), 0)
                if budget.session_bits is not None:
                    # the eager engine notices exhaustion at the *next*
                    # round's entry: this round finishes (broadcast and
                    # all), later ones never start
                    stopped = stopped | carry["exhausted"]
            # freeze the key stream on dead rounds (see module docstring)
            key = jax.random.wrap_key_data(jnp.where(
                live, jax.random.key_data(kj), jax.random.key_data(key)))
            carry = dict(carry, key=key, g=g, stopped=stopped)
            return carry, (g, executed, jnp.stack(sent_l),
                           jnp.stack(rung_l))

        init = {"key": key, "g": g0, "stopped": jnp.zeros((), bool)}
        if budget is not None:
            init["spent"] = jnp.asarray(setup_bits, jnp.int32)
            init["link"] = jnp.zeros((num,), jnp.int32)
            init["exhausted"] = jnp.zeros((), bool)
        fin, ys = jax.lax.scan(round_body, init,
                               mask.astype(bool), length=plan.max_rounds)
        return FedAvgResult(
            g=fin["g"], g_trace=ys[0], executed=ys[1], sent=ys[2],
            codec_idx=ys[3],
            exhausted=fin.get("exhausted", jnp.zeros((), bool)))

    return fedavg_fn


@functools.lru_cache(maxsize=64)
def _fedavg_program(plan: FedAvgPlan, feature_shape: tuple):
    return jax.jit(make_fedavg_fn(plan, feature_shape))


def fedavg_session(plan: FedAvgPlan, key: jax.Array,
                   Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
                   mask: jnp.ndarray, fit_w: jnp.ndarray) -> FedAvgResult:
    """Run one FedAvg session as a single compiled program (cached per
    (plan, feature shape)).  ``mask`` is the scenario's [max_rounds, M]
    participation schedule, ``fit_w`` the [M, n] fit-weight table."""
    Xs = tuple(jnp.asarray(x) for x in Xs)
    shapes = {tuple(x.shape[1:]) for x in Xs}
    if len(shapes) != 1:
        raise ValueError(f"fedavg needs one shared feature shape, got "
                         f"{sorted(shapes)}")
    mask = jnp.asarray(mask)
    if mask.shape != (plan.max_rounds, plan.num_agents):
        raise ValueError(
            f"participation mask shape {mask.shape} != "
            f"{(plan.max_rounds, plan.num_agents)}")
    return _fedavg_program(plan, shapes.pop())(key, Xs, classes, mask,
                                               fit_w)


__all__ = ["FedAvgPlan", "FedAvgResult", "fedavg_fit_weights",
           "fedavg_session", "make_fedavg_fn"]
