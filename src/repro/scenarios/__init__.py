"""Scenario engine: protocol variants x adversarial-reality knobs.

Two orthogonal axes over the one shared engine/channel stack:

  * :mod:`repro.scenarios.scenario`  — *who shows up, with what data, when*:
    per-round client subsampling, seeded straggler/dropout churn, non-IID
    shard partitions, clock-skewed stale reads.  Deterministic pure
    schedules — replayable, resumable, and consumable by compiled lowerings.
  * :mod:`repro.scenarios.protocols` — *what the round does*: FedAvg and
    Assisted Learning as :class:`~repro.core.engine.ProtocolVariant`s,
    shipping GradientMsg / ResidualMsg traffic through the same codecs,
    budgets, DP noise, and accountants as ASCII's interchange — one wire,
    comparable byte and epsilon ledgers.
  * :mod:`repro.scenarios.compiled`  — FedAvg's homogeneous round lowered
    into a single ``lax.scan`` over the participation mask, pinned
    bit-identical to the eager loop.
"""
from repro.scenarios.protocols import (PROTOCOLS, AssistedLearningVariant,
                                       FedAvgVariant, FittedAL,
                                       FittedFedAvg, fedavg_fit_weights,
                                       make_variant)
from repro.scenarios.scenario import PARTITIONS, PRESETS, Scenario

__all__ = [
    "PARTITIONS", "PRESETS", "PROTOCOLS", "AssistedLearningVariant",
    "FedAvgVariant", "FittedAL", "FittedFedAvg", "Scenario",
    "fedavg_fit_weights", "make_variant",
]
