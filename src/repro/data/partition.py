"""Vertical partitioning + sample-ID collation (Section II-A).

Agents hold disjoint column blocks of a holistic matrix, aligned by sample
ID.  `collate` implements the paper's convention that only the IDs present
at *every* agent are used ('only the overlapping data are used').
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np


def vertical_split(X: jnp.ndarray, splits: Sequence[int]) -> list[jnp.ndarray]:
    """Split columns into per-agent blocks of the given widths."""
    assert sum(splits) == X.shape[-1], (sum(splits), X.shape)
    out, ofs = [], 0
    for p in splits:
        out.append(X[:, ofs:ofs + p])
        ofs += p
    return out


def collate(ids: Sequence[np.ndarray], Xs: Sequence[jnp.ndarray]
            ) -> tuple[np.ndarray, list[jnp.ndarray]]:
    """Align per-agent matrices on the intersection of their sample IDs.

    Returns the common (sorted) IDs and each agent's rows re-ordered to that
    common key — the paper's 'consensus on how to collate/align the data'.
    """
    common = ids[0]
    for i in ids[1:]:
        common = np.intersect1d(common, i)
    out = []
    for agent_ids, X in zip(ids, Xs):
        order = {v: j for j, v in enumerate(np.asarray(agent_ids).tolist())}
        rows = np.array([order[v] for v in common.tolist()], dtype=np.int32)
        out.append(jnp.asarray(X)[rows])
    return common, out


def train_test_split(key_seed: int, n: int, train_frac: float = 0.7
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Paper Section VI: train on 70%, test on 30%, resampled per replicate."""
    rng = np.random.default_rng(key_seed)
    perm = rng.permutation(n)
    cut = int(round(train_frac * n))
    return perm[:cut], perm[cut:]


# ------------------------------------------------------- non-IID partitioners
# Horizontal sample shards layered on top of the vertical feature split
# (repro.scenarios): each agent keeps its feature block over *all* collated
# rows but only *fits* on its shard — the adversarial-reality non-IID knob.
# Both partitioners return a list of per-agent row-index arrays that cover
# range(n) exactly once, every shard nonempty (when n >= num_agents), fully
# determined by the seed.

def _rebalance_empties(shards: list[list[int]]) -> list[np.ndarray]:
    """Move one sample from the largest shard into each empty one, largest
    first — extreme skew may starve a shard, but every agent must hold at
    least one row to fit on."""
    for m, shard in enumerate(shards):
        if shard:
            continue
        donor = max(range(len(shards)), key=lambda i: len(shards[i]))
        if len(shards[donor]) > 1:
            shard.append(shards[donor].pop())
    return [np.asarray(sorted(s), dtype=np.int64) for s in shards]


def dirichlet_label_partition(seed: int, classes, num_agents: int,
                              alpha: float = 0.5) -> list[np.ndarray]:
    """Dirichlet label-skew shards (Hsu et al. 2019): for each class, split
    its samples across agents with proportions ~ Dir(alpha).  Small alpha
    concentrates each class on few agents (pathological non-IID); large
    alpha approaches IID."""
    if alpha <= 0:
        raise ValueError(f"Dirichlet alpha must be > 0, got {alpha}")
    if num_agents < 1:
        raise ValueError(f"need num_agents >= 1, got {num_agents}")
    rng = np.random.default_rng(seed)
    classes = np.asarray(classes)
    shards: list[list[int]] = [[] for _ in range(num_agents)]
    for c in np.unique(classes):
        idx = np.flatnonzero(classes == c)
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_agents, float(alpha)))
        cuts = np.floor(np.cumsum(props) * len(idx)).astype(int)[:-1]
        for m, part in enumerate(np.split(idx, cuts)):
            shards[m].extend(part.tolist())
    return _rebalance_empties(shards)


def quantity_proportions(num_agents: int, skew: float) -> np.ndarray:
    """Power-law shard proportions p_m ∝ (m+1)^-skew.  skew=0 is uniform;
    the spread max(p)/min(p) = num_agents^skew grows strictly monotonically
    in skew — the deterministically testable imbalance handle."""
    if skew < 0:
        raise ValueError(f"quantity skew must be >= 0, got {skew}")
    w = np.arange(1, num_agents + 1, dtype=np.float64) ** (-float(skew))
    return w / w.sum()


def quantity_partition(seed: int, n: int, num_agents: int,
                       skew: float = 1.0) -> list[np.ndarray]:
    """Quantity-skew shards: agent m holds a power-law-decaying share of a
    seeded permutation of the rows (largest-remainder apportionment, so
    sizes sum to n exactly)."""
    if num_agents < 1:
        raise ValueError(f"need num_agents >= 1, got {num_agents}")
    props = quantity_proportions(num_agents, skew)
    raw = props * n
    sizes = np.floor(raw).astype(int)
    rem = n - sizes.sum()
    order = np.argsort(-(raw - np.floor(raw)), kind="stable")
    sizes[order[:rem]] += 1
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    shards = [perm[s:s + z].tolist()
              for s, z in zip(np.cumsum(sizes) - sizes, sizes)]
    return _rebalance_empties(shards)
