"""Vertical partitioning + sample-ID collation (Section II-A).

Agents hold disjoint column blocks of a holistic matrix, aligned by sample
ID.  `collate` implements the paper's convention that only the IDs present
at *every* agent are used ('only the overlapping data are used').
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np


def vertical_split(X: jnp.ndarray, splits: Sequence[int]) -> list[jnp.ndarray]:
    """Split columns into per-agent blocks of the given widths."""
    assert sum(splits) == X.shape[-1], (sum(splits), X.shape)
    out, ofs = [], 0
    for p in splits:
        out.append(X[:, ofs:ofs + p])
        ofs += p
    return out


def collate(ids: Sequence[np.ndarray], Xs: Sequence[jnp.ndarray]
            ) -> tuple[np.ndarray, list[jnp.ndarray]]:
    """Align per-agent matrices on the intersection of their sample IDs.

    Returns the common (sorted) IDs and each agent's rows re-ordered to that
    common key — the paper's 'consensus on how to collate/align the data'.
    """
    common = ids[0]
    for i in ids[1:]:
        common = np.intersect1d(common, i)
    out = []
    for agent_ids, X in zip(ids, Xs):
        order = {v: j for j, v in enumerate(np.asarray(agent_ids).tolist())}
        rows = np.array([order[v] for v in common.tolist()], dtype=np.int32)
        out.append(jnp.asarray(X)[rows])
    return common, out


def train_test_split(key_seed: int, n: int, train_frac: float = 0.7
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Paper Section VI: train on 70%, test on 30%, resampled per replicate."""
    rng = np.random.default_rng(key_seed)
    perm = rng.permutation(n)
    cut = int(round(train_frac * n))
    return perm[:cut], perm[cut:]
