"""Host-side input pipeline: deterministic shuffled batching with epoch
reshuffling, for both tabular (ASCII agents) and token-stream (LM) data."""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


def batched_indices(n: int, batch_size: int, seed: int,
                    drop_remainder: bool = True) -> Iterator[np.ndarray]:
    """Infinite shuffled index batches (reshuffled each epoch)."""
    rng = np.random.default_rng(seed)
    while True:
        perm = rng.permutation(n)
        end = (n // batch_size) * batch_size if drop_remainder else n
        for i in range(0, end, batch_size):
            yield perm[i:i + batch_size]


def lm_batches(key, *, vocab_size: int, batch: int, seq_len: int,
               copy_prob: float = 0.35) -> Iterator[dict]:
    """Infinite synthetic LM batches (see data/synthetic.token_stream)."""
    from repro.data.synthetic import token_stream
    i = 0
    while True:
        sub = jax.random.fold_in(key, i)
        tokens = token_stream(sub, vocab_size=vocab_size, batch=batch,
                              seq_len=seq_len, copy_prob=copy_prob)
        yield {"tokens": tokens,
               "sample_weight": jnp.ones((batch,), jnp.float32)}
        i += 1
