"""Synthetic dataset generators for the paper's experiments.

MIMIC-III is credential-gated (PhysioNet DUA) and UCI/Fashion-MNIST are not
reachable offline, so per DESIGN.md §6 we generate surrogates with the
paper's exact dimensionalities, class counts, and per-agent feature splits.
Blob data is generated exactly as described (isotropic Gaussian blobs).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Dataset:
    name: str
    X: jnp.ndarray          # [n, p]
    classes: jnp.ndarray    # [n] int32
    num_classes: int
    splits: tuple[int, ...]  # per-agent feature counts (sum == p)


def gaussian_blobs(key, *, n: int, num_features: int, num_classes: int,
                   cluster_std: float = 1.0, center_box: float = 10.0,
                   num_redundant: int = 0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Isotropic Gaussian blobs (sklearn.datasets.make_blobs semantics)."""
    ck, xk, lk, rk = jax.random.split(key, 4)
    centers = jax.random.uniform(ck, (num_classes, num_features),
                                 minval=-center_box, maxval=center_box)
    classes = jax.random.randint(lk, (n,), 0, num_classes)
    X = centers[classes] + cluster_std * jax.random.normal(xk, (n, num_features))
    if num_redundant:
        noise = jax.random.normal(rk, (n, num_redundant)) * center_box / 2
        X = jnp.concatenate([X, noise], axis=-1)
    return X, classes.astype(jnp.int32)


def blob_fig3(key, n: int = 1000) -> Dataset:
    """Fig. 3a: 10-class blobs, 8 features, 4 agents x 2 features."""
    X, c = gaussian_blobs(key, n=n, num_features=8, num_classes=10,
                          cluster_std=1.5)
    return Dataset("blob", X, c, 10, (2, 2, 2, 2))


def blob_fig4(key, n: int = 1000) -> Dataset:
    """Fig. 4a: 10-class blobs, 5 informative + 195 redundant features,
    randomly divided into 2 agents x 100 features."""
    X, c = gaussian_blobs(key, n=n, num_features=5, num_classes=10,
                          cluster_std=1.0, num_redundant=195)
    perm = jax.random.permutation(jax.random.fold_in(key, 7), 200)
    return Dataset("blob200", X[:, perm], c, 10, (100, 100))


def blob_fig6(key, n: int = 1000) -> Dataset:
    """Fig. 6a: 20-class blobs, 20 features, 20 agents x 1 feature."""
    X, c = gaussian_blobs(key, n=n, num_features=20, num_classes=20,
                          cluster_std=1.0)
    return Dataset("blob20", X, c, 20, tuple([1] * 20))


def _tabular_surrogate(key, *, name, n, p, num_classes, splits,
                       informative_frac=0.7, noise=1.0, nonlinear=True):
    """Generic tabular surrogate: low-rank class-dependent means + optional
    sign interactions, standardized like a real tabular pull."""
    km, kx, kc, ki = jax.random.split(key, 4)
    num_inf = max(2, int(p * informative_frac))
    means = jax.random.normal(km, (num_classes, num_inf)) * 2.0
    classes = jax.random.randint(kc, (n,), 0, num_classes).astype(jnp.int32)
    X_inf = means[classes] + noise * jax.random.normal(kx, (n, num_inf))
    if nonlinear:
        # make a few informative columns only pairwise-informative
        X_inf = X_inf.at[:, :2].set(
            X_inf[:, :2] * jnp.sign(X_inf[:, 2:4] + 1e-3))
    X_noise = jax.random.normal(ki, (n, p - num_inf))
    X = jnp.concatenate([X_inf, X_noise], axis=-1)
    perm = jax.random.permutation(jax.random.fold_in(key, 11), p)
    X = X[:, perm]
    X = (X - X.mean(0)) / (X.std(0) + 1e-6)
    return Dataset(name, X, classes, num_classes, splits)


def mimic_surrogate(key, n: int = 15000) -> Dataset:
    """MIMIC-III extended-LoS surrogate: n=15000, p=16, K=2, split 3/12+1.

    The paper partitions 'according to the original data sources, one
    holding three features and the other holding 12' (16 total; the
    remaining feature rides with the larger source)."""
    return _tabular_surrogate(key, name="mimic", n=n, p=16, num_classes=2,
                              splits=(3, 13), informative_frac=0.6)


def qsar_surrogate(key, n: int = 1055) -> Dataset:
    """QSAR biodegradation surrogate: p=41, K=2, split 20/21."""
    return _tabular_surrogate(key, name="qsar", n=n, p=41, num_classes=2,
                              splits=(20, 21), informative_frac=0.5)


def wine_surrogate(key, n: int = 1599) -> Dataset:
    """Red-wine quality surrogate: p=11, K=6, split 6/5 (Fig. 3d) or
    11 x 1-feature agents (Fig. 6b)."""
    return _tabular_surrogate(key, name="wine", n=n, p=11, num_classes=6,
                              splits=(6, 5), informative_frac=0.9,
                              noise=1.6, nonlinear=False)


def fashion_surrogate(key, n: int = 4000, side: int = 28) -> Dataset:
    """Fashion-MNIST surrogate: 10 classes of 28x28 'garment' templates
    (class-dependent smooth random fields) + pixel noise; agents hold the
    left/right image halves (Fig. 5)."""
    kt, kx, kc = jax.random.split(key, 3)
    freq = jnp.linspace(0.3, 1.2, 4)
    coords = jnp.linspace(-1, 1, side)
    xx, yy = jnp.meshgrid(coords, coords)
    phases = jax.random.uniform(kt, (10, 4, 2), maxval=2 * jnp.pi)
    amps = jax.random.normal(jax.random.fold_in(kt, 1), (10, 4))

    def template(c):
        img = sum(amps[c, i] * jnp.sin(freq[i] * 3 * xx + phases[c, i, 0])
                  * jnp.cos(freq[i] * 3 * yy + phases[c, i, 1])
                  for i in range(4))
        return img

    templates = jnp.stack([template(c) for c in range(10)])   # [10, s, s]
    # class signal ramps left->right: the left-half agent alone is weak and
    # genuinely needs assistance (paper Fig. 5: B holds the other half)
    ramp = jnp.linspace(0.25, 1.3, side)[None, None, :]
    templates = templates * ramp
    classes = jax.random.randint(kc, (n,), 0, 10).astype(jnp.int32)
    imgs = templates[classes] + 1.1 * jax.random.normal(kx, (n, side, side))
    # left half -> agent A (columns 0..13), right half -> agent B
    X = imgs.reshape(n, side * side)
    # reorder pixels so the first side*side//2 belong to the left half
    col_idx = jnp.arange(side * side).reshape(side, side)
    left = col_idx[:, :side // 2].reshape(-1)
    right = col_idx[:, side // 2:].reshape(-1)
    X = X[:, jnp.concatenate([left, right])]
    half = side * (side // 2)
    return Dataset("fashion", X, classes, 10, (half, side * side - half))


def token_stream(key, *, vocab_size: int, batch: int, seq_len: int,
                 num_classes: int | None = None, copy_prob: float = 0.35):
    """Synthetic LM token batches for the end-to-end training driver and
    smoke tests: a genuine first-order Markov chain — with probability
    ``copy_prob`` token t is the affine map ``31 * t_{prev} + 7 (mod V)``
    of the *emitted* predecessor, else uniform noise.

    (The seed version applied the map to a pre-noise base sequence, which
    makes consecutive *output* tokens independent — ~zero learnable signal
    at any ``copy_prob``; that is why the tier-1 loss-decrease check could
    never pass.)  ``copy_prob`` scales the signal: at 1.0 the chain is
    deterministic and the next-token loss can approach 0."""
    kt, kl = jax.random.split(key)
    noise = jax.random.randint(kt, (batch, seq_len), 0, vocab_size)
    use_map = jax.random.bernoulli(kl, copy_prob, (batch, seq_len))

    def step(prev, xs):
        nz, um = xs
        nxt = jnp.where(um, (prev * 31 + 7) % vocab_size, nz)
        return nxt, nxt

    first = noise[:, 0]
    _, rest = jax.lax.scan(step, first,
                           (noise[:, 1:].T, use_map[:, 1:].T))
    tokens = jnp.concatenate([first[:, None], rest.T], axis=1)
    return tokens.astype(jnp.int32)
