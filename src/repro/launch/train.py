"""End-to-end LM training driver with the ignorance-weighted (WST) loss.

Examples:
  # ~100M-param model, a few hundred steps on synthetic token streams:
  PYTHONPATH=src python -m repro.launch.train --preset 100m --steps 300

  # any assigned architecture at reduced (smoke) size:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
      --steps 20 --batch 4 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.registry import ARCHS
from repro.data.pipeline import lm_batches
from repro.optim.optimizers import adamw
from repro.optim.schedules import cosine_with_warmup
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~95M params: the 'train a ~100M model for a few hundred steps' driver
    "100m": ArchConfig(
        name="lm-100m", arch_type="dense", num_layers=10, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, qk_norm=True, act="silu", dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned architecture id")
    ap.add_argument("--preset", default=None, choices=list(PRESETS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt_dir", default="")
    args = ap.parse_args()

    if args.preset:
        cfg = PRESETS[args.preset]
    else:
        cfg = ARCHS[args.arch or "qwen3-0.6b"]
        if args.reduced:
            cfg = cfg.reduced()

    sched = cosine_with_warmup(args.lr, max(args.steps // 20, 5), args.steps)
    opt = adamw(sched, weight_decay=0.01, grad_clip_norm=1.0)
    trainer = Trainer(cfg, opt, TrainerConfig(
        steps=args.steps, log_every=max(args.steps // 20, 1),
        ckpt_every=(args.steps // 2 if args.ckpt_dir else 0),
        ckpt_dir=args.ckpt_dir or "/tmp/repro_ckpt"))

    key = jax.random.key(0)
    data = lm_batches(jax.random.fold_in(key, 1), vocab_size=cfg.vocab_size,
                      batch=args.batch, seq_len=args.seq)

    params, _ = trainer.init(jax.random.fold_in(key, 2))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params:,} steps={args.steps} "
          f"batch={args.batch} seq={args.seq}")

    def log(step, m):
        print(f"step {step:5d}  loss {m['loss']:.4f}  "
              f"wall {m['wall']:.1f}s", flush=True)

    params, _, history = trainer.run(key, data, on_metrics=log)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
