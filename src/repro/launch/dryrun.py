"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, and extract the roofline terms from the compiled
artifact.  This is how the distribution config is proven coherent without
real hardware (DESIGN.md §5).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi_pod] [--quick]

Artifacts: one JSON per (arch, shape, mesh) under artifacts/dryrun/.
"""
# The build box has ONE real CPU device; the dry-run needs 512 placeholder
# devices.  Must run before ANY other import that initializes jax.
import os  # noqa: E402

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape  # noqa: E402
from repro.configs.registry import (ARCHS, SKIPS,  # noqa: E402
                                    long_context_overrides)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api  # noqa: E402
from repro.optim.optimizers import adamw  # noqa: E402
from repro.sharding import rules  # noqa: E402
from repro.sharding.context import mesh_context  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip, 1 link eff.)

# HLO result-typed collective instruction, e.g.
#   %all-gather.21 = f32[16,4096,1,128]{2,1,0,3} all-gather(%fusion.1), ...
# Post-optimization HLO prints operands by name only, so payload bytes are
# derived from the RESULT type and converted to approximate bytes-on-wire
# per device via _WIRE_FACTOR (all-reduce = reduce-scatter + all-gather of
# the same payload ~= 2x; the rest move ~result once).
_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\])\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "s64": 8,
          "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dt, 2)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Approx. wire bytes per device for every collective in the HLO."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_body, dt, dims, kind = m.groups()
        if tuple_body is not None:
            total = sum(_shape_bytes(t, d)
                        for t, d in _TUPLE_SHAPE_RE.findall(tuple_body))
        else:
            total = _shape_bytes(dt, dims)
        out[kind] = out.get(kind, 0) + int(total * _WIRE_FACTOR[kind])
    return out


def effective_config(arch: str, shape: InputShape,
                     remat: str | None = None) -> ArchConfig:
    cfg = ARCHS[arch]
    if shape.name == "long_500k":
        cfg = long_context_overrides(cfg)
    if shape.kind == "train":
        # block remat is the production default for training: without it the
        # stacked scan residuals of the larger archs exceed v5e HBM.
        cfg = cfg.with_overrides(remat=remat or "block")
    elif remat:
        cfg = cfg.with_overrides(remat=remat)
    return cfg


def input_specs(cfg: ArchConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    if shape.kind in ("train", "prefill"):
        text = s
        batch = {}
        if cfg.frontend == "vision":
            text = s - cfg.num_frontend_tokens
            batch["patch_emb"] = jax.ShapeDtypeStruct(
                (b, cfg.num_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
        batch["tokens"] = jax.ShapeDtypeStruct((b, text), i32)
        if shape.kind == "train":
            batch["sample_weight"] = jax.ShapeDtypeStruct((b,), f32)
        return batch
    # decode: one new token against a seq_len cache
    s_cache = s
    caches = jax.eval_shape(lambda: api.init_cache(cfg, b, s_cache))
    tokens = jax.ShapeDtypeStruct((b, 1), i32)
    return {"caches": caches, "tokens": tokens}


def _opt_specs(params_shape, cfg, mesh):
    # adam m/v mirror the parameter tree; path suffixes still match rules
    return {"m": rules.param_specs(params_shape, cfg, mesh),
            "v": rules.param_specs(params_shape, cfg, mesh)}


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) useful-FLOPs yardstick."""
    params_shape = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0), cfg))
    n_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_shape))
    if cfg.is_moe:
        # subtract inactive expert params
        e, k = cfg.num_experts, cfg.top_k
        expert_params = 3 * cfg.d_model * cfg.moe_d_ff
        # count MoE sublayers precisely
        if cfg.layer_pattern:
            per_unit = sum(1 for i in range(len(cfg.layer_pattern))
                           if cfg.moe_every <= 1 or i % cfg.moe_every == 1)
            n_moe = per_unit * (cfg.num_layers // len(cfg.layer_pattern))
        else:
            n_moe = cfg.num_layers if cfg.moe_every <= 1 else cfg.num_layers // cfg.moe_every
        n_active = n_total - n_moe * expert_params * (e - k)
    else:
        n_active = n_total
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def _scale_units(cfg: ArchConfig, u: int) -> ArchConfig:
    """A u-unit, unrolled variant of cfg (same widths) for cost extraction."""
    unit_len = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    kw = dict(scan_layers=False, num_layers=unit_len * u)
    if cfg.encoder_layers:
        kw["encoder_layers"] = u
    return cfg.with_overrides(**kw)


def _num_units(cfg: ArchConfig) -> int:
    unit_len = len(cfg.layer_pattern) if cfg.layer_pattern else 1
    return cfg.num_layers // unit_len


def _build_lowered(cfg: ArchConfig, shape: InputShape, mesh,
                   cache_mode: str):
    """Lower the step function for (cfg, shape) on mesh. Returns Lowered."""
    params_shape = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0), cfg))
    pspecs = rules.param_specs(params_shape, cfg, mesh)
    repl = NamedSharding(mesh, P())
    with mesh, mesh_context(mesh):
        if shape.kind == "train":
            opt = adamw(3e-4)
            train_step = api.make_train_step(cfg, opt)
            opt_shape = jax.eval_shape(opt.init, params_shape)
            ospecs = {"m": pspecs, "v": pspecs}
            bspecs = rules.batch_spec(cfg, shape, mesh)
            batch_sds = input_specs(cfg, shape)
            jitted = jax.jit(
                train_step,
                in_shardings=(rules.named(mesh, pspecs),
                              rules.named(mesh, ospecs),
                              rules.named(mesh, bspecs), repl),
                out_shardings=(rules.named(mesh, pspecs),
                               rules.named(mesh, ospecs), repl),
                donate_argnums=(0, 1))
            return jitted.lower(params_shape, opt_shape, batch_sds,
                                jax.ShapeDtypeStruct((), jnp.int32))
        if shape.kind == "prefill":
            prefill = api.make_prefill_step(cfg)
            bspecs = rules.batch_spec(cfg, shape, mesh)
            batch_sds = input_specs(cfg, shape)
            jitted = jax.jit(prefill,
                             in_shardings=(rules.named(mesh, pspecs),
                                           rules.named(mesh, bspecs)))
            return jitted.lower(params_shape, batch_sds)
        # decode
        s_cache = (api.cache_length(cfg, shape.seq_len)
                   if cache_mode == "ring" else shape.seq_len)
        serve = api.make_serve_step(cfg, cache_mode)
        ins = input_specs(cfg, shape)
        caches_sds = jax.eval_shape(
            lambda: api.init_cache(cfg, shape.global_batch, s_cache))
        cspecs = rules.cache_spec_tree(caches_sds, cfg, mesh,
                                       shape.global_batch, s_cache)
        dp = rules.data_axes(mesh)
        b_ax = dp if shape.global_batch % np.prod(
            [mesh.shape[a] for a in dp]) == 0 else None
        tok_sh = NamedSharding(mesh, P(b_ax, None))
        jitted = jax.jit(serve,
                         in_shardings=(rules.named(mesh, pspecs),
                                       rules.named(mesh, cspecs),
                                       tok_sh, repl),
                         donate_argnums=(1,))
        return jitted.lower(params_shape, caches_sds, ins["tokens"],
                            jax.ShapeDtypeStruct((), jnp.int32))


def _extract_costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collectives": collective_bytes(compiled.as_text())}


def _corrected_costs(cfg: ArchConfig, shape: InputShape, mesh,
                     cache_mode: str) -> dict:
    """Trip-count-corrected per-chip costs.

    XLA's cost analysis counts a while/scan body ONCE regardless of trip
    count (verified empirically), so the scanned compile under-reports both
    flops and collective bytes.  We compile unrolled 1-unit and 2-unit
    variants at full width: body = c2 - c1, total = c1 + (U - 1) * body.

    Grad-accumulation is handled by measuring ONE microbatch explicitly
    (batch/m at microbatches=1) and scaling by m — XLA sometimes unrolls a
    small accumulation loop (then the body is counted m times) and sometimes
    keeps the while (counted once), so measuring the loop itself is
    unreliable either way.
    """
    m = cfg.microbatches
    if shape.kind == "train" and m > 1:
        shape = InputShape(shape.name, shape.seq_len,
                           shape.global_batch // m, shape.kind)
        cfg = cfg.with_overrides(microbatches=1)
        one = _corrected_costs(cfg, shape, mesh, cache_mode)
        return {"flops": one["flops"] * m, "bytes": one["bytes"] * m,
                "collectives": {k: v * m
                                for k, v in one["collectives"].items()}}
    u_total = _num_units(cfg)
    c1 = _extract_costs(_build_lowered(_scale_units(cfg, 1), shape, mesh,
                                       cache_mode).compile())
    if u_total == 1:
        return c1
    c2 = _extract_costs(_build_lowered(_scale_units(cfg, 2), shape, mesh,
                                       cache_mode).compile())

    def lin(a, b):
        return max(a, a + (u_total - 1) * (b - a))

    kinds = set(c1["collectives"]) | set(c2["collectives"])
    return {
        "flops": lin(c1["flops"], c2["flops"]),
        "bytes": lin(c1["bytes"], c2["bytes"]),
        "collectives": {k: int(lin(c1["collectives"].get(k, 0),
                                   c2["collectives"].get(k, 0)))
                        for k in kinds},
    }


def run_pair(arch: str, shape_name: str, multi_pod: bool = False,
             cache_mode: str = "full", save: bool = True,
             tag: str = "", remat: str | None = None,
             overrides: dict | None = None) -> dict:
    shape = INPUT_SHAPES[shape_name]
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name,
                "skipped": SKIPS[(arch, shape_name)]}
    cfg = effective_config(arch, shape, remat=remat)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "x".join(map(str, mesh.devices.shape)),
                 "cache_mode": cache_mode}
    t0 = time.time()
    lowered = _build_lowered(cfg, shape, mesh, cache_mode)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            # The CPU backend has no native bf16 compute: every bf16 dot and
            # most intermediates are upcast to f32, so temp_bytes over-counts
            # the TPU bf16 working set by ~2x (verified against the buffer
            # assignment dump).  This adjusted figure is what EXPERIMENTS.md
            # compares against the 16 GB v5e HBM budget.
            "temp_bytes_bf16_adj": int(getattr(mem, "temp_size_in_bytes", 0)
                                       ) // 2,
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    rec["cost_scanned"] = _extract_costs(compiled)
    hlo = compiled.as_text()
    rec["hlo_ops"] = {k: hlo.count(f" {k}(") for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute", "fusion")}
    # trip-count-corrected costs (see _corrected_costs docstring)
    corr = _corrected_costs(cfg, shape, mesh, cache_mode)
    rec["cost"] = {"flops": corr["flops"], "bytes": corr["bytes"]}
    rec["collectives"] = corr["collectives"]

    # ---- roofline terms (per chip; SPMD program costs are per-partition)
    n_chips = mesh.devices.size
    flops = corr["flops"]
    bytes_hbm = corr["bytes"]
    coll = sum(corr["collectives"].values())
    rec["roofline"] = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_hbm / HBM_BW,
        "collective_s": coll / ICI_BW,
        "model_flops": model_flops(cfg, shape),
    }
    terms = {k: rec["roofline"][k] for k in
             ("compute_s", "memory_s", "collective_s")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    rec["n_chips"] = n_chips
    params_shape = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0), cfg))
    rec["params"] = int(sum(int(np.prod(x.shape))
                            for x in jax.tree.leaves(params_shape)))

    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        name = f"{arch}_{shape_name}_{rec['mesh']}{tag}.json"
        with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi_pod", action="store_true")
    ap.add_argument("--cache_mode", default="full", choices=["full", "ring"])
    ap.add_argument("--remat", default=None, choices=[None, "none", "block"])
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    pairs = []
    if args.all:
        pairs = [(a, s) for a in ARCHS for s in INPUT_SHAPES]
    else:
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
        archs = [args.arch] if args.arch else list(ARCHS)
        pairs = [(a, s) for a in archs for s in shapes]

    for arch, shape in pairs:
        try:
            rec = run_pair(arch, shape, multi_pod=args.multi_pod,
                           cache_mode=args.cache_mode, tag=args.tag,
                           remat=args.remat)
        except Exception as e:  # keep sweeping; failures are bugs to fix
            print(f"FAIL  {arch:24s} {shape:12s} {type(e).__name__}: "
                  f"{str(e)[:2000]}")
            continue
        if "skipped" in rec:
            print(f"SKIP  {arch:24s} {shape:12s} {rec['skipped']}")
            continue
        r = rec["roofline"]
        print(f"OK    {arch:24s} {shape:12s} mesh={rec['mesh']} "
              f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
              f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
              f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}")


if __name__ == "__main__":
    main()
