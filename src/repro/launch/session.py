"""Session driver: run an ASCII engine session from the command line.

Wires a dataset, a scheduler (via the variant name), and a transport into
``core.engine.Protocol``, with optional mid-run checkpointing and resume —
the launch-layer entry point for protocol runs, the way ``launch/train.py``
is for LM training.

  PYTHONPATH=src python -m repro.launch.session --dataset blob3 \
      --variant ascii --rounds 6 --transport metered
  PYTHONPATH=src python -m repro.launch.session --ckpt-dir /tmp/sess \
      --stop-after 2                       # save mid-run ...
  PYTHONPATH=src python -m repro.launch.session --ckpt-dir /tmp/sess \
      --resume                             # ... and pick the run back up
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.control import (AdaptiveController, BudgetAwareScheduler,
                           ServeController, make_accountant)
from repro.control.adaptive import SERVE_STATS
from repro.control.adaptive import STATS as CONTROLLER_STATS
from repro.core.engine import (InProcessTransport, MeshRingTransport,
                               MeteredTransport, Protocol, SessionConfig,
                               endpoints_for, variant_setup)
from repro.data.partition import train_test_split, vertical_split
from repro.data import synthetic
from repro.learners.logistic import LogisticRegression
from repro.learners.mlp import MLP
from repro.learners.tree import DecisionTree
from repro.scenarios import PARTITIONS, PRESETS, PROTOCOLS, Scenario, \
    make_variant
from repro.telemetry import Telemetry

DATASETS = {
    "blob3": lambda key, n: synthetic.blob_fig3(key, n=n),
    "blob4": lambda key, n: synthetic.blob_fig4(key, n=n),
    "blob6": lambda key, n: synthetic.blob_fig6(key, n=n),
    "wine": lambda key, n: synthetic.wine_surrogate(key),
}

TRANSPORTS = {
    "inprocess": InProcessTransport,
    "metered": MeteredTransport,
    "meshring": MeshRingTransport,
}

LEARNERS = {
    # tree is eager-only; logistic/mlp carry a LearnerCore and can ride
    # --backend compiled
    "tree": lambda args: DecisionTree(depth=args.depth, num_thresholds=8),
    "logistic": lambda args: LogisticRegression(steps=args.steps),
    "mlp": lambda args: MLP(hidden=(32, 16), steps=args.steps),
}


def _print_comm(transport, show_ema=True):
    """Wire-channel summary lines (codec ledger, budget state, DP spend)."""
    if transport.controller is not None:
        line = (f"controller: stat={transport.controller.stat},"
                f"rungs={len(transport.controller.ladder)}")
        if show_ema:        # compiled runs keep the EMA in the scan carry
            line += f",ema={float(transport.ctrl_state):.4f}"
        print(line)
    if transport.codec is not None:
        line = f"codec={type(transport.codec).__name__}"
        if isinstance(transport, MeteredTransport):
            line += (f",ignorance_bits="
                     f"{transport.bits_by_kind().get('ignorance', 0)}")
        print(line)
    if transport.serve_codec is not None:
        print(f"serve_codec={type(transport.serve_codec).__name__}")
    if transport.serve_controller is not None:
        print(f"serve_controller: stat={transport.serve_controller.stat},"
              f"rungs={len(transport.serve_controller.ladder)}")
    if hasattr(transport, "budget"):
        print(f"budget: spent={transport.total_bits}b,"
              f"skipped_hops={len(transport.skipped)},"
              f"exhausted={transport.exhausted}")
    if getattr(transport, "privacy", None) is not None:
        print(f"dp: {json.dumps(transport.accountant.report(transport.privacy))}")


def _print_serve(transport, preds, cte, before_bits):
    """Serve-path summary: distributed-prediction accuracy and the encoded
    ScoreBlockMsg bits this predict call booked."""
    line = f"serve: acc={float(jnp.mean(preds == cte)):.3f}"
    if isinstance(transport, MeteredTransport):
        bits = transport.bits_by_kind().get("score_block", 0) - before_bits
        line += f",score_block_bits={bits}"
    if hasattr(transport, "budget"):
        line += f",skipped_hops={len(transport.skipped)}"
    print(line)


def _finish_telemetry(args, telemetry, transport, dash=None):
    """Stop the profiler (if running), settle the dashboard's last frame,
    and write the trace/metrics artifacts; called at both backends'
    exits, after all traffic."""
    if args.profile_dir:
        jax.profiler.stop_trace()
        print(f"profile: wrote {args.profile_dir}")
    if dash is not None:
        dash.final()
    if telemetry is not None:
        telemetry.write_artifacts(trace=args.trace or None,
                                  metrics_out=args.metrics_out or None,
                                  transport=transport)
        for path in (args.trace, args.metrics_out):
            if path:
                print(f"telemetry: wrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dataset", default="blob3", choices=sorted(DATASETS))
    ap.add_argument("--n", type=int, default=600)
    ap.add_argument("--variant", default="ascii",
                    choices=["ascii", "simple", "random", "async"])
    ap.add_argument("--protocol", default="ascii",
                    choices=sorted(PROTOCOLS),
                    help="protocol variant (repro.scenarios): ascii = the "
                         "paper's ignorance interchange; fedavg = federated "
                         "averaging over a homogeneous functional roster "
                         "(GradientMsg uplinks through the same codec/"
                         "budget/DP channel); al = assisted-learning "
                         "residual-fitting rounds (ResidualMsg around the "
                         "ring, eager only)")
    ap.add_argument("--scenario", default="",
                    choices=[""] + sorted(PRESETS),
                    help="adversarial-reality preset (repro.scenarios): "
                         "clean/noniid/churn/subsample; fixes the knob "
                         "flags below")
    ap.add_argument("--subsample", type=float, default=0.0,
                    help="per-round client subsampling fraction in (0, 1] "
                         "(FedAvg's C; unlocks --accountant subsampled-rdp)")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-round permanent-departure probability")
    ap.add_argument("--straggle", type=float, default=0.0,
                    help="per-(round, agent) transient-miss probability")
    ap.add_argument("--partition", default="iid",
                    choices=sorted(PARTITIONS),
                    help="non-IID horizontal shards: dirichlet label skew "
                         "or power-law quantity skew (agents fit only on "
                         "their shard's rows)")
    ap.add_argument("--skew", type=float, default=0.5,
                    help="partition skew: dirichlet alpha / quantity "
                         "exponent")
    ap.add_argument("--clock-skew", default="",
                    help="comma-separated per-agent barrier lags (ASCII "
                         "--variant async only), e.g. 0,0,2,1")
    ap.add_argument("--scenario-seed", type=int, default=0,
                    help="seed of the scenario's churn/partition draws")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--transport", default="metered",
                    choices=sorted(TRANSPORTS))
    ap.add_argument("--learner", default="tree", choices=sorted(LEARNERS))
    ap.add_argument("--depth", type=int, default=3,
                    help="tree depth (tree learner only)")
    ap.add_argument("--steps", type=int, default=150,
                    help="optimizer steps (logistic/mlp learners)")
    ap.add_argument("--backend", default="eager",
                    choices=["eager", "compiled"],
                    help="compiled lowers the whole run into one lax.scan "
                         "program (ascii/simple/async variants, functional "
                         "learners; budget-aware scheduling lowers too)")
    ap.add_argument("--codec", default="",
                    choices=["", "fp32", "fp16", "int8", "int4", "topk"],
                    help="wire codec for outgoing ignorance scores "
                         "(repro.comm.codecs; the ledger books encoded "
                         "bits; empty = raw fp32 messages)")
    ap.add_argument("--serve-codec", default="",
                    choices=["", "fp32", "fp16", "int8", "int4", "topk"],
                    help="wire codec for prediction-time ScoreBlockMsg "
                         "traffic (defaults to --codec when that is set; "
                         "serve blocks are DP-noised, encoded, and booked "
                         "at their encoded size like training hops)")
    ap.add_argument("--byte-budget", type=int, default=0,
                    help="session byte budget: the transport degrades down "
                         "the fp32>fp16>int8>int4 codec ladder, then skips "
                         "hops and stops scheduling rounds (uses the "
                         "budgeted metered transport; incompatible with an "
                         "explicit --transport or --codec)")
    ap.add_argument("--dp-epsilon", type=float, default=0.0,
                    help="per-release DP epsilon: Gaussian-mechanism noise "
                         "on every outgoing ignorance vector, per-agent "
                         "epsilon accounting printed after the run")
    ap.add_argument("--controller", default="",
                    choices=[""] + list(CONTROLLER_STATS),
                    help="adaptive codec controller (repro.control): pick "
                         "the codec rung per hop from this statistic of "
                         "the outgoing ignorance vector (resid = hop "
                         "innovation, entropy/l2 = concentration), "
                         "front-loading precision while the signal is "
                         "high; replaces a fixed --codec, and floors the "
                         "--byte-budget ladder walk when both are set")
    ap.add_argument("--serve-controller", default="",
                    choices=[""] + list(SERVE_STATS),
                    help="serve-path adaptive policy (repro.control): pick "
                         "the ScoreBlockMsg codec rung per block from this "
                         "statistic of the outgoing [n, K] scores (margin = "
                         "mean top1-top2 gap, entropy = normalized row "
                         "entropy) — coarse rungs for confident blocks, "
                         "fine for uncertain ones; replaces a fixed "
                         "--serve-codec, and floors the --byte-budget serve "
                         "ladder walk when both are set")
    ap.add_argument("--accountant", default="basic",
                    choices=["basic", "rdp", "subsampled-rdp"],
                    help="privacy accountant for --dp-epsilon releases: "
                         "basic additive composition, Renyi-DP (moments) "
                         "composition converted to (eps, delta) on read — "
                         "tighter for long sessions, never looser — or "
                         "subsampled-rdp, RDP with privacy amplification "
                         "by the scenario's --subsample client-sampling "
                         "rate (capped at the full-batch bound)")
    ap.add_argument("--scheduler", default="",
                    choices=["", "budget-aware"],
                    help="round-order override (repro.control.scheduler): "
                         "budget-aware reorders agents each round by "
                         "remaining link budget so degradation rotates "
                         "instead of starving a fixed tail (sequential "
                         "variants; both backends — compiled lowers the "
                         "permutation into the scan)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint SessionState here after the run "
                         "(or after --stop-after rounds)")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="pause after this many rounds (with --ckpt-dir: "
                         "save a resumable checkpoint and exit)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --ckpt-dir instead of starting fresh")
    ap.add_argument("--trace", default="",
                    help="stream a JSONL telemetry trace (repro.telemetry "
                         "schema) here: spans append as they close, final "
                         "metric values seal the file after the run — a "
                         "killed session leaves a truncated prefix "
                         "`python -m repro.telemetry.check --allow-partial` "
                         "accepts")
    ap.add_argument("--metrics-out", default="",
                    help="write the final metrics registry here after the "
                         "run (.prom = Prometheus text exposition, "
                         "anything else = JSON snapshot)")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the run into "
                         "this directory (view in TensorBoard/Perfetto); "
                         "session/round/hop spans show up as trace "
                         "annotations on the profiler timeline")
    ap.add_argument("--watch", action="store_true",
                    help="render the live dashboard (stderr) while the "
                         "session runs: per-round wire bits, budget "
                         "skips, exhaustion — streamed from inside the "
                         "compiled program via in-flight taps (eager "
                         "rounds tap at round end); metered transports "
                         "only")
    args = ap.parse_args()

    key = jax.random.key(args.seed)
    ds = DATASETS[args.dataset](key, args.n)
    tr, te = train_test_split(args.seed, ds.X.shape[0])
    Xs = vertical_split(ds.X, ds.splits)
    Xtr, Xte = [x[tr] for x in Xs], [x[te] for x in Xs]
    ctr, cte = ds.classes[tr], ds.classes[te]

    if args.backend == "compiled":
        if args.resume or args.stop_after or args.ckpt_dir:
            ap.error("--backend compiled runs fit-to-completion with no "
                     "SessionState; checkpointing/pause/resume need the "
                     "eager backend")
        if args.learner == "tree":
            ap.error("--backend compiled needs a functional learner "
                     "(--learner logistic|mlp); tree is eager-only")
        if args.variant not in ("ascii", "simple", "async"):
            ap.error("--backend compiled supports sequential, budget-aware "
                     "and async-stale scheduling (--variant ascii|simple|"
                     "async)")
    if args.variant == "async" and args.controller:
        ap.error("adaptive controllers are per-hop rung policies with no "
                 "async analogue; --variant async releases its barrier "
                 "merge once per round (--codec/--byte-budget/--dp-epsilon "
                 "apply per barrier and are supported)")
    if args.byte_budget > 0:
        if args.codec:
            ap.error("--byte-budget drives codec choice through its "
                     "degradation ladder; drop --codec")
        if args.serve_codec:
            ap.error("--byte-budget drives the serve codec through the "
                     "same degradation ladder; drop --serve-codec")
        if args.transport != "metered":
            ap.error("--byte-budget needs the (budgeted) metered "
                     "transport; drop --transport")
    if args.controller and args.codec:
        ap.error("--controller drives codec choice through its ladder; "
                 "drop --codec")
    if args.serve_controller and args.serve_codec:
        ap.error("--serve-controller drives serve codec choice through "
                 "its ladder; drop --serve-codec")
    if args.accountant != "basic" and args.dp_epsilon <= 0:
        ap.error(f"--accountant {args.accountant} accounts --dp-epsilon "
                 f"releases; set --dp-epsilon too")
    if args.scheduler == "budget-aware" \
            and args.variant not in ("ascii", "simple"):
        ap.error("--scheduler budget-aware replaces the round order; "
                 "use a sequential variant (ascii|simple)")
    if args.protocol != "ascii":
        if args.variant in ("simple", "async"):
            ap.error(f"--variant {args.variant} is an ASCII scheduling "
                     f"mode; --protocol {args.protocol} runs its own round "
                     f"rule over an ordered roster (--variant ascii|random)")
        if args.controller or args.serve_controller:
            ap.error("adaptive controllers read ignorance-vector "
                     f"statistics; they do not apply to --protocol "
                     f"{args.protocol} traffic")
    if args.protocol == "fedavg" and args.learner == "tree":
        ap.error("--protocol fedavg averages flat parameter deltas from a "
                 "functional learner core; --learner tree has none "
                 "(use logistic|mlp)")
    if args.protocol == "al" and args.backend == "compiled":
        ap.error("--protocol al is eager-only: its ring of closed-form "
                 "ridge hops has no compiled lowering")
    if args.scenario and (args.subsample or args.dropout or args.straggle
                          or args.partition != "iid" or args.clock_skew):
        ap.error("--scenario presets fix the scenario knobs; drop the "
                 "individual --subsample/--dropout/--straggle/--partition/"
                 "--clock-skew flags (or drop --scenario)")
    if args.clock_skew and args.variant != "async":
        # hoisted from Scenario.validate so the explicit flag path errors
        # at argparse time with a message that names the flags
        ap.error("--clock-skew lags agents behind the stale-read barrier; "
                 "it needs --variant async")
    if args.scenario:
        scenario = PRESETS[args.scenario]
    else:
        try:
            clock = (tuple(int(s) for s in args.clock_skew.split(","))
                     if args.clock_skew else ())
        except ValueError:
            ap.error(f"--clock-skew wants comma-separated non-negative "
                     f"ints, got {args.clock_skew!r}")
        try:
            scenario = Scenario("cli", subsample=args.subsample or None,
                                dropout=args.dropout,
                                straggle=args.straggle,
                                partition=args.partition, skew=args.skew,
                                clock_skew=clock, seed=args.scenario_seed)
        except ValueError as e:
            ap.error(str(e))
    if args.accountant == "subsampled-rdp" and scenario.subsample is None:
        ap.error("--accountant subsampled-rdp amplifies privacy by the "
                 "client-sampling rate; set --subsample (or a subsampling "
                 "--scenario) so there is a rate to amplify by")
    if args.backend == "compiled" and args.protocol == "ascii" \
            and not scenario.trivial:
        ap.error("--backend compiled does not lower ASCII scenario knobs "
                 "(churn changes the chain's shape per round); use the "
                 "eager backend — fedavg scenarios do compile")
    variant_obj = make_variant(args.protocol)
    scheduler, upstream = variant_setup(args.variant, args.seed)
    if args.scheduler == "budget-aware":
        scheduler = BudgetAwareScheduler()
    try:
        scenario.validate(len(Xs), scheduler, variant_obj)
    except ValueError as e:
        ap.error(str(e))
    privacy = (GaussianMechanism(epsilon=args.dp_epsilon,
                                 nonneg=(args.protocol == "ascii"))
               if args.dp_epsilon > 0 else None)
    accountant = (make_accountant(args.accountant, q=scenario.subsample)
                  if privacy is not None else None)
    controller = (AdaptiveController(stat=args.controller)
                  if args.controller else None)
    serve_controller = (ServeController(stat=args.serve_controller)
                        if args.serve_controller else None)
    if args.byte_budget > 0:
        transport = BudgetedTransport(
            BudgetSpec(session_bits=args.byte_budget * 8), privacy=privacy,
            controller=controller, accountant=accountant,
            serve_controller=serve_controller)
    else:
        codec = make_codec(args.codec) if args.codec else None
        serve_codec = (make_codec(args.serve_codec) if args.serve_codec
                       else None)
        transport = TRANSPORTS[args.transport](codec=codec, privacy=privacy,
                                               serve_codec=serve_codec,
                                               controller=controller,
                                               accountant=accountant,
                                               serve_controller=serve_controller)
    telemetry = (Telemetry(profile=bool(args.profile_dir),
                           live=args.watch)
                 if (args.trace or args.metrics_out or args.profile_dir
                     or args.watch)
                 else None)
    if telemetry is not None and args.trace:
        # crash-durable: spans stream to the trace file as they close;
        # _finish_telemetry seals it with the final metric events (with
        # --watch, live round taps stream into it too, as they fire)
        telemetry.stream_trace(args.trace)
    dash = None
    if args.watch:
        from repro.telemetry.dash import Dashboard
        dash = Dashboard(telemetry.registry,
                         title=f"session:{args.dataset}"
                         ).attach(telemetry.live)
    engine = Protocol(SessionConfig(num_classes=ds.num_classes,
                                    max_rounds=args.rounds,
                                    upstream=upstream),
                      scheduler=scheduler, transport=transport,
                      backend=args.backend, variant=variant_obj,
                      scenario=None if scenario.trivial else scenario,
                      telemetry=telemetry)
    endpoints = endpoints_for(
        [LEARNERS[args.learner](args) for _ in Xs], Xtr)
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)

    # FedAvg's fitted object carries flat global params, not a component
    # ensemble; everything else (ascii, al) reports its ensemble size
    tag = "" if args.protocol == "ascii" else f"{args.protocol},"

    def _size(fitted):
        if args.protocol == "fedavg":
            return f"params={fitted.g.size}"
        return f"components={len(fitted.components)}"

    if args.backend == "compiled":
        fitted = engine.fit(jax.random.fold_in(key, 1), endpoints, ctr)
        acc = float(jnp.mean(fitted.predict(Xte) == cte))
        line = (f"{args.dataset},{tag}{args.variant},{args.transport},"
                f"compiled,rounds={fitted.num_rounds},"
                f"{_size(fitted)},acc={acc:.3f}")
        if isinstance(transport, MeteredTransport):
            line += f",bits={transport.total_bits}"
        print(line)
        if args.protocol == "ascii":
            # only ASCII has a serve path (chained ScoreBlockMsg traffic)
            before = (transport.bits_by_kind().get("score_block", 0)
                      if isinstance(transport, MeteredTransport) else 0)
            preds = engine.predict_distributed(Xte)
            _print_serve(transport, preds, cte, before)
        _print_comm(transport, show_ema=False)
        _finish_telemetry(args, telemetry, transport, dash)
        return

    # the run config that must match across pause/resume: a different
    # variant/seed/dataset would silently corrupt the resumed trajectory
    run_cfg = {k: getattr(args, k)
               for k in ("dataset", "n", "variant", "learner", "depth",
                         "steps", "seed", "codec", "serve_codec",
                         "byte_budget", "dp_epsilon", "controller",
                         "accountant", "scheduler", "serve_controller",
                         "protocol", "scenario", "subsample", "dropout",
                         "straggle", "partition", "skew", "clock_skew",
                         "scenario_seed")}
    cfg_path = os.path.join(args.ckpt_dir or ".", "cli_config.json")
    if args.resume:
        if not args.ckpt_dir:
            ap.error("--resume needs --ckpt-dir")
        if os.path.exists(cfg_path):
            with open(cfg_path) as f:
                saved = json.load(f)
            # manifests written before the learner/steps (PR 2), comm
            # (PR 3), or control-plane (PR 5) flags existed imply the old
            # defaults — fill, don't reject
            saved = {"learner": "tree", "steps": 150, "codec": "",
                     "serve_codec": "", "byte_budget": 0, "dp_epsilon": 0.0,
                     "controller": "", "accountant": "basic",
                     "scheduler": "", "serve_controller": "",
                     "protocol": "ascii", "scenario": "", "subsample": 0.0,
                     "dropout": 0.0, "straggle": 0.0, "partition": "iid",
                     "skew": 0.5, "clock_skew": "", "scenario_seed": 0,
                     **saved}
            if saved != run_cfg:
                ap.error(f"--resume config mismatch: checkpoint was written "
                         f"with {saved}, this run is {run_cfg}")
        else:
            print(f"warning: no {cfg_path} manifest (checkpoint written "
                  f"outside this CLI?) — cannot verify dataset/variant/seed "
                  f"match the saved session")
        session = engine.resume(args.ckpt_dir, endpoints, ctr)
        print(f"resumed {args.ckpt_dir} at round {session.state.round}")
    else:
        session = engine.start(jax.random.fold_in(key, 1), endpoints, ctr)

    session.run(max_rounds=args.stop_after or None)
    paused = (args.stop_after and not session.state.stopped
              and session.state.round < args.rounds)
    if args.ckpt_dir:
        path = session.checkpoint(args.ckpt_dir)
        with open(cfg_path, "w") as f:
            json.dump(run_cfg, f)
        print(f"checkpointed round {session.state.round} -> {path}")

    fitted = session.fitted()
    acc = float(jnp.mean(fitted.predict(Xte) == cte))
    line = (f"{args.dataset},{tag}{args.variant},{args.transport},"
            f"rounds={fitted.num_rounds},{_size(fitted)},"
            f"acc={acc:.3f}")
    if isinstance(transport, MeteredTransport):
        line += f",bits={transport.total_bits}"
    print(line)
    if not paused and args.protocol == "ascii":
        # serve only on the terminal run: the checkpoint above snapshots
        # comm state *before* this point, so a paused process serving here
        # would book budget spend and DP releases the snapshot misses —
        # free bits and an undercounted epsilon ledger after --resume
        before = (transport.bits_by_kind().get("score_block", 0)
                  if isinstance(transport, MeteredTransport) else 0)
        preds = session.predict_distributed(Xte)
        _print_serve(transport, preds, cte, before)
    _print_comm(transport)
    _finish_telemetry(args, telemetry, transport, dash)
    if paused:
        if args.ckpt_dir:
            print(f"paused after {session.state.round} rounds; rerun with "
                  f"--resume to continue")
        else:
            print(f"paused after {session.state.round} rounds; nothing was "
                  f"saved (pass --ckpt-dir to make the pause resumable)")


if __name__ == "__main__":
    main()
