"""Session driver: run an ASCII engine session from the command line.

Wires a dataset, a scheduler (via the variant name), and a transport into
``core.engine.Protocol``, with optional mid-run checkpointing and resume —
the launch-layer entry point for protocol runs, the way ``launch/train.py``
is for LM training.

  PYTHONPATH=src python -m repro.launch.session --dataset blob3 \
      --variant ascii --rounds 6 --transport metered
  PYTHONPATH=src python -m repro.launch.session --ckpt-dir /tmp/sess \
      --stop-after 2                       # save mid-run ...
  PYTHONPATH=src python -m repro.launch.session --ckpt-dir /tmp/sess \
      --resume                             # ... and pick the run back up
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core.engine import (InProcessTransport, MeshRingTransport,
                               MeteredTransport, Protocol, SessionConfig,
                               endpoints_for, variant_setup)
from repro.data.partition import train_test_split, vertical_split
from repro.data import synthetic
from repro.learners.logistic import LogisticRegression
from repro.learners.mlp import MLP
from repro.learners.tree import DecisionTree

DATASETS = {
    "blob3": lambda key, n: synthetic.blob_fig3(key, n=n),
    "blob4": lambda key, n: synthetic.blob_fig4(key, n=n),
    "blob6": lambda key, n: synthetic.blob_fig6(key, n=n),
    "wine": lambda key, n: synthetic.wine_surrogate(key),
}

TRANSPORTS = {
    "inprocess": InProcessTransport,
    "metered": MeteredTransport,
    "meshring": MeshRingTransport,
}

LEARNERS = {
    # tree is eager-only; logistic/mlp carry a LearnerCore and can ride
    # --backend compiled
    "tree": lambda args: DecisionTree(depth=args.depth, num_thresholds=8),
    "logistic": lambda args: LogisticRegression(steps=args.steps),
    "mlp": lambda args: MLP(hidden=(32, 16), steps=args.steps),
}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dataset", default="blob3", choices=sorted(DATASETS))
    ap.add_argument("--n", type=int, default=600)
    ap.add_argument("--variant", default="ascii",
                    choices=["ascii", "simple", "random", "async"])
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--transport", default="metered",
                    choices=sorted(TRANSPORTS))
    ap.add_argument("--learner", default="tree", choices=sorted(LEARNERS))
    ap.add_argument("--depth", type=int, default=3,
                    help="tree depth (tree learner only)")
    ap.add_argument("--steps", type=int, default=150,
                    help="optimizer steps (logistic/mlp learners)")
    ap.add_argument("--backend", default="eager",
                    choices=["eager", "compiled"],
                    help="compiled lowers the whole run into one lax.scan "
                         "program (sequential variants, functional learners)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="",
                    help="checkpoint SessionState here after the run "
                         "(or after --stop-after rounds)")
    ap.add_argument("--stop-after", type=int, default=0,
                    help="pause after this many rounds (with --ckpt-dir: "
                         "save a resumable checkpoint and exit)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --ckpt-dir instead of starting fresh")
    args = ap.parse_args()

    key = jax.random.key(args.seed)
    ds = DATASETS[args.dataset](key, args.n)
    tr, te = train_test_split(args.seed, ds.X.shape[0])
    Xs = vertical_split(ds.X, ds.splits)
    Xtr, Xte = [x[tr] for x in Xs], [x[te] for x in Xs]
    ctr, cte = ds.classes[tr], ds.classes[te]

    if args.backend == "compiled":
        if args.resume or args.stop_after or args.ckpt_dir:
            ap.error("--backend compiled runs fit-to-completion with no "
                     "SessionState; checkpointing/pause/resume need the "
                     "eager backend")
        if args.learner == "tree":
            ap.error("--backend compiled needs a functional learner "
                     "(--learner logistic|mlp); tree is eager-only")
        if args.variant not in ("ascii", "simple"):
            ap.error("--backend compiled supports sequential scheduling "
                     "only (--variant ascii|simple)")
    scheduler, upstream = variant_setup(args.variant, args.seed)
    transport = TRANSPORTS[args.transport]()
    engine = Protocol(SessionConfig(num_classes=ds.num_classes,
                                    max_rounds=args.rounds,
                                    upstream=upstream),
                      scheduler=scheduler, transport=transport,
                      backend=args.backend)
    endpoints = endpoints_for(
        [LEARNERS[args.learner](args) for _ in Xs], Xtr)

    if args.backend == "compiled":
        fitted = engine.fit(jax.random.fold_in(key, 1), endpoints, ctr)
        acc = float(jnp.mean(fitted.predict(Xte) == cte))
        line = (f"{args.dataset},{args.variant},{args.transport},compiled,"
                f"rounds={fitted.num_rounds},"
                f"components={len(fitted.components)},acc={acc:.3f}")
        if isinstance(transport, MeteredTransport):
            line += f",bits={transport.total_bits}"
        print(line)
        return

    # the run config that must match across pause/resume: a different
    # variant/seed/dataset would silently corrupt the resumed trajectory
    run_cfg = {k: getattr(args, k)
               for k in ("dataset", "n", "variant", "learner", "depth",
                         "steps", "seed")}
    cfg_path = os.path.join(args.ckpt_dir or ".", "cli_config.json")
    if args.resume:
        if not args.ckpt_dir:
            ap.error("--resume needs --ckpt-dir")
        if os.path.exists(cfg_path):
            with open(cfg_path) as f:
                saved = json.load(f)
            # manifests written before the learner/steps flags existed
            # imply the old fixed tree learner — default, don't reject
            saved = {"learner": "tree", "steps": 150, **saved}
            if saved != run_cfg:
                ap.error(f"--resume config mismatch: checkpoint was written "
                         f"with {saved}, this run is {run_cfg}")
        else:
            print(f"warning: no {cfg_path} manifest (checkpoint written "
                  f"outside this CLI?) — cannot verify dataset/variant/seed "
                  f"match the saved session")
        session = engine.resume(args.ckpt_dir, endpoints, ctr)
        print(f"resumed {args.ckpt_dir} at round {session.state.round}")
    else:
        session = engine.start(jax.random.fold_in(key, 1), endpoints, ctr)

    session.run(max_rounds=args.stop_after or None)
    paused = (args.stop_after and not session.state.stopped
              and session.state.round < args.rounds)
    if args.ckpt_dir:
        path = session.checkpoint(args.ckpt_dir)
        with open(cfg_path, "w") as f:
            json.dump(run_cfg, f)
        print(f"checkpointed round {session.state.round} -> {path}")

    fitted = session.fitted()
    acc = float(jnp.mean(fitted.predict(Xte) == cte))
    line = (f"{args.dataset},{args.variant},{args.transport},"
            f"rounds={fitted.num_rounds},components={len(fitted.components)},"
            f"acc={acc:.3f}")
    if isinstance(transport, MeteredTransport):
        line += f",bits={transport.total_bits}"
    print(line)
    if paused:
        if args.ckpt_dir:
            print(f"paused after {session.state.round} rounds; rerun with "
                  f"--resume to continue")
        else:
            print(f"paused after {session.state.round} rounds; nothing was "
                  f"saved (pass --ckpt-dir to make the pause resumable)")


if __name__ == "__main__":
    main()
