"""Production meshes.

Functions (not module constants) so importing never touches jax device
state.  Single pod: (data=16, model=16) = 256 chips of TPU v5e; multi-pod:
(pod=2, data=16, model=16) = 512 chips, the ``pod`` axis crossing DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over however many local devices exist (tests)."""
    n = n_devices or len(jax.devices())
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))
