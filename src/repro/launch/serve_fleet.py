"""Serve-fleet driver: a synthetic multi-tenant prediction workload against
the continuous-batching serve engine (:mod:`repro.serve`).

Fits a fleet of compiled protocol sessions, registers them as servable, and
replays a randomized request stream — tenants drawn round-robin, sessions
and serve-time rows drawn at random — through
``ServeEngine.submit``/``flush``.  Prints the per-tenant
denied/degraded/served counters, the cache and batcher stats, and the
sustained request throughput.

  PYTHONPATH=src python -m repro.launch.serve_fleet --sessions 6 \
      --tenants 3 --requests 40 --serve-codec int8 --cache-capacity 4
  PYTHONPATH=src python -m repro.launch.serve_fleet --serve-controller \
      margin --dp-epsilon 1.0 --epsilon-cap 8 --tenant-kb 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.control import ServeController
from repro.control.adaptive import SERVE_STATS
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.data import synthetic
from repro.data.partition import train_test_split, vertical_split
from repro.learners.logistic import LogisticRegression
from repro.serve import AdmissionController, AdmissionPolicy, ServeEngine
from repro.telemetry import Telemetry


def fit_fleet(args, key, Xtr, ctr, num_classes, telemetry=None):
    """Fit ``--sessions`` compiled protocols (distinct fold keys, one shared
    plan, so the session program compiles once)."""
    protos = {}
    for s in range(args.sessions):
        privacy = (GaussianMechanism(epsilon=args.dp_epsilon)
                   if args.dp_epsilon > 0 else None)
        serve_controller = (ServeController(stat=args.serve_controller)
                            if args.serve_controller else None)
        if args.byte_budget > 0:
            transport = BudgetedTransport(
                BudgetSpec(session_bits=args.byte_budget * 8),
                privacy=privacy, serve_controller=serve_controller)
        else:
            transport = MeteredTransport(
                privacy=privacy, serve_controller=serve_controller,
                serve_codec=(make_codec(args.serve_codec)
                             if args.serve_codec else None))
        proto = Protocol(SessionConfig(num_classes=num_classes,
                                       max_rounds=args.rounds),
                         transport=transport, backend="compiled",
                         telemetry=telemetry)
        endpoints = endpoints_for(
            [LogisticRegression(steps=args.steps) for _ in Xtr], Xtr)
        proto.fit(jax.random.fold_in(key, s), endpoints, ctr)
        protos[f"s{s}"] = proto
    return protos


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dataset", default="blob3",
                    choices=["blob3", "blob4", "blob6"])
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--tenants", type=int, default=2)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--block-n", type=int, default=32,
                    help="serve-time rows per request (one bucket shape)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-capacity", type=int, default=4,
                    help="resident sessions; the rest spill to checkpoint "
                         "and restore bit-exact on next touch")
    ap.add_argument("--flush-every", type=int, default=8,
                    help="drain the batch queue after this many submits")
    ap.add_argument("--serve-codec", default="",
                    choices=["", "fp32", "fp16", "int8", "int4"])
    ap.add_argument("--serve-controller", default="",
                    choices=[""] + list(SERVE_STATS))
    ap.add_argument("--byte-budget", type=int, default=0,
                    help="per-session byte budget (serve blocks walk the "
                         "degradation ladder against it)")
    ap.add_argument("--dp-epsilon", type=float, default=0.0)
    ap.add_argument("--tenant-kb", type=int, default=0,
                    help="per-tenant serve byte cap in KB (0 = uncapped); "
                         "requests a tenant cannot afford degrade to "
                         "head-only (or are denied with --no-degrade)")
    ap.add_argument("--epsilon-cap", type=float, default=0.0,
                    help="per-tenant total DP epsilon cap (0 = no gate)")
    ap.add_argument("--no-degrade", action="store_true",
                    help="deny over-budget requests instead of degrading "
                         "them to head-only")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-tenant latency SLO threshold in ms (0 = no "
                         "SLO tracking); admission denials count as "
                         "violations")
    ap.add_argument("--slo-objective", type=float, default=0.99,
                    help="fraction of a tenant's requests that must land "
                         "under --slo-ms")
    ap.add_argument("--watch", action="store_true",
                    help="render the live fleet dashboard (stderr) while "
                         "the workload runs: per-round wire taps, tenant "
                         "p50/p99, SLO burn, admission/cache counters")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="write a JSONL telemetry trace (flush/flush_wave/"
                         "bucket_dispatch spans + final metric values) "
                         "here after the workload; with --watch the live "
                         "events stream into it as they happen")
    ap.add_argument("--metrics-out", default="",
                    help="write the fleet metrics registry here (.prom = "
                         "Prometheus text exposition, else JSON snapshot)")
    args = ap.parse_args()
    if args.serve_controller and args.serve_codec:
        ap.error("--serve-controller drives serve codec choice through "
                 "its ladder; drop --serve-codec")

    key = jax.random.key(args.seed)
    ds = {"blob3": synthetic.blob_fig3, "blob4": synthetic.blob_fig4,
          "blob6": synthetic.blob_fig6}[args.dataset](key, n=args.n)
    tr, te = train_test_split(args.seed, ds.X.shape[0])
    Xs = vertical_split(ds.X, ds.splits)
    Xtr, Xte = [x[tr] for x in Xs], [x[te] for x in Xs]
    ctr = ds.classes[tr]

    telemetry = (Telemetry(live=args.watch)
                 if (args.trace or args.metrics_out or args.watch)
                 else None)
    if args.trace and telemetry is not None:
        telemetry.stream_trace(args.trace)
    dash = None
    if args.watch:
        from repro.telemetry.dash import Dashboard
        dash = Dashboard(telemetry.registry,
                         title="serve fleet").attach(telemetry.live)
    t0 = time.time()
    protos = fit_fleet(args, jax.random.fold_in(key, 1), Xtr, ctr,
                       ds.num_classes, telemetry=telemetry)
    print(f"fitted {args.sessions} sessions in {time.time() - t0:.2f}s")

    mechanism = (GaussianMechanism(epsilon=args.dp_epsilon)
                 if args.dp_epsilon > 0 else None)
    slo = None
    if args.slo_ms > 0:
        from repro.telemetry.slo import SLOConfig
        slo = SLOConfig(threshold_s=args.slo_ms / 1e3,
                        objective=args.slo_objective)
    engine = ServeEngine(
        cache_capacity=args.cache_capacity, max_batch=args.max_batch,
        admission=AdmissionController(
            AdmissionPolicy(allow_degrade=not args.no_degrade,
                            epsilon_cap=args.epsilon_cap or None),
            tenant_bits=args.tenant_kb * 8 * 1024 or None,
            mechanism=mechanism),
        telemetry=telemetry, slo=slo)
    for sid, proto in protos.items():
        engine.add_session(sid, proto)

    rng = np.random.default_rng(args.seed)
    n_te = int(Xte[0].shape[0])
    t0 = time.time()
    for i in range(args.requests):
        tenant = f"t{i % args.tenants}"
        sid = f"s{rng.integers(args.sessions)}"
        rows = rng.choice(n_te, size=min(args.block_n, n_te), replace=False)
        engine.submit(tenant, sid, [jnp.asarray(np.asarray(x)[rows])
                                    for x in Xte])
        if (i + 1) % args.flush_every == 0:
            engine.flush()
    engine.flush()
    dt = time.time() - t0

    summary = engine.summary()
    summary["elapsed_s"] = round(dt, 4)
    summary["qps"] = round(args.requests / max(dt, 1e-9), 2)
    if dash is not None:
        dash.final()
    print(json.dumps(summary, indent=2))
    if telemetry is not None:
        # fleet-wide: link gauges are per-transport, so skip the gauge
        # sync and export the shared counter registry + serve spans
        telemetry.write_artifacts(trace=args.trace or None,
                                  metrics_out=args.metrics_out or None)
        for path in (args.trace, args.metrics_out):
            if path:
                print(f"telemetry: wrote {path}")
    engine.close()


if __name__ == "__main__":
    main()
