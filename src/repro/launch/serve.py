"""Serving driver: prefill a prompt, then batched greedy decode with the
KV/SSM cache (the serve_step the decode dry-run shapes lower).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \
      --batch 4 --prompt_len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    # BooleanOptionalAction so --no-reduced can actually select the full
    # config (store_true with default=True could never be switched off)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt_len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--cache_mode", default="full", choices=["full", "ring"])
    ap.add_argument("--kv_quant", action="store_true",
                    help="int8 KV cache (GQA archs)")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = cfg.reduced()
    # independent streams for params / prompt tokens / frontend inputs —
    # reusing one key correlates the weights with the test inputs
    param_key, token_key, frontend_key = jax.random.split(jax.random.key(0), 3)
    params = api.init_params(param_key, cfg)

    b, s = args.batch, args.prompt_len
    total = s + args.gen
    batch = {"tokens": jax.random.randint(token_key, (b, s), 0,
                                          cfg.vocab_size)}
    off = 0
    if cfg.frontend == "vision":
        batch["patch_emb"] = jax.random.normal(
            frontend_key, (b, cfg.num_frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
        off = cfg.num_frontend_tokens
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            frontend_key, (b, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.dtype))

    s_cache = (api.cache_length(cfg, off + total)
               if args.cache_mode == "ring" else off + total)
    prefill = jax.jit(api.make_prefill_step(cfg))
    serve_step = jax.jit(api.make_serve_step(cfg, args.cache_mode))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    caches = api.pad_prefill_cache(caches, cfg, s_cache)
    if args.kv_quant:
        caches = api.quantize_cache(caches, cfg)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    print(f"prefill {s} tokens in {time.time() - t0:.2f}s "
          f"(cache len {s_cache}, mode {args.cache_mode})")

    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(off + s + i, jnp.int32)
        tok, logits, caches = serve_step(params, caches, tok, pos)
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    print(f"decoded {args.gen - 1} steps x batch {b} in {dt:.2f}s "
          f"({(args.gen - 1) * b / max(dt, 1e-9):.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
