"""Signal-adaptive codec controller: pick the rung per hop from the signal.

The ASCII interchange is bit-hungry exactly where it is most informative:
early rounds ship the hops that move the ignorance vector the most (and,
on concentrating cohorts, the highest-entropy vectors), while late rounds
ship a signal the receiver mostly already has — cheap to quantize
coarsely.  The fixed codec rung the comm subsystem spends per hop (PR 3/4)
is therefore wrong at both ends; :class:`AdaptiveController` replaces it
with a *policy*: observe a scalar statistic of the hop, smooth it with an
EMA, and map it through a descending threshold ladder to a codec rung —
high statistic buys fp32/fp16, a quiet signal degrades to int8/int4,
front-loading precision in the early rounds where the statistic is high.

Three statistics, all in [0, 1], higher = more precision:

  * ``"resid"`` (default) — the hop's *innovation*: the total-variation
    distance between the outgoing vector and the state the receiver
    already holds.  This is the quantization-relevant signal: a hop that
    barely moves the ignorance distribution (in the limit, a re-shipped
    uniform vector, which every integer codec reproduces exactly) needs no
    precision at all, while the large early-round updates are exactly
    where coarse rounding feeds visible error back into the next fit.
  * ``"entropy"`` — H(w)/log n of the outgoing vector: front-load
    precision while the ignorance mass is still spread wide, degrade as it
    collapses onto the few still-hard samples.
  * ``"l2"`` — the participation ratio 1/(n·Σw²), an L2 concentration
    measure (the cheap entropy surrogate).

Everything is a pure fixed-shape function of (w, ema), so the policy runs
identically on both engine backends:

  * eager — every transport routes rung choice through
    :func:`jitted_controller` (the cached-jit trick of
    ``comm.codecs.jitted_channel``, for the same last-ulp reason);
  * compiled — ``core.compiled.make_session_fn`` carries the EMA scalar in
    the ``lax.scan`` carry and computes the rung *branchlessly*
    (``sum(ema < thresholds)``) next to the budget ladder rule, so the
    whole adaptive session still lowers to one XLA program and
    ``quant_sweep_run``-style fleets still vmap.

Composition with a bit budget: the controller's rung is a *floor* on the
ladder index — the budget walk may degrade further (coarser) when bits run
low, never finer (``BudgetSpec.choose(..., floor=rung)``).

The EMA is protocol state: it rides the scan carry (compiled), lives on the
transport between hops (eager), is snapshotted into ``SessionState.comm``
at checkpoint time, and is restored on resume — a resumed adaptive session
picks the exact rungs the uninterrupted one would have.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.comm.codecs import Codec, Fp16Codec, Fp32Codec, QuantCodec

#: Same rungs as ``comm.budget.DEFAULT_LADDER`` (best codec first), declared
#: from the codec classes directly so this module never imports the engine.
DEFAULT_LADDER = (Fp32Codec(), Fp16Codec(), QuantCodec(bits=8),
                  QuantCodec(bits=4))

STATS = ("resid", "entropy", "l2")

#: Per-statistic default threshold ladders for the 4-rung DEFAULT_LADDER
#: (descending; one cut per rung boundary).  The resid cuts are calibrated
#: so a quiet channel decays fp16 -> int8 -> int4 within a few hops while
#: any sustained innovation holds the fine rungs.
DEFAULT_THRESHOLDS = {
    "resid": (0.75, 0.3, 0.03),
    "entropy": (0.99, 0.85, 0.7),
    "l2": (0.99, 0.85, 0.7),
}


@dataclass(frozen=True)
class AdaptiveController:
    """Per-hop codec-rung policy over a degradation ladder.

    ``ladder`` is the codec rungs, best first (stateless codecs only — the
    same constraint as :class:`~repro.comm.budget.BudgetSpec`, and for the
    same reason: error-feedback residuals cannot migrate between rungs).
    ``thresholds`` is one descending cut per rung boundary
    (``len(ladder) - 1`` entries): the smoothed statistic at or above
    ``thresholds[0]`` ships rung 0, below ``thresholds[-1]`` ships the last
    rung; ``None`` picks the per-``stat`` default
    (:data:`DEFAULT_THRESHOLDS`, defined for the default 4-rung ladder).
    ``beta`` is the EMA smoothing (0 = react to the raw per-hop statistic;
    the EMA starts at 1.0 — assume maximal signal until observed otherwise,
    which is what front-loads precision).  ``stat`` picks the observed
    signal statistic (module docstring).

    Hashable frozen dataclass of pure functions: a valid jit static
    argument, rides ``SessionPlan`` and the session scan like a codec.
    """
    ladder: tuple = DEFAULT_LADDER
    thresholds: tuple | None = None
    beta: float = 0.5
    stat: str = "resid"

    def __post_init__(self):
        if not self.ladder:
            raise ValueError("controller ladder must hold at least one codec")
        for c in self.ladder:
            if not isinstance(c, Codec) or c.stateful:
                raise ValueError(
                    f"controller ladder entries must be stateless Codecs, "
                    f"got {c!r}")
        if self.stat not in STATS:
            raise ValueError(f"unknown stat {self.stat!r}; expected {STATS}")
        if self.thresholds is None:
            cuts = DEFAULT_THRESHOLDS[self.stat][:len(self.ladder) - 1]
            object.__setattr__(self, "thresholds", tuple(cuts))
        if len(self.thresholds) != len(self.ladder) - 1:
            raise ValueError(
                f"need len(ladder) - 1 = {len(self.ladder) - 1} thresholds "
                f"(one per rung boundary), got {len(self.thresholds)}")
        if list(self.thresholds) != sorted(self.thresholds, reverse=True):
            raise ValueError(
                f"thresholds must descend (rung 0 is the best codec), got "
                f"{self.thresholds}")
        if not 0.0 <= self.beta < 1.0:
            raise ValueError(f"need 0 <= beta < 1, got {self.beta}")

    def init_state(self) -> jnp.ndarray:
        """Fresh EMA state: 1.0 — assume a maximal signal until the channel
        shows otherwise (this is what front-loads precision in round 1)."""
        return jnp.ones((), jnp.float32)

    def observe(self, w_prev: jnp.ndarray,
                w_out: jnp.ndarray) -> jnp.ndarray:
        """The raw per-hop statistic, in [0, 1] (higher = finer rung).

        ``w_out`` is the outgoing (post-reweight) ignorance vector the hop
        encodes; ``w_prev`` the vector the receiver already holds (its
        stale state) — only ``"resid"`` reads it.
        """
        n = int(w_out.shape[0])
        p = w_out.astype(jnp.float32)
        p = p / jnp.maximum(jnp.sum(p), 1e-12)
        if self.stat == "resid":
            q = w_prev.astype(jnp.float32)
            q = q / jnp.maximum(jnp.sum(q), 1e-12)
            return 0.5 * jnp.sum(jnp.abs(p - q))     # total variation
        if self.stat == "entropy":
            h = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)),
                                   0.0))
            return h / math.log(max(n, 2))
        return 1.0 / (n * jnp.maximum(jnp.sum(p * p), 1e-12))

    def step(self, w_prev: jnp.ndarray, w_out: jnp.ndarray,
             ema: jnp.ndarray, cuts=None, beta=None):
        """One controller step: observe, smooth, pick the rung.

        Returns ``(rung int32, new_ema f32)``.  The rung computation is
        branchless — ``sum(ema < thresholds)`` counts how many boundaries
        the smoothed statistic has fallen below — so it traces into the
        compiled session scan with no control flow.

        ``cuts``/``beta`` optionally override the static ``thresholds`` /
        ``beta`` fields with *traced* operands (same shapes): the sweep
        program (``core.compiled.control_sweep_run``) vmaps one traced
        session over per-config threshold/beta arrays so N controller
        hyperparameters compile exactly once.  When traced and static
        values coincide the arithmetic is identical, so the override path
        stays bit-compatible with the static one.
        """
        s = self.observe(w_prev, w_out)
        b = self.beta if beta is None else beta
        ema = b * ema + (1.0 - b) * s
        c = jnp.asarray(self.thresholds if cuts is None else cuts,
                        jnp.float32)
        rung = jnp.sum((ema < c).astype(jnp.int32))
        return rung, ema


SERVE_STATS = ("margin", "entropy")

#: Per-statistic default threshold ladders for the 4-rung DEFAULT_LADDER on
#: the serve path (descending).  Margin-derived uncertainty on a trained
#: ensemble's score blocks concentrates low, so the cuts sit well below the
#: training-path resid cuts; an unsure block (many near-tied rows) buys
#: fp32/fp16, a confident one degrades to int8/int4 — coarse rounding
#: cannot flip an argmax that top-2 margins already separate.
DEFAULT_SERVE_THRESHOLDS = {
    "margin": (0.8, 0.5, 0.2),
    "entropy": (0.9, 0.6, 0.3),
}


@dataclass(frozen=True)
class ServeController:
    """Per-block codec-rung policy for prediction-time ScoreBlockMsg traffic.

    The training controller (:class:`AdaptiveController`) reads the hop
    innovation of the ignorance vector; serve traffic has no analogous
    recurrence — each [n, K] score block is an independent release — so the
    serve policy is *stateless*: observe one scalar uncertainty statistic of
    the outgoing block, map it through descending thresholds to a ladder
    rung.  Two statistics, both in [0, 1], higher = more precision:

      * ``"margin"`` (default) — 1 minus the mean per-row top-2 margin of
        the row-normalized block: near-tied votes (the rows where coarse
        quantization could flip the argmax) read as high uncertainty.
      * ``"entropy"`` — mean per-row entropy H(p)/log K of the normalized
        block: spread vote mass buys precision, collapsed mass degrades.

    Pure fixed-shape functions of the raw (pre-noise) block: the eager
    transports route through :func:`jitted_serve_controller`, the compiled
    serve step (``core.compiled.make_serve_fn``) embeds :meth:`rung_for`
    branchlessly — both backends pick identical rungs per block.  Under a
    bit budget the rung floors the degrade-then-skip ladder walk, exactly
    like the training controller (``BudgetSpec.choose_costs(floor=)``).
    """
    ladder: tuple = DEFAULT_LADDER
    thresholds: tuple | None = None
    stat: str = "margin"

    def __post_init__(self):
        if not self.ladder:
            raise ValueError("serve-controller ladder must hold at least "
                             "one codec")
        for c in self.ladder:
            if not isinstance(c, Codec) or c.stateful:
                raise ValueError(
                    f"serve-controller ladder entries must be stateless "
                    f"Codecs, got {c!r} (serve hops have no next call to "
                    f"defer error-feedback state to)")
        if self.stat not in SERVE_STATS:
            raise ValueError(f"unknown serve stat {self.stat!r}; expected "
                             f"{SERVE_STATS}")
        if self.thresholds is None:
            cuts = DEFAULT_SERVE_THRESHOLDS[self.stat][:len(self.ladder) - 1]
            object.__setattr__(self, "thresholds", tuple(cuts))
        if len(self.thresholds) != len(self.ladder) - 1:
            raise ValueError(
                f"need len(ladder) - 1 = {len(self.ladder) - 1} thresholds "
                f"(one per rung boundary), got {len(self.thresholds)}")
        if list(self.thresholds) != sorted(self.thresholds, reverse=True):
            raise ValueError(
                f"thresholds must descend (rung 0 is the best codec), got "
                f"{self.thresholds}")

    def observe(self, block: jnp.ndarray) -> jnp.ndarray:
        """The block's uncertainty statistic, in [0, 1] (higher = finer
        rung).  ``block`` is the raw outgoing [n, K] score block — observed
        before DP noise, so the policy reads the sender's own signal."""
        k = int(block.shape[-1])
        # row-normalize the coded-vote mass into a distribution: shift each
        # row to nonnegative (coded votes carry -1/(K-1) off-class terms),
        # then divide by the row sum
        b = block.astype(jnp.float32)
        b = b - jnp.min(b, axis=-1, keepdims=True)
        p = b / jnp.maximum(jnp.sum(b, axis=-1, keepdims=True), 1e-12)
        if self.stat == "margin":
            top2 = jax.lax.top_k(p, min(2, k))[0]
            gap = (top2[..., 0] - top2[..., 1]) if k > 1 \
                else jnp.ones(p.shape[:-1], jnp.float32)
            return 1.0 - jnp.mean(gap)
        h = -jnp.sum(jnp.where(p > 0, p * jnp.log(jnp.maximum(p, 1e-30)),
                               0.0), axis=-1)
        return jnp.mean(h) / math.log(max(k, 2))

    def rung_for(self, block: jnp.ndarray) -> jnp.ndarray:
        """The chosen ladder rung (int32) for one outgoing block —
        branchless (``sum(stat < thresholds)``), so it traces into the
        compiled serve program with no control flow."""
        s = self.observe(block)
        cuts = jnp.asarray(self.thresholds, jnp.float32)
        return jnp.sum((s < cuts).astype(jnp.int32))


@functools.lru_cache(maxsize=64)
def jitted_serve_controller(controller: ServeController):
    """Cached jit of :meth:`ServeController.rung_for` — the eager
    ``Transport.serve_block`` routes rung choice through this so both
    backends run the exact same XLA computation (a last-ulp statistic
    difference at a threshold boundary would flip a rung)."""
    return jax.jit(controller.rung_for)


def controller_rung(controller: AdaptiveController, w_prev, w_out, ema):
    """Functional alias of :meth:`AdaptiveController.step` (sweep-friendly
    entry point for tests and benchmarks)."""
    return controller.step(w_prev, w_out, ema)


@functools.lru_cache(maxsize=64)
def jitted_controller(controller: AdaptiveController):
    """Cached jit of one controller step — the eager transports route rung
    choice through this so the eager engine runs the exact XLA computation
    the compiled session scan embeds (the ``jitted_channel`` discipline:
    op-by-op dispatch may fuse differently at the last ulp, and a last-ulp
    EMA difference at a threshold boundary would flip a rung)."""
    return jax.jit(functools.partial(controller_rung, controller))
