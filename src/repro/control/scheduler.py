"""Budget-aware round scheduling: spend the same bits in a better order.

The budget subsystem (`repro.comm.budget`) responds to scarcity per hop —
degrade down the codec ladder, then skip — but the *order* agents act in
each round is budget-blind: under a sequential chain the same agents always
hit the degraded/starved tail of the round.  :class:`BudgetAwareScheduler`
closes that gap: each round it orders the active agents by how much wire
budget their outgoing link has left (least-spent first), so degradation and
skips rotate across the cohort instead of starving a fixed suffix, and the
same :class:`~repro.comm.budget.BudgetSpec` caps buy more interchange.

Ordering key, ascending (all components deterministic):

  1. bits already spent by the agent as a sender — per-link spend on a
     :class:`~repro.comm.budget.BudgetedTransport` (including restored
     carryover), else the metered ledger's per-source tally
     (``TransportLog.bits_by_src``), else 0;
  2. ``-reward_ema`` — an optional EMA of the agent's observed weighted
     accuracy (``Scheduler.observe`` hook, fed by ``Session.step``), so
     ties break toward agents whose recent components earned more;
  3. the agent id (stability).

Both engine backends run it.  Eager, ``Session.step`` asks
:meth:`BudgetAwareScheduler.round_order` each round; compiled, the same
rule lowers into the session scan for *homogeneous* fleets (equal cores
and feature shapes): ``core.compiled.make_session_fn`` carries per-agent
spent-bit counters and the reward EMAs through the ``lax.scan`` and
re-permutes each round in-program via :func:`traced_round_order` (a
``lexsort`` over the identical ``(spent, -ema, id)`` key) plus gathers
over the stacked agent data — bit-for-bit the order the eager sort picks,
which the parity tests pin.  The EMA update itself is shared f32
arithmetic (:func:`reward_ema_update`): the eager path routes through its
cached jit so a last-ulp difference can never flip a tie-break.
Scheduler state (the reward EMAs) checkpoints through
``SessionState.comm`` (``state_dict``/``load_state_dict``), so a resumed
budget-aware session replays the exact order the uninterrupted one chose.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.engine import Scheduler


def reward_ema_update(beta, prev, acc, fresh):
    """The observed-reward EMA step, in f32 — the one formula both backends
    run.  ``fresh`` selects the first-observation branch (seed with the raw
    accuracy instead of smoothing from the 0 init), branchlessly so the
    compiled scan can apply it vectorized over the fleet."""
    b = jnp.asarray(beta, jnp.float32)
    prev = jnp.asarray(prev, jnp.float32)
    acc = jnp.asarray(acc, jnp.float32)
    upd = b * prev + (jnp.float32(1.0) - b) * acc
    return jnp.where(fresh, acc, upd)


@functools.lru_cache(maxsize=16)
def jitted_reward_ema(beta: float):
    """Cached jit of one EMA update — the eager scheduler routes through
    this (the ``jitted_controller`` discipline) so its stored EMAs are the
    exact f32 values the compiled scan carries; a host-float EMA could
    differ at the last ulp and flip the ``-ema`` tie-break."""
    return jax.jit(functools.partial(reward_ema_update, beta))


def traced_round_order(spent, ema):
    """In-scan twin of :meth:`BudgetAwareScheduler.round_order`: the round
    permutation as a traced ``lexsort`` over the same ascending key
    ``(spent bits, -reward EMA, agent id)``.  ``lexsort`` is stable and
    sorts by the *last* key first, so the key order reverses here; pass a
    zero ``ema`` to disable the tie-break (``use_reward=False``)."""
    ids = jnp.arange(spent.shape[0], dtype=jnp.int32)
    return jnp.lexsort((ids, -ema.astype(jnp.float32),
                        spent)).astype(jnp.int32)


@dataclass(frozen=True)
class BudgetAwarePlan:
    """Static (hashable) description of a :class:`BudgetAwareScheduler` for
    the compiled backend — rides ``SessionPlan.scheduler`` as a jit-static
    argument.  ``spend_signal`` names what the carried per-agent spent-bit
    counters track: ``"link"`` (budgeted transport: per-link ladder spend),
    ``"wire"`` (plain metered: interchange wire bits by sender), or
    ``"none"`` (unmetered transport: all zeros, pure EMA/id ordering)."""
    reward_smoothing: float = 0.5
    use_reward: bool = True
    spend_signal: str = "link"

    def __post_init__(self):
        if not 0.0 <= self.reward_smoothing < 1.0:
            raise ValueError(f"need 0 <= reward_smoothing < 1, got "
                             f"{self.reward_smoothing}")
        if self.spend_signal not in ("link", "wire", "none"):
            raise ValueError(f"unknown spend_signal "
                             f"{self.spend_signal!r}")


class BudgetAwareScheduler(Scheduler):
    """Order the active agents by remaining outgoing-link budget.

    ``reward_smoothing`` is the EMA coefficient for the observed-reward
    tie-break (0 = latest observation only); ``use_reward=False`` disables
    the tie-break entirely (pure budget ordering).
    """

    def __init__(self, reward_smoothing: float = 0.5,
                 use_reward: bool = True) -> None:
        if not 0.0 <= reward_smoothing < 1.0:
            raise ValueError(
                f"need 0 <= reward_smoothing < 1, got {reward_smoothing}")
        self.reward_smoothing = reward_smoothing
        self.use_reward = use_reward
        self._transport = None
        self._reward_ema: dict[int, float] = {}
        # per-sender spend a paused run had already booked into a plain
        # metered ledger: the ledger itself is process-local (a resumed
        # transport's log starts empty), so the ordering signal must cross
        # the checkpoint through scheduler state; budgeted transports
        # restore link_spent via the comm snapshot and need no baseline
        self._spent_baseline: dict[str, int] = {}

    # ---- engine hooks -------------------------------------------------------
    def bind_transport(self, transport) -> None:
        self._transport = transport

    def reset(self) -> None:
        self._reward_ema = {}
        self._spent_baseline = {}

    def observe(self, agent_id: int, acc: float) -> None:
        if not self.use_reward:
            return
        prev = self._reward_ema.get(agent_id)
        # shared f32 update (module docstring): the stored value is the
        # exact f32 the compiled scan would carry, so both backends break
        # EMA ties identically
        val = jitted_reward_ema(self.reward_smoothing)(
            0.0 if prev is None else prev, float(acc), prev is None)
        self._reward_ema[agent_id] = float(val)

    def plan(self) -> "BudgetAwarePlan":
        """The static twin the compiled backend lowers — spend signal from
        the transport this scheduler is bound to."""
        t = self._transport
        if hasattr(t, "link_spent"):
            signal = "link"
        elif hasattr(t, "log"):
            signal = "wire"
        else:
            signal = "none"
        return BudgetAwarePlan(reward_smoothing=self.reward_smoothing,
                               use_reward=self.use_reward,
                               spend_signal=signal)

    # ---- the ordering rule --------------------------------------------------
    def _spent_by_agent(self, active: list[int]) -> dict[int, int]:
        """Bits each active agent has spent as a sender, from live transport
        state: per-link budget spend when the transport enforces a budget,
        else the metered ledger's per-source interchange tally."""
        t = self._transport
        if t is None:
            return {m: 0 for m in active}
        names = {ep.agent_id: ep.name
                 for ep in getattr(t, "_endpoints", {}).values()}
        by_src = self._by_src()
        return {m: by_src.get(names.get(m, ""), 0) for m in active}

    def _by_src(self) -> dict[str, int]:
        t = self._transport
        by_src: dict[str, int] = {}
        if hasattr(t, "link_spent"):
            # restored with the transport on resume: no baseline on top
            for (src, _dst), bits in t.link_spent.items():
                by_src[src] = by_src.get(src, 0) + int(bits)
        elif hasattr(t, "log"):
            by_src = dict(t.log.bits_by_src(("ignorance", "model_weight")))
            for src, bits in self._spent_baseline.items():
                by_src[src] = by_src.get(src, 0) + bits
        return by_src

    def round_order(self, round_idx: int, active: list[int]) -> list[int]:
        spent = self._spent_by_agent(active)
        order = sorted(active,
                       key=lambda m: (spent.get(m, 0),
                                      -self._reward_ema.get(m, 0.0), m))
        # telemetry (when the transport's ledger carries a registry): did
        # budget pressure actually reorder this round?  Observation only —
        # the order is already decided
        registry = getattr(getattr(self._transport, "log", None),
                           "registry", None)
        if registry is not None:
            registry.inc("scheduler_rounds_total", 1,
                         changed=order != sorted(active))
        return order

    # ---- checkpointing ------------------------------------------------------
    def state_dict(self) -> dict:
        """JSON-able scheduler state for the SessionState comm snapshot.

        Budgeted link spend is transport state and rides the same snapshot;
        the plain-metered fallback's per-sender tally is process-local, so
        it is folded into scheduler state here (live ledger + any earlier
        baseline) — a resumed session orders rounds exactly like the
        uninterrupted one on every transport."""
        state: dict = {"reward_ema": {str(m): v for m, v
                                      in sorted(self._reward_ema.items())}}
        t = self._transport
        if t is not None and not hasattr(t, "link_spent") \
                and hasattr(t, "log"):
            state["spent_by_src"] = dict(sorted(self._by_src().items()))
        return state

    def load_state_dict(self, state: dict) -> None:
        self._reward_ema = {int(m): float(v)
                            for m, v in state.get("reward_ema", {}).items()}
        self._spent_baseline = {s: int(b) for s, b
                                in state.get("spent_by_src", {}).items()}
