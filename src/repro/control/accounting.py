"""Rényi-DP (moments) accounting for the interchange privacy mechanism.

The comm subsystem's :class:`~repro.comm.privacy.PrivacyAccountant` tallies
releases under *basic* additive composition: k releases of an (ε, δ)
Gaussian mechanism report (kε, kδ).  That is honest but loose — over a long
session (or serve traffic, where every predict call releases per agent) the
reported budget grows linearly while the true privacy loss grows like √k.
:class:`RDPAccountant` is the tight replacement, a drop-in behind the same
interface (``record`` / ``spent`` / ``report`` / a ``releases`` dict that
rides the ``SessionState.comm`` snapshot unchanged):

  * each release of the Gaussian mechanism with noise multiplier
    ν = σ/clip has Rényi divergence ε_RDP(α) = α / (2ν²) at every order
    α > 1 (Mironov 2017, Prop. 7);
  * k releases compose *additively in RDP*: k·α / (2ν²) — the accountant
    state is still just the per-agent release count, which is why the
    compiled backend's post-run replay (`Protocol._replay_traffic`) and the
    checkpoint snapshot need no changes;
  * conversion to (ε, δ) happens **on read**:
    ε(δ) = min_α [ k·α/(2ν²) + log(1/δ)/(α − 1) ] over a fixed order grid,
    reported at the mechanism's own δ.

The reported ε is additionally capped at the basic-composition value k·ε —
both are valid accountings of the same trace, so the tally may always
report the tighter pair.  When the cap binds, the report is the *proven*
additive pair (k·ε at δ = k·δ_mech), never k·ε at the smaller per-release
δ basic composition does not establish.  This keeps the invariant ("RDP
reports ε no larger than additive composition on the same trace") true by
construction at k = 1 — where the classical calibration's slack and the
RDP conversion overhead roughly cancel — while the RDP bound itself wins
whenever the per-release ε is moderate, with the gap widening like √k
vs k over a session.

Reads are *monotone-safe*: ``spent`` and ``report`` are pure functions of
the release counts (the conversion is cached per (k, ν, δ), never stored on
the accountant), so reading ε mid-session, checkpointing, and resuming can
neither double-count nor reset a release.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field

from repro.comm.privacy import GaussianMechanism, PrivacyAccountant

#: The order grid the (ε, δ) conversion minimizes over — the standard
#: moments-accountant spread: dense at low orders (small-k traces), doubling
#: into the tail (large-k traces push the optimum toward α → 1).
DEFAULT_ORDERS = (1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0,
                  12.0, 16.0, 24.0, 32.0, 48.0, 64.0, 128.0, 256.0, 512.0)


@functools.lru_cache(maxsize=4096)
def _rdp_to_eps(k: int, nu: float, delta: float,
                orders: tuple) -> tuple[float, float]:
    """min over orders of k·α/(2ν²) + log(1/δ)/(α−1) → (ε, argmin α).

    Pure and cached per (k, ν, δ, orders): accountant reads never mutate
    accountant state (the monotone-safety contract)."""
    if k <= 0:
        return 0.0, float(orders[0])
    best_eps, best_order = math.inf, float(orders[0])
    log_inv_delta = math.log(1.0 / delta)
    for a in orders:
        eps = k * a / (2.0 * nu * nu) + log_inv_delta / (a - 1.0)
        if eps < best_eps:
            best_eps, best_order = eps, float(a)
    return best_eps, best_order


def rdp_epsilon(k: int, mechanism: GaussianMechanism,
                orders: tuple = DEFAULT_ORDERS) -> tuple[float, float, float]:
    """(ε, δ, argmin order) for k releases of ``mechanism``: the RDP
    composition converted at the mechanism's δ, or — when that is looser —
    the proven additive pair (k·ε, k·δ).  Order 0.0 marks the additive
    bound.  Both accountings are valid for the trace; the tighter-ε pair
    is returned, with the δ that bound actually establishes."""
    nu = mechanism.sigma / mechanism.clip
    eps, order = _rdp_to_eps(int(k), float(nu), float(mechanism.delta),
                             tuple(orders))
    additive = k * mechanism.epsilon
    if additive < eps:
        return additive, min(1.0, k * mechanism.delta), 0.0
    return eps, mechanism.delta, order


@dataclass
class RDPAccountant(PrivacyAccountant):
    """Per-agent release tally reported under Rényi-DP composition.

    Subclasses :class:`~repro.comm.privacy.PrivacyAccountant`, so the
    state (``releases``) and the ``record`` path are identical — transports,
    the compiled replay, and the checkpoint snapshot treat both accountants
    interchangeably.  Only the *read* changes: ``spent`` returns the RDP ε
    at the mechanism's δ (never above k·ε), and ``report`` additionally
    carries the additive-composition ε for comparison.
    """
    orders: tuple = field(default=DEFAULT_ORDERS)

    def spent(self, agent: str, mechanism: GaussianMechanism
              ) -> tuple[float, float]:
        k = self.releases.get(agent, 0)
        if k == 0:
            return 0.0, 0.0
        eps, delta, _ = rdp_epsilon(k, mechanism, self.orders)
        return eps, delta

    def report(self, mechanism: GaussianMechanism) -> dict:
        out = {}
        for name in sorted(self.releases):
            k = self.releases[name]
            eps, delta, order = rdp_epsilon(k, mechanism, self.orders)
            out[name] = {"releases": k,
                         "epsilon": eps,
                         "delta": delta,
                         "epsilon_additive": k * mechanism.epsilon,
                         "rdp_order": order}
        return out


ACCOUNTANTS = {
    "basic": PrivacyAccountant,
    "rdp": RDPAccountant,
}


def make_accountant(name: str) -> PrivacyAccountant:
    """Accountant registry lookup for CLI / benchmark names."""
    if name not in ACCOUNTANTS:
        raise ValueError(
            f"unknown accountant {name!r}; expected {sorted(ACCOUNTANTS)}")
    return ACCOUNTANTS[name]()
