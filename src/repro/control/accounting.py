"""Rényi-DP (moments) accounting for the interchange privacy mechanism.

The comm subsystem's :class:`~repro.comm.privacy.PrivacyAccountant` tallies
releases under *basic* additive composition: k releases of an (ε, δ)
Gaussian mechanism report (kε, kδ).  That is honest but loose — over a long
session (or serve traffic, where every predict call releases per agent) the
reported budget grows linearly while the true privacy loss grows like √k.
:class:`RDPAccountant` is the tight replacement, a drop-in behind the same
interface (``record`` / ``spent`` / ``report`` / a ``releases`` dict that
rides the ``SessionState.comm`` snapshot unchanged):

  * each release of the Gaussian mechanism with noise multiplier
    ν = σ/clip has Rényi divergence ε_RDP(α) = α / (2ν²) at every order
    α > 1 (Mironov 2017, Prop. 7);
  * k releases compose *additively in RDP*: k·α / (2ν²) — the accountant
    state is still just the per-agent release count, which is why the
    compiled backend's post-run replay (`Protocol._replay_traffic`) and the
    checkpoint snapshot need no changes;
  * conversion to (ε, δ) happens **on read**:
    ε(δ) = min_α [ k·α/(2ν²) + log(1/δ)/(α − 1) ] over a fixed order grid,
    reported at the mechanism's own δ.

The reported ε is additionally capped at the basic-composition value k·ε —
both are valid accountings of the same trace, so the tally may always
report the tighter pair.  When the cap binds, the report is the *proven*
additive pair (k·ε at δ = k·δ_mech), never k·ε at the smaller per-release
δ basic composition does not establish.  This keeps the invariant ("RDP
reports ε no larger than additive composition on the same trace") true by
construction at k = 1 — where the classical calibration's slack and the
RDP conversion overhead roughly cancel — while the RDP bound itself wins
whenever the per-release ε is moderate, with the gap widening like √k
vs k over a session.

Reads are *monotone-safe*: ``spent`` and ``report`` are pure functions of
the release counts (the conversion is cached per (k, ν, δ), never stored on
the accountant), so reading ε mid-session, checkpointing, and resuming can
neither double-count nor reset a release.

Telemetry rides the inherited ``record``: the base accountant's optional
registry hook (``dp_releases_total{agent}``, a class attribute so these
dataclass subclasses keep their field order) fires for RDP flavors too —
one emission point for every accountant the repo ships.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field

from repro.comm.privacy import GaussianMechanism, PrivacyAccountant

#: The order grid the (ε, δ) conversion minimizes over — the standard
#: moments-accountant spread: dense at low orders (small-k traces), doubling
#: into the tail (large-k traces push the optimum toward α → 1).
DEFAULT_ORDERS = (1.25, 1.5, 1.75, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 8.0,
                  12.0, 16.0, 24.0, 32.0, 48.0, 64.0, 128.0, 256.0, 512.0)


@functools.lru_cache(maxsize=4096)
def _rdp_to_eps(k: int, nu: float, delta: float,
                orders: tuple) -> tuple[float, float]:
    """min over orders of k·α/(2ν²) + log(1/δ)/(α−1) → (ε, argmin α).

    Pure and cached per (k, ν, δ, orders): accountant reads never mutate
    accountant state (the monotone-safety contract)."""
    if k <= 0:
        return 0.0, float(orders[0])
    best_eps, best_order = math.inf, float(orders[0])
    log_inv_delta = math.log(1.0 / delta)
    for a in orders:
        eps = k * a / (2.0 * nu * nu) + log_inv_delta / (a - 1.0)
        if eps < best_eps:
            best_eps, best_order = eps, float(a)
    return best_eps, best_order


def rdp_epsilon(k: int, mechanism: GaussianMechanism,
                orders: tuple = DEFAULT_ORDERS) -> tuple[float, float, float]:
    """(ε, δ, argmin order) for k releases of ``mechanism``: the RDP
    composition converted at the mechanism's δ, or — when that is looser —
    the proven additive pair (k·ε, k·δ).  Order 0.0 marks the additive
    bound.  Both accountings are valid for the trace; the tighter-ε pair
    is returned, with the δ that bound actually establishes."""
    nu = mechanism.sigma / mechanism.clip
    eps, order = _rdp_to_eps(int(k), float(nu), float(mechanism.delta),
                             tuple(orders))
    additive = k * mechanism.epsilon
    if additive < eps:
        return additive, min(1.0, k * mechanism.delta), 0.0
    return eps, mechanism.delta, order


@dataclass
class RDPAccountant(PrivacyAccountant):
    """Per-agent release tally reported under Rényi-DP composition.

    Subclasses :class:`~repro.comm.privacy.PrivacyAccountant`, so the
    state (``releases``) and the ``record`` path are identical — transports,
    the compiled replay, and the checkpoint snapshot treat both accountants
    interchangeably.  Only the *read* changes: ``spent`` returns the RDP ε
    at the mechanism's δ (never above k·ε), and ``report`` additionally
    carries the additive-composition ε for comparison.
    """
    orders: tuple = field(default=DEFAULT_ORDERS)

    def spent(self, agent: str, mechanism: GaussianMechanism
              ) -> tuple[float, float]:
        k = self.releases.get(agent, 0)
        if k == 0:
            return 0.0, 0.0
        eps, delta, _ = rdp_epsilon(k, mechanism, self.orders)
        return eps, delta

    def report(self, mechanism: GaussianMechanism) -> dict:
        out = {}
        for name in sorted(self.releases):
            k = self.releases[name]
            eps, delta, order = rdp_epsilon(k, mechanism, self.orders)
            out[name] = {"releases": k,
                         "epsilon": eps,
                         "delta": delta,
                         "epsilon_additive": k * mechanism.epsilon,
                         "rdp_order": order}
        return out


#: Integer order grid for the sampled-Gaussian-mechanism bound (the
#: binomial expansion below is exact at integer α only) — the integer
#: subset of DEFAULT_ORDERS' spread.
SUBSAMPLED_ORDERS = (2, 3, 4, 5, 6, 8, 12, 16, 24, 32, 48, 64, 128, 256, 512)


@functools.lru_cache(maxsize=4096)
def sgm_rdp(alpha: int, q: float, nu: float) -> float:
    """One release of the sampled Gaussian mechanism at integer order α:
    each round every client is included independently-equivalently with
    probability q, so the released vector is the Gaussian mechanism applied
    to a q-subsample.  Mironov, Talwar & Zhang 2019 (Prop. 10 / eq. 3) give
    the exact integer-order bound

        ε(α) = log A(α) / (α − 1),
        A(α) = Σ_{k=0}^{α} C(α,k) q^k (1−q)^{α−k} exp((k² − k)/(2ν²)),

    evaluated in log space (lgamma binomials + logsumexp) so α = 512 does
    not overflow.  At q = 1 only the k = α term survives and the bound
    reduces exactly to the full-batch α/(2ν²)."""
    if not (0.0 < q <= 1.0):
        raise ValueError(f"subsampling rate must be in (0, 1], got {q}")
    if alpha < 2:
        raise ValueError(f"integer SGM orders start at 2, got {alpha}")
    if q == 1.0:
        return alpha / (2.0 * nu * nu)
    log_q, log_1q = math.log(q), math.log1p(-q)
    terms = []
    for k in range(alpha + 1):
        log_binom = (math.lgamma(alpha + 1) - math.lgamma(k + 1)
                     - math.lgamma(alpha - k + 1))
        terms.append(log_binom + k * log_q + (alpha - k) * log_1q
                     + (k * k - k) / (2.0 * nu * nu))
    hi = max(terms)
    log_a = hi + math.log(sum(math.exp(t - hi) for t in terms))
    return log_a / (alpha - 1)


def subsampled_rdp_epsilon(k: int, mechanism: GaussianMechanism, q: float,
                           orders: tuple = SUBSAMPLED_ORDERS
                           ) -> tuple[float, float, float]:
    """(ε, δ, argmin order) for k releases of ``mechanism`` under q-client
    subsampling: amplified SGM composition converted at the mechanism's δ,
    **capped at the full-batch RDP bound** (and, through it, the additive
    bound) so amplification is never looser than not claiming it.  Assumes
    secrecy of the sample — the adversary must not learn which clients a
    round actually included (the participation schedule is metadata here,
    so treat the amplified figure as the modeled best case).  Order 0.0
    marks a binding additive cap, matching :func:`rdp_epsilon`."""
    full = rdp_epsilon(k, mechanism)
    if k <= 0 or q >= 1.0:
        return full
    nu = mechanism.sigma / mechanism.clip
    log_inv_delta = math.log(1.0 / mechanism.delta)
    best_eps, best_order = math.inf, float(orders[0])
    for a in orders:
        eps = k * sgm_rdp(int(a), float(q), float(nu)) \
            + log_inv_delta / (a - 1.0)
        if eps < best_eps:
            best_eps, best_order = eps, float(a)
    if best_eps < full[0]:
        return best_eps, mechanism.delta, best_order
    return full


@dataclass
class SubsampledRDPAccountant(RDPAccountant):
    """RDP accountant with privacy amplification by client subsampling.

    ``q`` is the per-round client-inclusion rate (the Scenario's
    ``subsample`` knob); each recorded release is treated as one sampled-
    Gaussian release and composed in RDP.  The read-side contract matches
    :class:`RDPAccountant` exactly — same ``releases`` state, checkpoint
    snapshot, and replay path — and the reported ε is capped at the
    full-batch RDP (hence additive) bound, so switching accountants can
    only tighten the report."""
    q: float = 1.0

    def __post_init__(self):
        if not (0.0 < self.q <= 1.0):
            raise ValueError(
                f"subsampling rate q must be in (0, 1], got {self.q}")

    def spent(self, agent: str, mechanism: GaussianMechanism
              ) -> tuple[float, float]:
        k = self.releases.get(agent, 0)
        if k == 0:
            return 0.0, 0.0
        eps, delta, _ = subsampled_rdp_epsilon(k, mechanism, self.q)
        return eps, delta

    def report(self, mechanism: GaussianMechanism) -> dict:
        out = {}
        for name in sorted(self.releases):
            k = self.releases[name]
            eps, delta, order = subsampled_rdp_epsilon(k, mechanism, self.q)
            full_eps, _, _ = rdp_epsilon(k, mechanism, self.orders)
            out[name] = {"releases": k,
                         "epsilon": eps,
                         "delta": delta,
                         "epsilon_full_batch": full_eps,
                         "epsilon_additive": k * mechanism.epsilon,
                         "q": self.q,
                         "rdp_order": order}
        return out


ACCOUNTANTS = {
    "basic": PrivacyAccountant,
    "rdp": RDPAccountant,
    "subsampled-rdp": SubsampledRDPAccountant,
}


def make_accountant(name: str, q: float | None = None) -> PrivacyAccountant:
    """Accountant registry lookup for CLI / benchmark names.  ``q`` is the
    client-subsampling rate; passing it upgrades ``rdp`` to the amplified
    accountant (and parameterizes ``subsampled-rdp``)."""
    if name not in ACCOUNTANTS:
        raise ValueError(
            f"unknown accountant {name!r}; expected {sorted(ACCOUNTANTS)}")
    if name == "subsampled-rdp" or (name == "rdp" and q is not None
                                    and q < 1.0):
        return SubsampledRDPAccountant(q=1.0 if q is None else float(q))
    return ACCOUNTANTS[name]()
