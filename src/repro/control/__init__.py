"""Control plane: how the channel spends its resources, hop by hop.

The comm subsystem (`repro.comm`) gave the interchange a *wire* — codecs,
bit budgets, DP noise.  This package adds the *policy* layer that decides,
per hop, how that wire is spent, running identically on both engine
backends:

  * :mod:`repro.control.adaptive`   — an entropy-adaptive codec controller:
    a pure, traceable policy that picks the codec rung per hop from the
    observed ignorance statistics (front-load precision while the signal is
    still high-entropy, decay to cheap rungs as it concentrates).  Rides the
    eager transports through a cached jit and the compiled session scan as a
    branchless rung-index computation in the carry.
  * :mod:`repro.control.scheduler`  — a budget-aware round scheduler that
    reorders agents each round by remaining link budget (and optionally an
    observed-reward EMA), so the same :class:`~repro.comm.budget.BudgetSpec`
    caps buy more accuracy than the degrade-then-skip ladder alone.
  * :mod:`repro.control.accounting` — Rényi-DP (moments) accounting behind
    the :class:`~repro.comm.privacy.PrivacyAccountant` interface: releases
    compose in RDP, conversion to (ε, δ) happens on read, and the reported
    ε is never larger than basic additive composition on the same trace.

Controller state (the entropy EMA) and accountant state (release counts)
are part of the protocol state: they checkpoint through ``SessionState``
(the comm snapshot) and survive pause/resume with no free bits and no ε
resets.
"""
from repro.control.accounting import (ACCOUNTANTS, RDPAccountant,
                                      SubsampledRDPAccountant,
                                      make_accountant, sgm_rdp,
                                      subsampled_rdp_epsilon)
from repro.control.adaptive import (AdaptiveController, ServeController,
                                    controller_rung, jitted_controller,
                                    jitted_serve_controller)
from repro.control.scheduler import BudgetAwareScheduler

__all__ = [
    "ACCOUNTANTS", "AdaptiveController", "BudgetAwareScheduler",
    "RDPAccountant", "ServeController", "SubsampledRDPAccountant",
    "controller_rung", "jitted_controller", "jitted_serve_controller",
    "make_accountant", "sgm_rdp", "subsampled_rdp_epsilon",
]
