"""Per-tenant latency SLOs and error-budget burn.

An SLO here is the classic serving objective: "fraction ``objective`` of a
tenant's requests complete within ``threshold_s`` seconds".  The error
budget is the allowed violation fraction ``1 - objective``; **burn** is the
share of that budget consumed so far::

    burn = violations / (requests * (1 - objective))

burn < 1.0 means the tenant is inside its objective, burn >= 1.0 means the
objective is blown for the window observed.  Admission denials count as
violations — a tenant turned away at the door did not get an answer within
threshold, and hiding denials from the SLO would let an over-aggressive
admission policy look "fast".

The tracker is registry-backed (``slo_requests_total{tenant}``,
``slo_violations_total{tenant}`` counters and a ``slo_burn{tenant}``
gauge), so SLO state travels in the same traces/snapshots as everything
else and the dashboard reads it with the stock accessors.  Wired in by
:class:`~repro.serve.engine.ServeEngine`: request latency is observed at
the single submit -> flush-complete settle point, denials at admission.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.telemetry.registry import MetricsRegistry


@dataclass(frozen=True)
class SLOConfig:
    """One latency objective applied to every tenant: requests should
    complete within ``threshold_s`` seconds at least ``objective`` of the
    time (e.g. threshold_s=0.25, objective=0.99 == "p99 under 250ms")."""
    threshold_s: float = 0.25
    objective: float = 0.99

    def __post_init__(self):
        if self.threshold_s <= 0:
            raise ValueError(f"threshold_s must be > 0, "
                             f"got {self.threshold_s}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError(f"objective must be in (0, 1), "
                             f"got {self.objective}")


class SLOTracker:
    """Folds per-request outcomes into per-tenant SLO counters and keeps
    the burn gauge current.  All state lives in the registry, so a
    reloaded trace reconstructs the same report."""

    def __init__(self, config: SLOConfig,
                 registry: MetricsRegistry) -> None:
        self.config = config
        self.registry = registry

    # -------------------------------------------------------------- folds
    def observe(self, tenant: str, seconds: float) -> None:
        """One completed request: latency against the threshold."""
        self.registry.inc("slo_requests_total", 1, tenant=tenant)
        if seconds > self.config.threshold_s:
            self.registry.inc("slo_violations_total", 1, tenant=tenant)
        self._update_burn(tenant)

    def record_denial(self, tenant: str) -> None:
        """One admission denial: a request that never completed, booked
        as a violation against the tenant's error budget."""
        self.registry.inc("slo_requests_total", 1, tenant=tenant)
        self.registry.inc("slo_violations_total", 1, tenant=tenant)
        self._update_burn(tenant)

    def _update_burn(self, tenant: str) -> None:
        self.registry.set_gauge("slo_burn", self.burn(tenant),
                                tenant=tenant)

    # -------------------------------------------------------------- reads
    def burn(self, tenant: str) -> float:
        """Error-budget burn for one tenant (0.0 before any request)."""
        requests = self.registry.value("slo_requests_total", tenant=tenant)
        if not requests:
            return 0.0
        violations = self.registry.value("slo_violations_total",
                                         tenant=tenant)
        return violations / (requests * (1.0 - self.config.objective))

    def report(self) -> dict:
        """{tenant: {requests, violations, burn, ok}} for every tenant
        seen, plus the config — the fleet-summary / dashboard block."""
        tenants = self.registry.label_values("slo_requests_total", "tenant")
        return {
            "threshold_s": self.config.threshold_s,
            "objective": self.config.objective,
            "tenants": {
                t: {
                    "requests": self.registry.value("slo_requests_total",
                                                    tenant=t),
                    "violations": self.registry.value(
                        "slo_violations_total", tenant=t),
                    "burn": self.burn(t),
                    "ok": self.burn(t) < 1.0,
                }
                for t in tenants
            },
        }
