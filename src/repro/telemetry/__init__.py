"""Telemetry: the unified observability layer for train/serve/scenario runs.

One :class:`Telemetry` object owns a :class:`MetricsRegistry` (every
counter the system keeps: wire bits, DP releases, budget skips, admission
outcomes, cache/batch events) and a :class:`SpanTracer` (session -> round
-> hop on the train path, flush -> flush_wave -> bucket_dispatch on the
serve path), plus the attach/export plumbing that wires them into a run:

    tele = Telemetry()
    proto = Protocol(..., telemetry=tele)
    proto.fit(...)
    tele.write_artifacts(trace="run.jsonl", metrics_out="run.json",
                         transport=proto.transport)

The hard invariant (asserted by tests/test_telemetry.py): a run with
telemetry attached is bit-identical to the same run without — observation
reads already-computed host values, never folds keys, never adds device
dispatches inside traced code, never perturbs the budget ladder walk.

Emission sits at the choke points both engine backends share
(`TransportLog.send_bits`, `PrivacyAccountant.record`,
`BudgetedTransport.record_skip`/`record_spend`): eager paths emit live as
hops happen; the compiled backend emits while `Protocol._replay_traffic` /
`_replay_serve` / the scenario `_replay` walk the scanned ledger — so eager
and compiled runs produce identical registries wherever their ledgers
already agree (which the backend-parity tests pin).
"""
from __future__ import annotations

from repro.telemetry.export import (StreamingTraceWriter,  # noqa: F401
                                    snapshot, write_metrics, write_trace)
from repro.telemetry.live import LiveSink  # noqa: F401
from repro.telemetry.registry import MetricsRegistry
from repro.telemetry.spans import Span, SpanTracer  # noqa: F401


class Telemetry:
    """Registry + tracer + attach/export plumbing for one run.

    ``profile`` additionally opens ``jax.profiler`` trace annotations per
    span (pair with ``jax.profiler.trace(dir)`` around the run); ``fence``
    controls the ``block_until_ready`` fences at dispatch boundaries
    (timing-only — on by default so span durations measure computation,
    not async-dispatch enqueue); ``live`` opens the in-flight emission
    plane (:mod:`repro.telemetry.live`): compiled programs stream
    per-round taps into this registry *while executing* instead of going
    dark until the post-run replay.
    """

    def __init__(self, *, profile: bool = False, fence: bool = True,
                 live: bool = False):
        self.registry = MetricsRegistry()
        self.tracer = SpanTracer(self.registry, profile=profile,
                                 fence=fence)
        self.live: LiveSink | None = (LiveSink(self.registry)
                                      if live else None)
        self._stream: StreamingTraceWriter | None = None

    def stream_trace(self, path: str) -> StreamingTraceWriter:
        """Open a crash-durable JSONL trace at ``path``: the meta line
        lands now, every span appends as it closes, and
        :meth:`write_artifacts` (or :meth:`StreamingTraceWriter.close`)
        seals it with the metric events.  A run killed in between leaves
        a truncated-but-well-formed prefix ``repro.telemetry.check
        --allow-partial`` accepts — instead of no trace at all."""
        self._stream = StreamingTraceWriter(path, registry=self.registry,
                                            tracer=self.tracer)
        if self.live is not None:
            self.live.writer = self._stream
        return self._stream

    def span(self, name: str, step: int | None = None, **attrs):
        return self.tracer.span(name, step, **attrs)

    def fence(self, value):
        return self.tracer.fence(value)

    # ------------------------------------------------------------- attach
    def attach_transport(self, transport) -> None:
        """Point a transport's ledger surfaces at this registry.

        Idempotent (re-attaching the same transport is a no-op) and
        backfilling: entries and DP releases booked *before* attach are
        folded in once, so attach order doesn't skew totals.  Budgeted
        entries carry the codec rung that priced them, so ``hops_by_rung``
        backfills too — a registry attached after traffic agrees with one
        attached before.
        """
        log = getattr(transport, "log", None)
        if log is None and hasattr(transport, "send_bits"):
            log = transport                  # a bare TransportLog
        if log is not None and \
                getattr(log, "registry", None) is not self.registry:
            for e in log.entries:
                self.registry.inc("wire_bits_total", e["bits"],
                                  kind=e["kind"], src=e["src"],
                                  dst=e["dst"])
                self.registry.inc("messages_total", 1, kind=e["kind"])
                if "rung" in e:
                    self.registry.inc("hops_by_rung_total", 1,
                                      rung=e["rung"])
            for link in getattr(transport, "skipped", ()):
                self.registry.inc("budget_skips_total", 1,
                                  src=link[0], dst=link[1])
            log.registry = self.registry
        accountant = getattr(transport, "accountant", None)
        if accountant is not None and \
                getattr(accountant, "registry", None) is not self.registry:
            for agent, count in accountant.releases.items():
                self.registry.inc("dp_releases_total", count, agent=agent)
            accountant.registry = self.registry

    def sync_gauges(self, transport) -> None:
        """Copy the budget state that isn't event-shaped (per-link spent
        bits, the exhausted flag) into gauges — called at export time."""
        for (src, dst), bits in sorted(
                getattr(transport, "link_spent", {}).items()):
            self.registry.set_gauge("budget_link_spent_bits", bits,
                                    src=src, dst=dst)
        if hasattr(transport, "exhausted"):
            self.registry.set_gauge("budget_exhausted",
                                    int(transport.exhausted))

    # ------------------------------------------------------------- export
    def write_artifacts(self, *, trace: str | None = None,
                        metrics_out: str | None = None,
                        transport=None) -> None:
        """Write the requested artifacts (``--trace`` JSONL event log,
        ``--metrics-out`` JSON snapshot or ``.prom`` text)."""
        if transport is not None:
            self.sync_gauges(transport)
        if trace:
            if self._stream is not None and self._stream.path == trace:
                # the run streamed here all along: seal with the metric
                # events rather than rewriting from scratch
                self._stream.close()
            else:
                write_trace(trace, registry=self.registry,
                            tracer=self.tracer)
        if metrics_out:
            write_metrics(metrics_out, self.registry, self.tracer)
