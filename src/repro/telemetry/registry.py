"""The unified metrics registry: one sink for every counter the system keeps.

ASCII's currencies — interchange bits, DP releases, budget skips, serve
admission outcomes — were tallied in four disjoint ad-hoc surfaces
(`TransportLog.bits_by_kind`, `AdmissionController` per-tenant ints,
batcher/cache counters, `PrivacyAccountant.releases`).  This registry is the
single store behind all of them: labeled counters, gauges, and histograms
with deterministic ordering, JSON-able event export, and exact integer
arithmetic for bit tallies.

Design constraints (the telemetry hard invariant):

  * **observation only** — the registry is written from host-side code that
    reads already-computed values (ledger bookings, replay walks, settle
    hooks).  It never folds PRNG keys, never adds device dispatches, and is
    never read by protocol logic, so telemetry-on and telemetry-off runs are
    bit-identical on every pinned trajectory.
  * **both backends, one layer** — emission hooks sit at the choke points
    both backends already share (`TransportLog.send_bits`,
    `PrivacyAccountant.record`, `BudgetedTransport.record_skip`/
    `record_spend`): eager paths emit live, the compiled backend emits
    during its post-run ledger replay, so eager and compiled runs produce
    identical registries wherever their ledgers already agree.
  * **cheap** — an increment is one dict update on a sorted-label key; no
    locks, no strings formatted until export.

Metric name conventions (see README "Observability" for the full table):
``*_total`` counters, ``*_bits``/``*_seconds`` units in the name, labels
for the dimension that varies (kind/src/dst/agent/tenant/rung/event).
"""
from __future__ import annotations

import bisect
import math

#: Fixed exponential histogram bucket bounds (powers of two, seconds-
#: oriented: ~1 microsecond to 32 seconds, plus a +Inf overflow bucket).
#: Fixed and global on purpose: every histogram in every run buckets
#: identically, so traces diff, registries from different processes merge,
#: and the validator needs no per-metric bound configuration.
BUCKET_BOUNDS: tuple = tuple(2.0 ** e for e in range(-20, 6))
NUM_BUCKETS = len(BUCKET_BOUNDS) + 1          # trailing +Inf bucket


def bucket_index(value: float) -> int:
    """The bucket a value lands in: smallest i with value <= BUCKET_BOUNDS
    [i] (Prometheus ``le`` semantics), NUM_BUCKETS-1 for the overflow."""
    return bisect.bisect_left(BUCKET_BOUNDS, value)


def quantile_estimate(agg: dict, q: float) -> float | None:
    """Estimate the q-quantile of one histogram aggregate from its bucket
    counts: find the bucket holding the target rank and interpolate
    linearly inside it (clamped to the observed [min, max], so single-
    bucket and overflow cases stay sane).  Returns None for an empty
    aggregate or a bucketless (schema-v1) one."""
    count = agg.get("count", 0)
    buckets = agg.get("buckets")
    if not count or not buckets:
        return None
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    rank = max(1, math.ceil(q * count))
    cum = 0
    for i, c in enumerate(buckets):
        cum += c
        if cum >= rank:
            lo = BUCKET_BOUNDS[i - 1] if i > 0 else agg["min"]
            hi = (BUCKET_BOUNDS[i] if i < len(BUCKET_BOUNDS)
                  else agg["max"])
            frac = (rank - (cum - c)) / c
            est = lo + (hi - lo) * frac
            return min(max(est, agg["min"]), agg["max"])
    return agg["max"]


def _label_key(labels: dict) -> tuple:
    """Canonical hashable key: sorted (name, value) pairs, values
    stringified once so ints/bools label identically to their str forms."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Labeled counters, gauges, and histogram aggregates.

    A *series* is (metric name, label set); counters accumulate, gauges
    hold the last set value, histograms keep {count, sum, min, max} plus
    fixed exponential bucket counts (:data:`BUCKET_BOUNDS` — global, so
    no per-metric bound configuration can drift) from which
    :meth:`quantile` estimates percentiles to within one bucket.
    """

    def __init__(self) -> None:
        self._counters: dict[str, dict[tuple, int | float]] = {}
        self._gauges: dict[str, dict[tuple, float]] = {}
        self._hists: dict[str, dict[tuple, dict]] = {}

    # -------------------------------------------------------------- writes
    def inc(self, name: str, value: int | float = 1, /, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {name!r} increments must be >= 0, "
                             f"got {value}")
        series = self._counters.setdefault(name, {})
        key = _label_key(labels)
        series[key] = series.get(key, 0) + value

    def set_gauge(self, name: str, value: float, /, **labels) -> None:
        self._gauges.setdefault(name, {})[_label_key(labels)] = value

    def observe(self, name: str, value: float, /, **labels) -> None:
        series = self._hists.setdefault(name, {})
        key = _label_key(labels)
        agg = series.get(key)
        if agg is None:
            counts = [0] * NUM_BUCKETS
            counts[bucket_index(value)] = 1
            series[key] = {"count": 1, "sum": value, "min": value,
                           "max": value, "buckets": counts}
        else:
            agg["count"] += 1
            agg["sum"] += value
            agg["min"] = min(agg["min"], value)
            agg["max"] = max(agg["max"], value)
            counts = agg.get("buckets")
            if counts is not None:       # absent on reloaded v1 aggregates
                counts[bucket_index(value)] += 1

    # --------------------------------------------------------------- reads
    def value(self, name: str, /, **labels) -> int | float:
        """Counter value of one exact series (0 when never incremented)."""
        return self._counters.get(name, {}).get(_label_key(labels), 0)

    def gauge(self, name: str, /, **labels) -> float | None:
        return self._gauges.get(name, {}).get(_label_key(labels))

    def histogram(self, name: str, /, **labels) -> dict | None:
        agg = self._hists.get(name, {}).get(_label_key(labels))
        if agg is None:
            return None
        out = dict(agg)
        if "buckets" in out:
            out["buckets"] = list(out["buckets"])
        return out

    def quantile(self, name: str, q: float, /, **labels) -> float | None:
        """Estimated q-quantile of one exact histogram series (None when
        the series doesn't exist or carries no buckets).  Accurate to
        within one bucket of the exact percentile — the resolution the
        fixed exponential bounds buy."""
        agg = self._hists.get(name, {}).get(_label_key(labels))
        return None if agg is None else quantile_estimate(agg, q)

    def merged_histogram(self, name: str) -> dict | None:
        """One aggregate folding every label set of ``name`` together —
        the cross-tenant view ``quantile_all`` and the dashboard read."""
        series = self._hists.get(name)
        if not series:
            return None
        merged: dict | None = None
        for agg in series.values():
            if merged is None:
                merged = {"count": agg["count"], "sum": agg["sum"],
                          "min": agg["min"], "max": agg["max"],
                          "buckets": list(agg.get("buckets") or
                                          [0] * NUM_BUCKETS)}
            else:
                merged["count"] += agg["count"]
                merged["sum"] += agg["sum"]
                merged["min"] = min(merged["min"], agg["min"])
                merged["max"] = max(merged["max"], agg["max"])
                for i, c in enumerate(agg.get("buckets") or ()):
                    merged["buckets"][i] += c
        return merged

    def quantile_all(self, name: str, q: float) -> float | None:
        """Estimated q-quantile across every label set of ``name``."""
        merged = self.merged_histogram(name)
        return None if merged is None else quantile_estimate(merged, q)

    def total(self, name: str) -> int | float:
        """Counter total across every label set of ``name``."""
        return sum(self._counters.get(name, {}).values())

    def series(self, name: str) -> dict[tuple, int | float]:
        """{label-key tuple: value} for one counter, deterministically
        ordered — the raw readback the serve counters build on."""
        return dict(sorted(self._counters.get(name, {}).items()))

    def label_values(self, name: str, label: str) -> list[str]:
        """Distinct values of one label across a counter's series, sorted."""
        out = set()
        for key in self._counters.get(name, {}):
            for k, v in key:
                if k == label:
                    out.add(v)
        return sorted(out)

    def counter_names(self) -> list[str]:
        return sorted(self._counters)

    # -------------------------------------------------------------- events
    def to_events(self) -> list[dict]:
        """The registry as a deterministic list of JSON-able metric events —
        the JSONL trace payload, loss-free: ``from_events`` round-trips."""
        events: list[dict] = []
        for name in sorted(self._counters):
            for key, value in sorted(self._counters[name].items()):
                events.append({"type": "counter", "name": name,
                               "labels": dict(key), "value": value})
        for name in sorted(self._gauges):
            for key, value in sorted(self._gauges[name].items()):
                events.append({"type": "gauge", "name": name,
                               "labels": dict(key), "value": value})
        for name in sorted(self._hists):
            for key, agg in sorted(self._hists[name].items()):
                e = {"type": "histogram", "name": name,
                     "labels": dict(key), **agg}
                if "buckets" in e:
                    e["buckets"] = list(e["buckets"])
                events.append(e)
        return events

    @classmethod
    def from_events(cls, events: list[dict]) -> "MetricsRegistry":
        """Rebuild a registry from ``to_events`` output (JSONL reload)."""
        reg = cls()
        for e in events:
            kind = e.get("type")
            if kind == "counter":
                reg.inc(e["name"], e["value"], **e.get("labels", {}))
            elif kind == "gauge":
                reg.set_gauge(e["name"], e["value"], **e.get("labels", {}))
            elif kind == "histogram":
                series = reg._hists.setdefault(e["name"], {})
                agg = {"count": e["count"], "sum": e["sum"],
                       "min": e["min"], "max": e["max"]}
                if e.get("buckets") is not None:   # absent in v1 traces
                    agg["buckets"] = list(e["buckets"])
                series[_label_key(e.get("labels", {}))] = agg
        return reg
