"""The unified metrics registry: one sink for every counter the system keeps.

ASCII's currencies — interchange bits, DP releases, budget skips, serve
admission outcomes — were tallied in four disjoint ad-hoc surfaces
(`TransportLog.bits_by_kind`, `AdmissionController` per-tenant ints,
batcher/cache counters, `PrivacyAccountant.releases`).  This registry is the
single store behind all of them: labeled counters, gauges, and histograms
with deterministic ordering, JSON-able event export, and exact integer
arithmetic for bit tallies.

Design constraints (the telemetry hard invariant):

  * **observation only** — the registry is written from host-side code that
    reads already-computed values (ledger bookings, replay walks, settle
    hooks).  It never folds PRNG keys, never adds device dispatches, and is
    never read by protocol logic, so telemetry-on and telemetry-off runs are
    bit-identical on every pinned trajectory.
  * **both backends, one layer** — emission hooks sit at the choke points
    both backends already share (`TransportLog.send_bits`,
    `PrivacyAccountant.record`, `BudgetedTransport.record_skip`/
    `record_spend`): eager paths emit live, the compiled backend emits
    during its post-run ledger replay, so eager and compiled runs produce
    identical registries wherever their ledgers already agree.
  * **cheap** — an increment is one dict update on a sorted-label key; no
    locks, no strings formatted until export.

Metric name conventions (see README "Observability" for the full table):
``*_total`` counters, ``*_bits``/``*_seconds`` units in the name, labels
for the dimension that varies (kind/src/dst/agent/tenant/rung/event).
"""
from __future__ import annotations


def _label_key(labels: dict) -> tuple:
    """Canonical hashable key: sorted (name, value) pairs, values
    stringified once so ints/bools label identically to their str forms."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Labeled counters, gauges, and histogram aggregates.

    A *series* is (metric name, label set); counters accumulate, gauges
    hold the last set value, histograms keep {count, sum, min, max} — the
    aggregate the span tracer and benchmarks need, without bucket-bound
    configuration to drift.
    """

    def __init__(self) -> None:
        self._counters: dict[str, dict[tuple, int | float]] = {}
        self._gauges: dict[str, dict[tuple, float]] = {}
        self._hists: dict[str, dict[tuple, dict]] = {}

    # -------------------------------------------------------------- writes
    def inc(self, name: str, value: int | float = 1, /, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {name!r} increments must be >= 0, "
                             f"got {value}")
        series = self._counters.setdefault(name, {})
        key = _label_key(labels)
        series[key] = series.get(key, 0) + value

    def set_gauge(self, name: str, value: float, /, **labels) -> None:
        self._gauges.setdefault(name, {})[_label_key(labels)] = value

    def observe(self, name: str, value: float, /, **labels) -> None:
        series = self._hists.setdefault(name, {})
        key = _label_key(labels)
        agg = series.get(key)
        if agg is None:
            series[key] = {"count": 1, "sum": value, "min": value,
                           "max": value}
        else:
            agg["count"] += 1
            agg["sum"] += value
            agg["min"] = min(agg["min"], value)
            agg["max"] = max(agg["max"], value)

    # --------------------------------------------------------------- reads
    def value(self, name: str, /, **labels) -> int | float:
        """Counter value of one exact series (0 when never incremented)."""
        return self._counters.get(name, {}).get(_label_key(labels), 0)

    def gauge(self, name: str, /, **labels) -> float | None:
        return self._gauges.get(name, {}).get(_label_key(labels))

    def histogram(self, name: str, /, **labels) -> dict | None:
        agg = self._hists.get(name, {}).get(_label_key(labels))
        return None if agg is None else dict(agg)

    def total(self, name: str) -> int | float:
        """Counter total across every label set of ``name``."""
        return sum(self._counters.get(name, {}).values())

    def series(self, name: str) -> dict[tuple, int | float]:
        """{label-key tuple: value} for one counter, deterministically
        ordered — the raw readback the serve counters build on."""
        return dict(sorted(self._counters.get(name, {}).items()))

    def label_values(self, name: str, label: str) -> list[str]:
        """Distinct values of one label across a counter's series, sorted."""
        out = set()
        for key in self._counters.get(name, {}):
            for k, v in key:
                if k == label:
                    out.add(v)
        return sorted(out)

    def counter_names(self) -> list[str]:
        return sorted(self._counters)

    # -------------------------------------------------------------- events
    def to_events(self) -> list[dict]:
        """The registry as a deterministic list of JSON-able metric events —
        the JSONL trace payload, loss-free: ``from_events`` round-trips."""
        events: list[dict] = []
        for name in sorted(self._counters):
            for key, value in sorted(self._counters[name].items()):
                events.append({"type": "counter", "name": name,
                               "labels": dict(key), "value": value})
        for name in sorted(self._gauges):
            for key, value in sorted(self._gauges[name].items()):
                events.append({"type": "gauge", "name": name,
                               "labels": dict(key), "value": value})
        for name in sorted(self._hists):
            for key, agg in sorted(self._hists[name].items()):
                events.append({"type": "histogram", "name": name,
                               "labels": dict(key), **agg})
        return events

    @classmethod
    def from_events(cls, events: list[dict]) -> "MetricsRegistry":
        """Rebuild a registry from ``to_events`` output (JSONL reload)."""
        reg = cls()
        for e in events:
            kind = e.get("type")
            if kind == "counter":
                reg.inc(e["name"], e["value"], **e.get("labels", {}))
            elif kind == "gauge":
                reg.set_gauge(e["name"], e["value"], **e.get("labels", {}))
            elif kind == "histogram":
                series = reg._hists.setdefault(e["name"], {})
                series[_label_key(e.get("labels", {}))] = {
                    "count": e["count"], "sum": e["sum"],
                    "min": e["min"], "max": e["max"]}
        return reg
