"""In-flight metric emission from compiled programs (and eager twins).

The compiled backend books every metric *after* the run: `Protocol.
_replay_traffic` walks the scanned ledger once the scan has returned, so a
long `fleet_run` or `control_sweep_run` is a black box while it executes.
This module adds the live plane: tiny `jax.debug.callback` taps inside the
scanned round body (and the serve dispatch) stream per-round scalars to a
host-side :class:`LiveSink` *while the program runs* — feeding the same
:class:`~repro.telemetry.registry.MetricsRegistry`, the streaming JSONL
trace, and the terminal dashboard.

Zero-interference contract (pinned by tests/test_telemetry_live.py and
`benchmarks/telemetry_bench.py --live`):

  * **live-on == live-off bit-identical** — the taps read values the round
    body already computes and feed them to `jax.debug.callback`, which has
    no data-flow back into the program; posteriors/ledgers are unchanged.
  * **final live registry == replay-booked registry** — the per-round
    deltas are priced by the *same formulas* the replay walk uses, so at
    program exit ``live_wire_bits_total == wire_bits_total``,
    ``live_messages_total{kind=ignorance} == messages_total{kind=
    ignorance}``, ``live_budget_skips_total == budget_skips_total``.
  * **eager == compiled** — eager paths call the sink directly with the
    same payloads, and every sink update is commutative (sums, max), so
    the two backends produce identical live series even though compiled
    taps may arrive unordered (``jax.debug.callback`` ordering is not
    guaranteed under ``vmap``).

Design notes the taps depend on:

  * Gating happens **host-side**: compiled taps always fire for every scan
    step (including rounds after early stop and batch pad slots) and carry
    an ``active`` flag; the sink drops inactive taps.  Branch-level gating
    via `lax.cond` is unsafe — under `vmap` a cond lowers to `select` and
    both branches execute.
  * Wall-clock time appears **only** in streamed trace events and the
    dashboard feed, never in the registry — registry equality across
    backends is a pinned invariant and timestamps would break it.
  * One live session at a time per sink: the module-level ``_SINK`` is the
    single routing point the compiled callbacks can reach (they close over
    nothing), installed around each compiled dispatch via
    :func:`installed` and called directly by eager paths.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

#: The active sink compiled-program callbacks route to.  Module-global on
#: purpose: `jax.debug.callback` payloads are staged at trace time and the
#: cached program must reach whatever sink the *current* run installed.
_SINK: "LiveSink | None" = None


@contextmanager
def installed(sink: "LiveSink | None"):
    """Route compiled-program taps to ``sink`` for the duration of the
    block (no-op when ``sink`` is None).  On exit, drains any callbacks
    still in flight (`jax.effects_barrier`) before restoring the previous
    sink, so a tap never lands on a dead run's sink."""
    global _SINK
    if sink is None:
        yield
        return
    prev = _SINK
    _SINK = sink
    try:
        yield
    finally:
        try:
            import jax
            jax.effects_barrier()
        except Exception:
            pass
        _SINK = prev


# ----------------------------------------------------- traced-side helpers
def _pack(*vals):
    """One int32 vector per tap: a single device->host transfer instead of
    one per scalar (per-buffer transfer overhead dominated tap cost)."""
    import jax.numpy as jnp
    return jnp.stack([jnp.asarray(v, jnp.int32) for v in vals])


def key_salt(key):
    """A zero that *depends* on the session PRNG key, added to one tap
    operand at every emit site.  Under ``vmap`` (``fleet_run``,
    ``serve_batch``) ``jax.debug.callback`` unrolls one call per batch
    element only for operands the batch axis actually reaches; with
    identical cohorts and a deterministic learner every metric operand can
    be batch-invariant, and a fully unbatched payload would collapse S
    sessions' taps into one.  The key is batched by construction, so the
    salt forces per-session delivery without changing any value."""
    import jax
    import jax.numpy as jnp
    return (jax.random.key_data(key).sum() * 0).astype(jnp.int32)


def emit_round(t, active, bits, sent, skipped, new_exh) -> None:
    """Stage a per-round progress tap inside traced code.  All arguments
    are scalar arrays the round body already computed; ``active`` is False
    for scan steps past the early-stop point (the sink drops them)."""
    import jax
    jax.debug.callback(_round_tap,
                       _pack(t, active, bits, sent, skipped, new_exh))


def emit_serve(active, bits, sent, skipped) -> None:
    """Stage a per-request serve tap inside traced code.  ``active`` is
    False for the batch-pad filler slots (deliver mask all-False)."""
    import jax
    jax.debug.callback(_serve_tap, _pack(active, bits, sent, skipped))


def _round_tap(packed) -> None:
    sink = _SINK
    if sink is not None:
        t, active, bits, sent, skipped, new_exh = (int(v) for v in packed)
        if active:
            sink.round_tap(t, bits, sent, skipped, new_exh)


def _serve_tap(packed) -> None:
    sink = _SINK
    if sink is not None:
        active, bits, sent, skipped = (int(v) for v in packed)
        if active:
            sink.serve_tap(bits, sent, skipped)


class LiveSink:
    """Host-side endpoint of the live taps: folds per-round deltas into
    the registry's ``live_*`` series, streams ``{"type": "live", ...}``
    events to the open JSONL trace, and notifies the dashboard hook.

    Every update is commutative over the tap multiset — counter sums and
    a running max for the round gauge — so unordered compiled delivery,
    eager sequential delivery, and vmapped fleet delivery all converge to
    the same registry.  The ``live_*`` prefix keeps the in-flight series
    disjoint from the replay-booked ones they must equal at exit.
    """

    def __init__(self, registry, writer=None, on_event=None) -> None:
        self.registry = registry
        #: open StreamingTraceWriter (set by Telemetry.stream_trace)
        self.writer = writer
        #: dashboard hook: called with each live event dict
        self.on_event = on_event
        self.taps = 0
        self._max_round = -1
        self._t0: float | None = None
        self._last_t: float | None = None

    # --------------------------------------------------------------- taps
    def round_tap(self, t: int, bits: int, sent: int, skipped: int,
                  new_exh: int) -> None:
        reg = self.registry
        reg.inc("live_rounds_total", 1)
        reg.inc("live_wire_bits_total", bits)
        reg.inc("live_messages_total", sent, kind="ignorance")
        reg.inc("live_budget_skips_total", skipped)
        reg.inc("live_exhausted_total", new_exh)
        self._max_round = max(self._max_round, t)
        reg.set_gauge("live_round", self._max_round)
        self._stamp({"type": "live", "tag": "round", "t": t, "bits": bits,
                     "sent": sent, "skipped": skipped,
                     "exhausted": new_exh})

    def serve_tap(self, bits: int, sent: int, skipped: int) -> None:
        reg = self.registry
        reg.inc("live_serve_requests_total", 1)
        reg.inc("live_wire_bits_total", bits)
        reg.inc("live_messages_total", sent, kind="score_block")
        reg.inc("live_budget_skips_total", skipped)
        self._stamp({"type": "live", "tag": "serve", "bits": bits,
                     "sent": sent, "skipped": skipped})

    def _stamp(self, event: dict) -> None:
        now = time.perf_counter()
        if self._t0 is None:
            self._t0 = now
        self._last_t = now
        self.taps += 1
        event["t_s"] = round(now - self._t0, 6)
        if self.writer is not None:
            self.writer.write_event(event)
        if self.on_event is not None:
            self.on_event(event)

    # -------------------------------------------------------------- reads
    def rate(self) -> float:
        """Taps per second over the sink's lifetime (0.0 before the second
        tap) — the dashboard's rounds/sec feed."""
        if self.taps < 2 or self._last_t is None or self._t0 is None:
            return 0.0
        elapsed = self._last_t - self._t0
        return (self.taps - 1) / elapsed if elapsed > 0 else 0.0
