"""Telemetry exporters: JSONL event traces, JSON snapshots, Prometheus text.

Three artifact shapes, one source (a :class:`~repro.telemetry.registry.
MetricsRegistry` and optionally a :class:`~repro.telemetry.spans.
SpanTracer`):

  * **JSONL trace** (``--trace file.jsonl``) — one event per line: a
    leading ``meta`` line (schema version), then every closed span, then
    the registry's metric events.  Loss-free: :func:`load_registry`
    rebuilds an equal registry from the file (the round-trip the exporter
    test pins), and :mod:`repro.telemetry.check` validates the schema.
  * **JSON snapshot** (``--metrics-out file.json``) — the nested
    {counters, gauges, histograms} document benchmark summaries embed.
  * **Prometheus text** (``--metrics-out file.prom``) — the standard
    exposition format, one scrape's worth, for anything that already reads
    node-exporter-style files.
"""
from __future__ import annotations

import json

from repro.telemetry.registry import MetricsRegistry

SCHEMA = "repro-telemetry"
#: v2 added bucketed histograms ("buckets" on histogram events /
#: snapshot leaves, ``_bucket{le=...}`` Prometheus exposition) and the
#: in-flight "live" event kind the streaming taps emit.  v1 traces
#: (bucketless histograms, no live events) still validate and reload.
SCHEMA_VERSION = 2
ACCEPTED_VERSIONS = (1, 2)


def meta_event() -> dict:
    return {"type": "meta", "schema": SCHEMA, "version": SCHEMA_VERSION}


def trace_events(registry: MetricsRegistry | None = None,
                 tracer=None) -> list[dict]:
    """The full JSONL payload: meta line, spans, then metric events."""
    events = [meta_event()]
    if tracer is not None:
        events.extend(tracer.to_events())
    if registry is not None:
        events.extend(registry.to_events())
    return events


def write_trace(path: str, *, registry: MetricsRegistry | None = None,
                tracer=None) -> int:
    """Write the JSONL event log; returns the number of events written."""
    events = trace_events(registry, tracer)
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e, sort_keys=True) + "\n")
    return len(events)


class StreamingTraceWriter:
    """Incremental JSONL trace export: the meta line lands on disk at open,
    every span is appended (and flushed) the moment it closes, and the
    registry's metric events are appended at :meth:`close`.

    This is the crash-durable twin of :func:`write_trace`: a session that
    dies mid-run leaves a truncated-but-well-formed *prefix* on disk —
    every span that finished survives — which ``repro.telemetry.check
    --allow-partial`` accepts (a prefix may reference a parent span that
    had not closed yet, and its final line may be torn mid-write).  A run
    that reaches :meth:`close` produces a trace
    :func:`~repro.telemetry.check.validate_events` accepts un-relaxed;
    spans appear in *close* order rather than :func:`write_trace`'s open
    order, which no consumer distinguishes (:func:`load_registry` reads
    only metric events, the validator is order-blind past the meta line).
    """

    def __init__(self, path: str, *, registry: MetricsRegistry | None = None,
                 tracer=None) -> None:
        self.path = path
        self.registry = registry
        self.tracer = tracer
        self.events_written = 0
        self._f = open(path, "w")
        self._emit(meta_event())
        if tracer is not None:
            tracer.on_close = self._on_span

    def _emit(self, event: dict) -> None:
        self._f.write(json.dumps(event, sort_keys=True) + "\n")
        self._f.flush()
        self.events_written += 1

    def _on_span(self, span) -> None:
        if not self._f.closed:
            self._emit(span.to_event())

    def write_event(self, event: dict) -> None:
        """Append one extra event mid-stream (the live-emission taps push
        their per-round progress events here while the compiled program is
        still executing).  Dropped silently after :meth:`close` — a tap
        that outlives the trace has nowhere durable to land anyway."""
        if not self._f.closed:
            self._emit(event)

    def close(self) -> int:
        """Append the metric events and seal the file; returns the total
        event count.  Idempotent (a second close is a no-op)."""
        if self._f.closed:
            return self.events_written
        if self.registry is not None:
            for e in self.registry.to_events():
                self._emit(e)
        self._f.close()
        if self.tracer is not None and self.tracer.on_close == self._on_span:
            self.tracer.on_close = None
        return self.events_written

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def load_events(path: str, *, allow_partial: bool = False) -> list[dict]:
    """Parse a JSONL trace.  ``allow_partial`` tolerates a torn final line
    (a streaming writer killed mid-``write``): the un-parseable tail line
    is dropped instead of raising; a torn line anywhere *else* still
    raises — truncation only ever eats the end of a stream."""
    events = []
    with open(path) as f:
        lines = [ln.strip() for ln in f]
    lines = [ln for ln in lines if ln]
    for i, line in enumerate(lines):
        try:
            events.append(json.loads(line))
        except json.JSONDecodeError:
            if allow_partial and i == len(lines) - 1:
                break
            raise
    return events


def load_registry(path: str) -> MetricsRegistry:
    """Rebuild the metrics registry from a JSONL trace (span and meta
    events are ignored; metric events reload loss-free)."""
    return MetricsRegistry.from_events(
        [e for e in load_events(path)
         if e.get("type") in ("counter", "gauge", "histogram")])


# ------------------------------------------------------------------ snapshots
def snapshot(registry: MetricsRegistry, tracer=None) -> dict:
    """Nested JSON-able snapshot: per-metric series keyed by a stable
    ``label=value`` joined string (empty-label series key "")."""
    def nest(events_of_type, value_of):
        out: dict = {}
        for e in events_of_type:
            key = ",".join(f"{k}={v}" for k, v in sorted(e["labels"].items()))
            out.setdefault(e["name"], {})[key] = value_of(e)
        return out

    events = registry.to_events()
    doc = {
        "schema": SCHEMA, "version": SCHEMA_VERSION,
        "counters": nest((e for e in events if e["type"] == "counter"),
                         lambda e: e["value"]),
        "gauges": nest((e for e in events if e["type"] == "gauge"),
                       lambda e: e["value"]),
        "histograms": nest(
            (e for e in events if e["type"] == "histogram"),
            lambda e: {k: e[k] for k in
                       ("count", "sum", "min", "max", "buckets")
                       if k in e}),
    }
    if tracer is not None:
        doc["spans"] = len(tracer.spans)
    return doc


def _prom_escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _prom_series(name: str, key: tuple, value) -> str:
    if not key:
        return f"{name} {value}"
    labels = ",".join(f'{k}="{_prom_escape(v)}"' for k, v in key)
    return f"{name}{{{labels}}} {value}"


def _prom_bound(bound: float) -> str:
    """A bucket bound as Prometheus renders it: integral bounds without a
    trailing ``.0`` so ``le="1"`` not ``le="1.0"``."""
    return str(int(bound)) if float(bound).is_integer() else repr(bound)


def prometheus_text(registry: MetricsRegistry) -> str:
    """One scrape in the Prometheus text exposition format.  Histograms
    export natively — cumulative ``_bucket{le=...}`` samples over the
    global :data:`~repro.telemetry.registry.BUCKET_BOUNDS` plus
    ``_sum``/``_count`` — with ``_min``/``_max`` kept as companion gauges
    (Prometheus histograms don't carry extrema).  A bucketless aggregate
    (reloaded from a v1 trace) falls back to the summary-style export."""
    from repro.telemetry.registry import BUCKET_BOUNDS
    lines: list[str] = []
    for name in sorted(registry._counters):
        lines.append(f"# TYPE {name} counter")
        for key, value in sorted(registry._counters[name].items()):
            lines.append(_prom_series(name, key, value))
    for name in sorted(registry._gauges):
        lines.append(f"# TYPE {name} gauge")
        for key, value in sorted(registry._gauges[name].items()):
            lines.append(_prom_series(name, key, value))
    for name in sorted(registry._hists):
        series = sorted(registry._hists[name].items())
        if all(agg.get("buckets") for _, agg in series):
            lines.append(f"# TYPE {name} histogram")
            for key, agg in series:
                cum = 0
                for i, bound in enumerate(BUCKET_BOUNDS):
                    cum += agg["buckets"][i]
                    lines.append(_prom_series(
                        f"{name}_bucket",
                        key + (("le", _prom_bound(bound)),), cum))
                lines.append(_prom_series(f"{name}_bucket",
                                          key + (("le", "+Inf"),),
                                          agg["count"]))
                lines.append(_prom_series(f"{name}_sum", key, agg["sum"]))
                lines.append(_prom_series(f"{name}_count", key,
                                          agg["count"]))
            extrema = ("min", "max")
        else:
            extrema = ("count", "sum", "min", "max")
        for suffix in extrema:
            lines.append(f"# TYPE {name}_{suffix} gauge")
            for key, agg in series:
                lines.append(_prom_series(f"{name}_{suffix}", key,
                                          agg[suffix]))
    return "\n".join(lines) + "\n"


def write_metrics(path: str, registry: MetricsRegistry,
                  tracer=None) -> None:
    """Write the metrics artifact ``--metrics-out`` asks for: Prometheus
    text when the path ends in ``.prom``, else the JSON snapshot."""
    if path.endswith(".prom"):
        with open(path, "w") as f:
            f.write(prometheus_text(registry))
        return
    with open(path, "w") as f:
        json.dump(snapshot(registry, tracer), f, indent=2, sort_keys=True)
        f.write("\n")
