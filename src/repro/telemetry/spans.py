"""Span tracing for protocol runs: session -> round -> hop on the train
path, flush -> flush_wave -> bucket_dispatch on the serve path.

A :class:`Span` is a closed wall-clock interval with a name, a parent, and
JSON-able attributes; the :class:`SpanTracer` maintains the open-span stack
(so nesting falls out of lexical scope), records every closed span, and
feeds per-span durations into the metrics registry as ``span_seconds``
histograms.

Two JIT-awareness knobs, both timing-only (numerics are never touched):

  * ``fence`` — :meth:`SpanTracer.fence` runs ``jax.block_until_ready`` on
    the value a dispatch boundary produced, so the enclosing span measures
    the *computation*, not the async-dispatch enqueue.  Callers place
    fences at dispatch boundaries only (the compiled session / serve-batch
    call sites); traced code never fences.
  * ``profile`` — spans additionally open ``jax.profiler``
    ``TraceAnnotation`` scopes (``StepTraceAnnotation`` when the span has a
    ``step``), so an XLA profile captured with ``jax.profiler.trace`` lines
    up with protocol rounds and flush waves.
"""
from __future__ import annotations

import time
from contextlib import ExitStack, contextmanager


class Span:
    """One closed (or still-open) traced interval."""

    __slots__ = ("span_id", "parent_id", "name", "start_s", "end_s", "attrs")

    def __init__(self, span_id: int, parent_id: int | None, name: str,
                 start_s: float, attrs: dict) -> None:
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.start_s = start_s
        self.end_s: float | None = None
        self.attrs = attrs

    @property
    def duration_s(self) -> float | None:
        return None if self.end_s is None else self.end_s - self.start_s

    def to_event(self) -> dict:
        return {"type": "span", "id": self.span_id,
                "parent": self.parent_id, "name": self.name,
                "start_s": self.start_s, "end_s": self.end_s,
                "attrs": self.attrs}


class SpanTracer:
    """Open/close spans with automatic parenting; record them all.

    ``registry`` (optional) receives a ``span_seconds{name=...}`` histogram
    observation per closed span.  ``clock`` is injectable for tests.
    """

    def __init__(self, registry=None, *, profile: bool = False,
                 fence: bool = True, clock=time.perf_counter) -> None:
        self.registry = registry
        self.profile = profile
        self.fence_enabled = fence
        self.clock = clock
        self.spans: list[Span] = []
        self._stack: list[Span] = []
        self._next_id = 0
        # close hook: called with each span as it closes (streaming trace
        # export appends it to disk there, so a killed run keeps every
        # span that finished).  Observation only — never touches the span.
        self.on_close = None

    @property
    def current(self) -> Span | None:
        return self._stack[-1] if self._stack else None

    @contextmanager
    def span(self, name: str, step: int | None = None, **attrs):
        """Open a child of the current span for the ``with`` body."""
        parent = self._stack[-1].span_id if self._stack else None
        if step is not None:
            attrs = dict(attrs, step=int(step))
        sp = Span(self._next_id, parent, name, self.clock(), attrs)
        self._next_id += 1
        self.spans.append(sp)
        self._stack.append(sp)
        try:
            with ExitStack() as es:
                if self.profile:
                    import jax.profiler
                    if step is not None:
                        es.enter_context(jax.profiler.StepTraceAnnotation(
                            name, step_num=int(step)))
                    else:
                        es.enter_context(
                            jax.profiler.TraceAnnotation(name))
                yield sp
        finally:
            sp.end_s = self.clock()
            self._stack.pop()
            if self.registry is not None:
                self.registry.observe("span_seconds", sp.duration_s,
                                      name=name)
            if self.on_close is not None:
                self.on_close(sp)

    def fence(self, value):
        """Wall-clock fence at a dispatch boundary: block until ``value``'s
        arrays are ready (when fencing is on), then return it unchanged.
        Synchronization only — the value is never modified."""
        if self.fence_enabled and value is not None:
            import jax
            jax.block_until_ready(value)
        return value

    # ------------------------------------------------------------- readback
    def to_events(self) -> list[dict]:
        return [sp.to_event() for sp in self.spans]

    def well_formed(self) -> bool:
        """Every span closed, every parent id resolvable and opened before
        its child — the invariant the span-tree test pins."""
        by_id = {sp.span_id: sp for sp in self.spans}
        for sp in self.spans:
            if sp.end_s is None:
                return False
            if sp.parent_id is not None:
                parent = by_id.get(sp.parent_id)
                if parent is None or parent.start_s > sp.start_s:
                    return False
        return not self._stack
