"""Schema validation for telemetry artifacts — the reusable ``--check``.

``python -m repro.telemetry.check [--allow-partial] FILE [FILE ...]``
validates each file by suffix and exits nonzero on the first violation
(``--allow-partial`` accepts the truncated prefix a killed streaming
trace writer leaves — ``.jsonl`` only):

  * ``.jsonl`` — JSONL event trace: leading meta line with the right
    schema/version, every event one of meta/span/counter/gauge/histogram/
    live with the required fields, every span closed with a resolvable
    parent, histogram bucket counts (v2) consistent with their totals.
  * ``.json``  — metrics snapshot: schema/version plus the
    counters/gauges/histograms maps with numeric leaves.
  * ``.prom``  — Prometheus text: every non-comment line parses as
    ``name{labels} value`` (or bare ``name value``) with a numeric value
    and a preceding ``# TYPE`` for its metric family.

CI runs this over the artifacts the instrumented bench-smoke workloads
emit; tests reuse the validators directly.
"""
from __future__ import annotations

import json
import re
import sys

from repro.telemetry.export import (ACCEPTED_VERSIONS, SCHEMA,
                                    SCHEMA_VERSION, load_events)
from repro.telemetry.registry import NUM_BUCKETS

_METRIC_FIELDS = {
    "counter": ("name", "labels", "value"),
    "gauge": ("name", "labels", "value"),
    "histogram": ("name", "labels", "count", "sum", "min", "max"),
}
_SPAN_FIELDS = ("id", "parent", "name", "start_s", "end_s", "attrs")
#: In-flight progress events streamed by the live taps (schema v2+):
#: a tag naming the tap plus whatever scalars it carries.
_LIVE_FIELDS = ("tag",)
_PROM_LINE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{.*\})?\s+(-?[0-9.eE+\-infa]+)$')


def validate_events(events: list[dict],
                    allow_partial: bool = False) -> list[str]:
    """Validate a JSONL trace's event list; return human-readable errors
    (empty list == valid).

    ``allow_partial`` accepts the truncated-but-well-formed *prefix* a
    killed :class:`~repro.telemetry.export.StreamingTraceWriter` leaves
    behind: spans stream to disk in close order, so a prefix may reference
    a parent span that had not closed (and hence landed) yet, and a stream
    killed before any event flushed may be empty.  Every event that *is*
    present is still held to the full schema."""
    errors: list[str] = []
    if not events:
        return [] if allow_partial else ["empty trace: no events"]
    head = events[0]
    version = head.get("version")
    if head.get("type") != "meta":
        errors.append("first event must be type=meta")
    elif (head.get("schema") != SCHEMA or
          version not in ACCEPTED_VERSIONS):
        errors.append(f"meta schema/version mismatch: {head}")
    spans: dict = {}
    for i, e in enumerate(events):
        kind = e.get("type")
        if kind == "meta":
            if i != 0:
                errors.append(f"event {i}: meta only allowed first")
        elif kind == "span":
            missing = [f for f in _SPAN_FIELDS if f not in e]
            if missing:
                errors.append(f"event {i}: span missing {missing}")
                continue
            if e["end_s"] is None:
                errors.append(f"event {i}: span {e['name']!r} never closed")
            spans[e["id"]] = e
        elif kind in _METRIC_FIELDS:
            missing = [f for f in _METRIC_FIELDS[kind] if f not in e]
            if missing:
                errors.append(f"event {i}: {kind} missing {missing}")
            elif not isinstance(e["labels"], dict):
                errors.append(f"event {i}: labels must be an object")
            elif kind == "histogram":
                errors.extend(f"event {i}: {msg}"
                              for msg in _check_buckets(e))
        elif kind == "live":
            if version == 1:
                errors.append(f"event {i}: live events are schema v2+ "
                              f"but trace declares v1")
            missing = [f for f in _LIVE_FIELDS if f not in e]
            if missing:
                errors.append(f"event {i}: live missing {missing}")
        else:
            errors.append(f"event {i}: unknown type {kind!r}")
    if not allow_partial:
        for e in spans.values():
            if e["parent"] is not None and e["parent"] not in spans:
                errors.append(f"span {e['id']}: dangling parent "
                              f"{e['parent']}")
    return errors


def _check_buckets(agg: dict) -> list[str]:
    """Validate the optional bucket counts on one histogram aggregate —
    absent is fine (v1), present must be NUM_BUCKETS non-negative ints
    summing to the aggregate's count."""
    buckets = agg.get("buckets")
    if buckets is None:
        return []
    if (not isinstance(buckets, list) or len(buckets) != NUM_BUCKETS or
            not all(isinstance(c, int) and c >= 0 for c in buckets)):
        return [f"histogram {agg.get('name', '?')}: buckets must be "
                f"{NUM_BUCKETS} non-negative ints"]
    if sum(buckets) != agg.get("count"):
        return [f"histogram {agg.get('name', '?')}: bucket counts sum to "
                f"{sum(buckets)}, count says {agg.get('count')}"]
    return []


def validate_snapshot(doc: dict) -> list[str]:
    errors: list[str] = []
    if (doc.get("schema") != SCHEMA or
            doc.get("version") not in ACCEPTED_VERSIONS):
        errors.append(f"snapshot schema/version mismatch: "
                      f"{doc.get('schema')!r} v{doc.get('version')!r}")
    for section in ("counters", "gauges", "histograms"):
        block = doc.get(section)
        if not isinstance(block, dict):
            errors.append(f"missing/invalid section {section!r}")
            continue
        for name, series in block.items():
            if not isinstance(series, dict):
                errors.append(f"{section}.{name}: series must be an object")
                continue
            for key, value in series.items():
                if section == "histograms":
                    ok = (isinstance(value, dict) and
                          all(isinstance(value.get(f), (int, float))
                              for f in ("count", "sum", "min", "max")))
                    if ok and _check_buckets({**value, "name": name}):
                        ok = False
                else:
                    ok = isinstance(value, (int, float))
                if not ok:
                    errors.append(f"{section}.{name}[{key!r}]: bad value "
                                  f"{value!r}")
    return errors


def validate_prometheus(text: str) -> list[str]:
    errors: list[str] = []
    typed: set[str] = set()
    for lineno, line in enumerate(text.splitlines(), start=1):
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 3 and parts[1] == "TYPE":
                typed.add(parts[2])
            continue
        m = _PROM_LINE.match(line)
        if m is None:
            errors.append(f"line {lineno}: unparseable sample {line!r}")
            continue
        name = m.group(1)
        if name not in typed:
            # Histogram families type the base name; their samples carry
            # the standard suffixes (plus our _min/_max companion gauges,
            # which get their own TYPE lines — checked here as a fallback
            # so a suffixed sample never needs a second family).
            base = next((name[:-len(s)] for s in
                         ("_bucket", "_sum", "_count", "_min", "_max")
                         if name.endswith(s)), None)
            if base is None or base not in typed:
                errors.append(f"line {lineno}: {name} sample before # TYPE")
        try:
            float(m.group(3))
        except ValueError:
            errors.append(f"line {lineno}: non-numeric value {m.group(3)!r}")
    return errors


def validate_file(path: str, allow_partial: bool = False) -> list[str]:
    if path.endswith(".jsonl"):
        try:
            events = load_events(path, allow_partial=allow_partial)
        except json.JSONDecodeError as e:
            # a torn line is a validation failure in strict mode (a
            # killed writer leaves one; --allow-partial tolerates it)
            return [f"unparseable line: {e}"]
        return validate_events(events, allow_partial=allow_partial)
    if path.endswith(".prom"):
        with open(path) as f:
            return validate_prometheus(f.read())
    with open(path) as f:
        return validate_snapshot(json.load(f))


def main(argv: list[str] | None = None) -> int:
    paths = sys.argv[1:] if argv is None else argv
    allow_partial = "--allow-partial" in paths
    paths = [p for p in paths if p != "--allow-partial"]
    if not paths:
        print("usage: python -m repro.telemetry.check [--allow-partial] "
              "FILE [FILE ...]", file=sys.stderr)
        return 2
    bad = 0
    for path in paths:
        errors = validate_file(path, allow_partial=allow_partial)
        if errors:
            bad += 1
            for err in errors:
                print(f"{path}: {err}", file=sys.stderr)
        else:
            print(f"{path}: OK")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
