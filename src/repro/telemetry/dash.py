"""Live fleet dashboard: a refreshing terminal view of a running fleet.

One :class:`Dashboard` reads everything from the shared
:class:`~repro.telemetry.registry.MetricsRegistry` (the same sink the wire
ledger, the live taps, the serve counters, and the SLO tracker feed), so a
frame is a pure function of registry state plus the sink's tap rate:

  * progress   — live round, rounds seen, rounds/sec, serve taps
  * wire       — in-flight bits, messages by kind, bits by codec rung
  * budget     — skips, exhaustion events, per-link spent-bit gauges
  * latency    — per-tenant p50/p99 from the ``request_seconds`` bucketed
    histogram, plus the cross-tenant merged quantiles
  * SLO        — per-tenant error-budget burn (``repro.telemetry.slo``)
  * serve      — admission outcomes, cache and batch event counters

Hook it to a running program via :meth:`attach` (the LiveSink's
``on_event`` fires it; frames are throttled to ``min_interval``) — that is
what ``--watch`` on the launch drivers does — or render one frame from a
recorded trace::

    python -m repro.telemetry.dash run.jsonl

which accepts the truncated trace a killed run leaves behind (the CI
render smoke).  Rendering never mutates the registry, so watching a run
cannot perturb it — the same zero-interference contract the taps obey.
"""
from __future__ import annotations

import sys
import time

from repro.telemetry.registry import MetricsRegistry

#: codec-rung bar glyph budget (widest bar in the bits-by-rung block)
_BAR = 24


def _fmt_bits(bits: float) -> str:
    """Human wire-bit count: 12_345 -> '12.3 kb' (decimal, it's a rate
    ledger not a memory size)."""
    for unit, div in (("Gb", 1e9), ("Mb", 1e6), ("kb", 1e3)):
        if bits >= div:
            return f"{bits / div:.1f} {unit}"
    return f"{int(bits)} b"


def _fmt_s(seconds: float | None) -> str:
    if seconds is None:
        return "-"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.0f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.1f} ms"
    return f"{seconds:.2f} s"


def _bar(value: float, peak: float) -> str:
    if peak <= 0:
        return ""
    return "#" * max(1, round(_BAR * value / peak)) if value else ""


def render(registry: MetricsRegistry, *, sink=None, title: str = "fleet",
           clock=None) -> str:
    """One dashboard frame as plain text (no ANSI — the watcher adds the
    cursor control).  ``sink`` contributes the tap rate; ``clock`` is the
    frame timestamp (None = unstamped, for deterministic render tests)."""
    reg = registry
    lines = [f"== {title} =="]
    if clock is not None:
        lines[0] += f"  t={clock:.1f}s"

    # ------------------------------------------------------------ progress
    rounds = reg.total("live_rounds_total")
    serve_taps = reg.value("live_serve_requests_total")
    if rounds or serve_taps:
        cur = reg.gauge("live_round")
        rate = sink.rate() if sink is not None else 0.0
        seg = [f"round {int(cur)}" if cur is not None else "round -",
               f"{int(rounds)} seen"]
        if rate > 0:
            seg.append(f"{rate:.1f} taps/s")
        if serve_taps:
            seg.append(f"{int(serve_taps)} serve reqs")
        lines.append("progress   " + "  |  ".join(seg))

    # ---------------------------------------------------------------- wire
    live_bits = reg.total("live_wire_bits_total")
    booked_bits = reg.total("wire_bits_total")
    bits = live_bits or booked_bits
    if bits:
        kinds = {dict(k).get("kind", "?"): v
                 for k, v in reg.series("messages_total").items()}
        live_kinds = {dict(k).get("kind", "?"): v
                      for k, v in reg.series("live_messages_total").items()}
        shown = live_kinds or kinds
        msgs = "  ".join(f"{k}={int(v)}" for k, v in sorted(shown.items()))
        src = "live" if live_bits else "booked"
        lines.append(f"wire       {_fmt_bits(bits)} ({src})  |  {msgs}")
    rungs = reg.series("hops_by_rung_total")
    if rungs:
        peak = max(rungs.values())
        for key, count in rungs.items():
            rung = dict(key).get("rung", "?")
            lines.append(f"  rung {rung:>2}  {int(count):6d} hops  "
                         f"{_bar(count, peak)}")

    # -------------------------------------------------------------- budget
    skips = reg.total("live_budget_skips_total") or \
        reg.total("budget_skips_total")
    exh_events = reg.value("live_exhausted_total")
    exh_gauge = reg.gauge("budget_exhausted")
    spent = reg._gauges.get("budget_link_spent_bits", {})
    if skips or exh_events or exh_gauge or spent:
        state = "EXHAUSTED" if (exh_events or exh_gauge) else "ok"
        lines.append(f"budget     {int(skips)} skips  |  {state}")
        for key, bits_spent in sorted(spent.items()):
            kl = dict(key)
            lines.append(f"  link {kl.get('src', '?')}->"
                         f"{kl.get('dst', '?')}  "
                         f"{_fmt_bits(bits_spent)} spent")

    # ------------------------------------------------------------- latency
    tenants = sorted(
        {dict(k).get("tenant") for k in reg._hists.get("request_seconds", {})}
        - {None})
    if tenants:
        p50 = reg.quantile_all("request_seconds", 0.5)
        p99 = reg.quantile_all("request_seconds", 0.99)
        lines.append(f"latency    all: p50 {_fmt_s(p50)}  "
                     f"p99 {_fmt_s(p99)}")
        for t in tenants:
            p50 = reg.quantile("request_seconds", 0.5, tenant=t)
            p99 = reg.quantile("request_seconds", 0.99, tenant=t)
            n = reg.histogram("request_seconds", tenant=t)["count"]
            row = (f"  {t:<12} p50 {_fmt_s(p50):>9}  "
                   f"p99 {_fmt_s(p99):>9}  n={int(n)}")
            burn = reg.gauge("slo_burn", tenant=t)
            if burn is not None:
                row += (f"  burn {burn:6.2f} "
                        f"{'OK' if burn < 1.0 else 'BLOWN'}")
            lines.append(row)

    # --------------------------------------------------------------- serve
    outcomes = reg.series("admission_outcomes_total")
    if outcomes:
        by_outcome: dict[str, int] = {}
        for key, v in outcomes.items():
            o = dict(key).get("outcome", "?")
            by_outcome[o] = by_outcome.get(o, 0) + int(v)
        lines.append("admission  " + "  ".join(
            f"{o}={v}" for o, v in sorted(by_outcome.items())))
    cache = {dict(k).get("event", "?"): int(v)
             for k, v in reg.series("cache_events_total").items()}
    batch = {dict(k).get("event", "?"): int(v)
             for k, v in reg.series("batch_events_total").items()}
    if cache or batch:
        seg = []
        if cache:
            seg.append("cache " + " ".join(
                f"{k}={v}" for k, v in sorted(cache.items())))
        if batch:
            seg.append("batch " + " ".join(
                f"{k}={v}" for k, v in sorted(batch.items())))
        lines.append("engine     " + "  |  ".join(seg))
    return "\n".join(lines)


class Dashboard:
    """Throttled terminal watcher over one registry + live sink.

    ``attach(sink)`` chains onto the sink's ``on_event`` hook (preserving
    any hook already installed); each accepted event redraws the frame
    in place (ANSI home+clear) at most once per ``min_interval`` seconds.
    ``final()`` force-renders the closing frame — launch drivers call it
    after the run so the last state stays on screen.
    """

    def __init__(self, registry: MetricsRegistry, *, title: str = "fleet",
                 min_interval: float = 0.25, stream=None) -> None:
        self.registry = registry
        self.title = title
        self.min_interval = min_interval
        self.stream = stream if stream is not None else sys.stderr
        self.sink = None
        self.frames = 0
        self._t0 = time.perf_counter()
        self._last_draw: float | None = None
        self._chained = None

    def attach(self, sink) -> "Dashboard":
        self.sink = sink
        self._chained = sink.on_event
        sink.on_event = self._on_event
        return self

    # ------------------------------------------------------------- drawing
    def _on_event(self, event: dict) -> None:
        if self._chained is not None:
            self._chained(event)
        now = time.perf_counter()
        if self._last_draw is not None and \
                now - self._last_draw < self.min_interval:
            return
        self.draw(now)

    def draw(self, now: float | None = None) -> None:
        now = time.perf_counter() if now is None else now
        self._last_draw = now
        self.frames += 1
        frame = render(self.registry, sink=self.sink, title=self.title,
                       clock=now - self._t0)
        # home + clear-below keeps the frame in place without flashing
        self.stream.write("\x1b[H\x1b[J" + frame + "\n")
        self.stream.flush()

    def final(self) -> None:
        """Force-render the closing frame (ignores the throttle)."""
        self.draw()


def main(argv: list[str] | None = None) -> int:
    """Render one dashboard frame from a recorded JSONL trace (accepts
    the truncated trace a killed run left behind) — the CI render smoke
    and the post-hoc view of any ``--trace`` artifact."""
    args = sys.argv[1:] if argv is None else argv
    if not args or len(args) != 1:
        print("usage: python -m repro.telemetry.dash TRACE.jsonl",
              file=sys.stderr)
        return 2
    from repro.telemetry.export import load_events
    events = load_events(args[0], allow_partial=True)
    registry = MetricsRegistry.from_events(
        [e for e in events if e.get("type") in
         ("counter", "gauge", "histogram")])
    live = [e for e in events if e.get("type") == "live"]
    # a killed run's trace has live events but no sealed registry block:
    # fold the live stream back into registry series so the frame still
    # shows progress (sums are commutative, same arithmetic as the sink)
    if live and not registry.counter_names():
        for e in live:
            if e.get("tag") == "round":
                registry.inc("live_rounds_total", 1)
                registry.inc("live_wire_bits_total", e.get("bits", 0))
                registry.inc("live_budget_skips_total", e.get("skipped", 0))
                registry.inc("live_exhausted_total", e.get("exhausted", 0))
                cur = registry.gauge("live_round")
                registry.set_gauge("live_round",
                                   max(e.get("t", 0),
                                       cur if cur is not None else -1))
            elif e.get("tag") == "serve":
                registry.inc("live_serve_requests_total", 1)
                registry.inc("live_wire_bits_total", e.get("bits", 0))
    frame = render(registry, title=args[0])
    print(frame)
    if live:
        span = live[-1].get("t_s", 0.0)
        print(f"-- {len(live)} live events over {span:.1f}s --")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
