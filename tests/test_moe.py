"""MoE: grouped-matmul (ragged_dot) impl vs dense oracle, router invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models.moe import moe_apply, moe_init, router_topk


def _cfg(**kw):
    base = dict(name="t", arch_type="moe", num_layers=1, d_model=32,
                num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                vocab_size=128, num_experts=4, top_k=2, moe_d_ff=48,
                dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


@pytest.mark.parametrize("e,k", [(4, 2), (8, 1), (8, 3)])
def test_gmm_matches_dense(e, k):
    cfg = _cfg(num_experts=e, top_k=k)
    key = jax.random.key(0)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y_dense, aux_d = moe_apply(params, x, cfg, impl="dense")
    y_gmm, aux_g = moe_apply(params, x, cfg, impl="gmm")
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_gmm),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_g), rtol=1e-6)


def test_router_topk_normalized():
    cfg = _cfg()
    key = jax.random.key(0)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 2), (16, cfg.d_model))
    probs, idx, aux = router_topk(params, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(probs, -1)), 1.0,
                               rtol=1e-5)
    assert idx.shape == (16, cfg.top_k)
    # distinct experts per token
    assert all(len(set(row.tolist())) == cfg.top_k for row in np.asarray(idx))
    # aux loss >= 1 (Switch load-balance loss is minimized at 1.0)
    assert float(aux) >= 1.0 - 1e-5


def test_gmm_grad_finite():
    cfg = _cfg()
    key = jax.random.key(0)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(p, x, cfg, impl="gmm")
        return jnp.sum(jnp.square(y)) + 0.01 * aux

    grads = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # expert weights receive gradient
    assert float(jnp.max(jnp.abs(grads["wi_gate"]))) > 0
