"""Learner zoo: weighted fits respect ignorance weights (Prop. 1 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.learners.forest import RandomForest
from repro.learners.logistic import LogisticRegression
from repro.learners.mlp import MLP
from repro.learners.tree import DecisionTree


def _separable(key, n=200, k=3, p=4):
    centers = jax.random.normal(key, (k, p)) * 6
    c = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, k)
    X = centers[c] + jax.random.normal(jax.random.fold_in(key, 2), (n, p))
    return X, c.astype(jnp.int32)


LEARNERS = {
    "tree": DecisionTree(depth=3, num_thresholds=8),
    "forest": RandomForest(num_trees=4, depth=3, num_thresholds=8),
    "logistic": LogisticRegression(steps=150),
    "mlp": MLP(hidden=(32, 16), steps=150),
}


@pytest.mark.parametrize("name", list(LEARNERS))
def test_fit_separable(name, key):
    X, c = _separable(key)
    learner = LEARNERS[name]
    params = learner.fit(key, X, c, jnp.full((len(c),), 1.0 / len(c)), 3)
    acc = float(jnp.mean(learner.predict(params, X) == c))
    assert acc > 0.9, (name, acc)


@pytest.mark.parametrize("name", ["tree", "logistic", "mlp"])
def test_weights_steer_fit(name, key):
    """Concentrating ignorance on a subset makes the learner fit it."""
    # two interleaved groups that a depth-1 split can't both satisfy
    n = 100
    X = jnp.concatenate([jnp.linspace(-1, 0, n)[:, None],
                         jnp.linspace(0, 1, n)[:, None]])
    c = jnp.concatenate([jnp.zeros(n), jnp.ones(n)]).astype(jnp.int32)
    c = c.at[:10].set(1)     # conflicting head segment
    learner = LEARNERS[name]
    w_uniform = jnp.full((2 * n,), 1.0 / (2 * n))
    w_head = jnp.zeros((2 * n,)).at[:10].set(0.1)
    p_u = learner.fit(key, X, c, w_uniform, 2)
    p_h = learner.fit(key, X, c, w_head, 2)
    r = learner.reward(p_h, X, c)
    r_u = learner.reward(p_u, X, c)
    # weighted accuracy on the emphasized head must improve
    assert float(jnp.mean(r[:10])) >= float(jnp.mean(r_u[:10]))


def test_tree_reward_is_binary(key):
    X, c = _separable(key)
    t = LEARNERS["tree"]
    params = t.fit(key, X, c, jnp.full((len(c),), 1.0 / len(c)), 3)
    r = t.reward(params, X, c)
    assert set(np.unique(np.asarray(r))).issubset({0.0, 1.0})


@given(st.integers(2, 5), st.integers(2, 6))
@settings(max_examples=6, deadline=None)
def test_tree_predictions_in_range(depth, k):
    key = jax.random.key(depth * 10 + k)
    X, c = _separable(key, n=80, k=k)
    t = DecisionTree(depth=depth, num_thresholds=4)
    params = t.fit(key, X, c, jnp.full((80,), 1 / 80), k)
    pred = np.asarray(t.predict(params, X))
    assert pred.min() >= 0 and pred.max() < k
