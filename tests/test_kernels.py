"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


class TestWeightedCE:
    @pytest.mark.parametrize("t,v", [(128, 512), (256, 1024), (64, 2048)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_forward(self, t, v, dtype, key):
        logits = (jax.random.normal(key, (t, v)) * 4).astype(dtype)
        labels = jax.random.randint(key, (t,), 0, v)
        w = jax.random.uniform(key, (t,))
        loss = ops.weighted_ce(logits, labels, w)
        loss_ref, _ = ref.weighted_ce(logits, labels, w)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(loss), np.asarray(loss_ref),
                                   rtol=tol, atol=tol)

    def test_backward(self, key):
        t, v = 128, 512
        logits = jax.random.normal(key, (t, v)) * 3
        labels = jax.random.randint(key, (t,), 0, v)
        w = jax.random.uniform(key, (t,))
        g = jax.grad(lambda l: jnp.sum(ops.weighted_ce(l, labels, w) * 2.0)
                     )(logits)
        g_ref = jax.grad(lambda l: jnp.sum(ref.weighted_ce(l, labels, w)[0]
                                           * 2.0))(logits)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-5)

    def test_zero_weight_zero_loss_and_grad(self, key):
        t, v = 128, 512
        logits = jax.random.normal(key, (t, v))
        labels = jax.random.randint(key, (t,), 0, v)
        w = jnp.zeros((t,))
        assert float(jnp.max(jnp.abs(ops.weighted_ce(logits, labels, w)))) == 0
        g = jax.grad(lambda l: ops.weighted_ce(l, labels, w).sum())(logits)
        assert float(jnp.max(jnp.abs(g))) == 0


class TestFlashAttention:
    @pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize("window", [None, 64])
    def test_vs_ref(self, h, kv, window, key):
        b, s, d = 2, 256, 32
        q = jax.random.normal(key, (b, h, s, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, d))
        out = ops.flash_attention(q, k, v, causal=True, window=window)
        out_ref = ref.flash_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=2e-5, atol=2e-5)

    @given(st.sampled_from([128, 256]), st.sampled_from([32, 64]),
           st.sampled_from([None, 128]))
    @settings(max_examples=6, deadline=None)
    def test_property_sweep(self, s, d, window):
        key = jax.random.key(s + d)
        q = jax.random.normal(key, (1, 2, s, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, s, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, s, d))
        out = ops.flash_attention(q, k, v, window=window)
        out_ref = ref.flash_attention(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=3e-5, atol=3e-5)

    def test_bf16(self, key):
        b, h, s, d = 1, 2, 128, 64
        q = jax.random.normal(key, (b, h, s, d)).astype(jnp.bfloat16)
        k = jax.random.normal(jax.random.fold_in(key, 1),
                              (b, h, s, d)).astype(jnp.bfloat16)
        v = jax.random.normal(jax.random.fold_in(key, 2),
                              (b, h, s, d)).astype(jnp.bfloat16)
        out = ops.flash_attention(q, k, v)
        out_ref = ref.flash_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(out_ref, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestIgnorance:
    @given(st.sampled_from([1024, 4096]), st.floats(0.0, 4.0))
    @settings(max_examples=10, deadline=None)
    def test_vs_ref(self, n, alpha):
        key = jax.random.key(n)
        w = jax.random.dirichlet(key, jnp.ones(n))
        r = (jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > 0.3
             ).astype(jnp.float32)
        out = ops.ignorance_update(w, r, jnp.asarray(alpha))
        out_ref = ref.ignorance_update(w, r, jnp.asarray(alpha))
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=1e-5, atol=1e-7)
        assert abs(float(jnp.sum(out)) - 1.0) < 1e-5


class TestFlashDecode:
    @pytest.mark.parametrize("window", [None, 128])
    @pytest.mark.parametrize("h,kv", [(4, 4), (8, 2)])
    def test_fp_vs_ref(self, h, kv, window, key):
        b, s, d = 2, 512, 64
        q = jax.random.normal(key, (b, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, d))
        pos = jnp.asarray(300, jnp.int32)
        out = ops.flash_decode(q, k, v, pos, window=window)
        out_ref = ref.flash_decode(q, k, v, pos, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=2e-5, atol=2e-5)

    def test_int8_fused_dequant(self, key):
        from repro.models.attention import quantize_kv
        b, h, kv, s, d = 1, 4, 2, 256, 32
        q = jax.random.normal(key, (b, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, d))
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        pos = jnp.asarray(200, jnp.int32)
        out = ops.flash_decode(q, kq, vq, pos, k_scale=ks, v_scale=vs)
        out_ref = ref.flash_decode(q, kq, vq, pos, k_scale=ks, v_scale=vs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=2e-5, atol=2e-5)
        # int8 path close to the fp oracle
        fp = ref.flash_decode(q, k, v, pos)
        rel = float(jnp.max(jnp.abs(out - fp)) / (jnp.max(jnp.abs(fp)) + 1e-9))
        assert rel < 0.05

    @given(st.integers(0, 255), st.sampled_from([None, 64]))
    @settings(max_examples=6, deadline=None)
    def test_position_sweep(self, pos, window):
        key = jax.random.key(pos)
        b, h, s, d = 1, 2, 256, 32
        q = jax.random.normal(key, (b, h, d))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, h, s, d))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, h, s, d))
        p = jnp.asarray(pos, jnp.int32)
        out = ops.flash_decode(q, k, v, p, window=window)
        out_ref = ref.flash_decode(q, k, v, p, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                                   rtol=3e-5, atol=3e-5)
