"""Serve engine: continuous batching, resident cache, admission control.

The defining pin: a request served through the batched engine is
bit-identical to the same request served alone via
``Protocol.predict_distributed(Xs, request=rid)`` — predictions, booked
wire bits, and accountant releases.  Plus: budgeted same-session requests
serialize across batching waves exactly like sequential serving; a session
evicted to checkpoint spill and restored serves identically to one that
stayed resident; per-tenant admission denies/degrades BEFORE any work and
the counters add up; and the serve-path adaptive controller stays
eager == compiled.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.control import ServeController
from repro.core import compiled
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.serve import (ACCEPT, DEGRADE, DENY, AdmissionController,
                         AdmissionPolicy, Batcher, ServeEngine, Slot)
from repro.serve.cache import ServeSessionState, SessionCache


@pytest.fixture(scope="module")
def blob():
    ds = blob_fig3(jax.random.key(0), n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr], [x[te] for x in Xs],
            ds.num_classes)


def _fit(blob, make_transport, seed=11, rounds=2, steps=30,
         backend="compiled"):
    Xtr, ctr, _, k = blob
    transport = make_transport()
    proto = Protocol(SessionConfig(num_classes=k, max_rounds=rounds),
                     transport=transport, backend=backend)
    proto.fit(jax.random.key(seed),
              endpoints_for([LogisticRegression(steps=steps)
                             for _ in Xtr], Xtr), ctr)
    return proto, transport


def _requests(blob, sessions, count, block_n=16, seed=7):
    _, _, Xte, _ = blob
    rng = np.random.default_rng(seed)
    n = int(Xte[0].shape[0])
    out = []
    for _ in range(count):
        sid = sessions[rng.integers(len(sessions))]
        rows = rng.choice(n, size=block_n, replace=False)
        out.append((sid, tuple(jnp.asarray(np.asarray(x)[rows])
                               for x in Xte)))
    return out


@pytest.fixture(scope="module")
def fleet(blob):
    """Three fitted DP+codec sessions sharing one plan (compiles once)."""
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    protos = {
        f"s{i}": _fit(blob, lambda: MeteredTransport(
            serve_codec=make_codec("int8"), privacy=mech), seed=20 + i)
        for i in range(3)}
    return protos, mech


# ================================================= the batched-parity pin
def test_batched_bit_identical_to_per_request(blob, fleet):
    """Engine-served preds, wire bits, and DP releases match the standalone
    ``predict_distributed(request=rid)`` path for every request."""
    protos, _ = fleet
    engine = ServeEngine(cache_capacity=3, max_batch=4)
    for sid, (proto, _) in protos.items():
        engine.add_session(sid, proto)       # snapshot BEFORE baselines run
    reqs = _requests(blob, list(protos), 10)
    for rid, (sid, Xblk) in enumerate(reqs):
        engine.submit("t0", sid, Xblk, request=rid)
        if (rid + 1) % 4 == 0:
            engine.flush()
    engine.flush()

    for rid, (sid, Xblk) in enumerate(reqs):
        proto, transport = protos[sid]
        n_before = len(transport.log.entries)
        rel_before = dict(transport.accountant.releases)
        base = proto.predict_distributed(Xblk, request=rid)
        out = engine.outcomes[rid]
        np.testing.assert_array_equal(out.preds, np.asarray(base))
        # the standalone path booked the same score_block bits the engine
        # charged this request
        new = transport.log.entries[n_before:]
        assert all(e["kind"] == "score_block" for e in new)
        assert out.bits == sum(e["bits"] for e in new) > 0
        # and the same number of DP releases
        rel_delta = sum(transport.accountant.releases.get(a, 0)
                        - rel_before.get(a, 0)
                        for a in transport.accountant.releases)
        assert out.releases == rel_delta == len(new)

    # fleet-wide ledgers: engine log carries session-prefixed endpoints and
    # per-session accountants composed exactly the served releases
    assert engine.log.total_bits == sum(
        o.bits for o in engine.outcomes.values())
    for sid, (proto, transport) in protos.items():
        meta = engine.sessions[sid]
        served = meta.served
        assert all(v == served for v in meta.accountant.releases.values())
    assert engine.batcher.batches_run < len(reqs)   # it actually batched
    engine.close()


def test_batched_budget_waves_match_sequential(blob):
    """Same-session requests queued in ONE flush serialize across batching
    waves: preds, per-request bits, skips, and exhaustion match serving the
    requests one at a time against the budgeted session."""
    spec = BudgetSpec(session_bits=26_000)
    proto, transport = _fit(blob, lambda: BudgetedTransport(spec))
    engine = ServeEngine(cache_capacity=1, max_batch=8)
    engine.add_session("s0", proto)
    reqs = _requests(blob, ["s0"], 6)
    for rid, (sid, Xblk) in enumerate(reqs):
        engine.submit("t0", sid, Xblk, request=rid)
    engine.flush()                          # one flush -> 6 serialized waves

    skips_before = 0
    for rid, (sid, Xblk) in enumerate(reqs):
        n_before = len(transport.log.entries)
        base = proto.predict_distributed(Xblk, request=rid)
        out = engine.outcomes[rid]
        np.testing.assert_array_equal(out.preds, np.asarray(base))
        booked = sum(e["bits"] for e in transport.log.entries[n_before:])
        assert out.bits == booked
    # the ladder ran dry at the same point on both paths
    meta = engine.sessions["s0"]
    assert len(meta.skipped) > 0            # budget actually bit
    assert meta.exhausted == transport.exhausted
    # and the cached counters came out where the live transport's did
    state = engine.cache.get("s0")
    remaining = spec.session_bits - transport.log.total_bits
    assert int(np.asarray(state.rem_session)) == remaining
    engine.close()


# ============================================= spill/restore bit-exactness
def test_evicted_session_serves_bit_identically(blob, fleet):
    """Memory pressure: a session spilled to checkpoint and restored must
    produce bit-identical predictions, ledger, and accountant state."""
    protos, _ = fleet
    resident = ServeEngine(cache_capacity=3, max_batch=4)
    pressured = ServeEngine(cache_capacity=1, max_batch=4)
    for sid, (proto, _) in protos.items():
        resident.add_session(sid, proto)
        pressured.add_session(sid, proto)
    reqs = _requests(blob, list(protos), 9, seed=13)
    for rid, (sid, Xblk) in enumerate(reqs):
        resident.submit("t0", sid, Xblk, request=rid)
        pressured.submit("t0", sid, Xblk, request=rid)
        if rid % 2 == 0:
            resident.flush()
            pressured.flush()
            for s in list(pressured.cache.resident_ids):
                pressured.cache.evict(s)    # force every session out
    resident.flush()
    pressured.flush()

    assert pressured.cache.stats()["spills"] > 0
    assert pressured.cache.stats()["restores"] > 0
    for rid in range(len(reqs)):
        a, b = resident.outcomes[rid], pressured.outcomes[rid]
        np.testing.assert_array_equal(a.preds, b.preds)
        assert (a.bits, a.releases) == (b.bits, b.releases)
    for sid in protos:
        assert (resident.sessions[sid].accountant.releases
                == pressured.sessions[sid].accountant.releases)
    assert resident.log.total_bits == pressured.log.total_bits
    resident.close()
    pressured.close()


def test_cache_spill_roundtrip_exact(tmp_path):
    cache = SessionCache(1, str(tmp_path))
    mk = lambda v: ServeSessionState(
        params=(jnp.arange(4.0) * v,), alphas=jnp.ones(3) * v,
        valid=jnp.array([True, True, False]),
        key_data=jax.random.key_data(jax.random.key(int(v))),
        rem_session=jnp.asarray(1000 + int(v), jnp.int32),
        rem_link=jnp.asarray([7, 8, 9], jnp.int32))
    cache.put("a", mk(1.0))
    cache.put("b", mk(2.0))                 # evicts a
    assert cache.resident_ids == ("b",)
    a = cache.get("a")                      # restore from spill
    np.testing.assert_array_equal(np.asarray(a.params[0]),
                                  np.arange(4.0))
    np.testing.assert_array_equal(
        np.asarray(a.key_data),
        np.asarray(jax.random.key_data(jax.random.key(1))))
    assert int(a.rem_session) == 1001
    assert cache.stats()["spills"] >= 1
    assert cache.stats()["restores"] == 1
    with pytest.raises(KeyError):
        cache.get("never-put")


# ====================================================== admission control
def test_admission_deny_degrade_and_counters(blob, fleet):
    """Per-tenant gating happens BEFORE any work: an unaffordable request
    degrades to head-only (books zero wire bits, zero releases) or is
    denied outright under no-degrade; counters add up."""
    protos, mech = fleet
    proto, _ = protos["s0"]
    endpoints, plan, _ = proto._compiled_ctx
    shape = (16, plan.num_classes)
    full = int(plan.serve_ladder[0].wire_bits(shape)) * (len(endpoints) - 1)
    cap_bits = int(full * 1.5)              # one full request fits, not two
    engine = ServeEngine(
        cache_capacity=2, max_batch=4,
        admission=AdmissionController(
            AdmissionPolicy(allow_degrade=True),
            tenant_bits=cap_bits, mechanism=mech))
    engine.add_session("s0", proto)
    reqs = _requests(blob, ["s0"], 4, seed=3)
    decisions = [engine.submit("poor", sid, X, request=r)[1]
                 for r, (sid, X) in enumerate(reqs)]
    engine.flush()
    outcomes = [d.outcome for d in decisions]
    assert outcomes[0] == ACCEPT
    assert DEGRADE in outcomes              # the cap bit mid-stream
    first_deg = outcomes.index(DEGRADE)
    assert all(o == DEGRADE for o in outcomes[first_deg:])
    for rid, o in enumerate(outcomes):
        out = engine.outcomes[rid]
        assert out.preds is not None        # degraded still answers
        if o == DEGRADE:
            assert out.bits == 0 and out.releases == 0
    c = engine.admission.counters()["poor"]
    assert c["served"] == outcomes.count(ACCEPT)
    assert c["degraded"] == outcomes.count(DEGRADE)
    assert c["denied"] == 0
    assert c["bits"] <= cap_bits
    engine.close()

    deny = ServeEngine(
        cache_capacity=2, max_batch=4,
        admission=AdmissionController(
            AdmissionPolicy(allow_degrade=False), tenant_bits=1))
    deny.add_session("s0", proto)
    _, d = deny.submit("poor", "s0", reqs[0][1], request=0)
    assert d.outcome == DENY
    assert deny.outcomes[0].preds is None   # completed at submit, no work
    assert len(deny.batcher) == 0
    assert deny.admission.counters()["poor"]["denied"] == 1
    deny.close()


def test_admission_epsilon_cap(blob, fleet):
    """The (epsilon, delta) ledger gates too: once a tenant's composed
    epsilon would exceed the cap, its requests stop shipping DP blocks."""
    protos, mech = fleet
    proto, _ = protos["s1"]
    m = len(proto._compiled_ctx[0])
    # cap allows exactly one full request's (M-1) releases, not two
    cap = mech.epsilon * (m - 1) * 1.5
    engine = ServeEngine(
        cache_capacity=2, max_batch=4,
        admission=AdmissionController(
            AdmissionPolicy(allow_degrade=True, epsilon_cap=cap),
            mechanism=mech))
    engine.add_session("s1", proto)
    reqs = _requests(blob, ["s1"], 2, seed=5)
    d0 = engine.submit("tA", "s1", reqs[0][1], request=0)[1]
    engine.flush()
    d1 = engine.submit("tA", "s1", reqs[1][1], request=1)[1]
    engine.flush()
    assert (d0.outcome, d1.outcome) == (ACCEPT, DEGRADE)
    assert engine.outcomes[1].releases == 0
    assert "epsilon" in d1.reason
    engine.close()


# ==================================== serve_batch primitive + the batcher
def test_serve_batch_matches_serve_session_per_slot(blob, fleet):
    """The vmap axis never mixes slots: each batched slot equals the same
    serve_session call alone, and all-False deliver pads contribute
    nothing."""
    protos, _ = fleet
    proto, _ = protos["s2"]
    _, plan, result = proto._compiled_ctx
    evolved = proto._evolved_key(result)
    reqs = _requests(blob, ["s2"], 3, seed=9)
    num = plan.num_agents
    big = np.iinfo(np.int32).max

    from repro.comm.codecs import serve_key
    slots = [{"key": serve_key(evolved, rid), "Xs": Xblk,
              "params": result.params, "alphas": result.alphas,
              "valid": result.valid,
              "rem_session": jnp.asarray(big, jnp.int32),
              "rem_link": jnp.asarray([big] * num, jnp.int32),
              "deliver": np.ones(num, bool)}
             for rid, (_, Xblk) in enumerate(reqs)]
    batched = compiled.serve_batch(plan, slots)
    for rid, (_, Xblk) in enumerate(reqs):
        alone = compiled.serve_session(plan, result,
                                       serve_key(evolved, rid), Xblk)
        np.testing.assert_array_equal(np.asarray(batched.preds[rid]),
                                      np.asarray(alone.preds))
        np.testing.assert_array_equal(np.asarray(batched.blocks[rid]),
                                      np.asarray(alone.blocks))
        np.testing.assert_array_equal(np.asarray(batched.sent[rid]),
                                      np.asarray(alone.sent))

    # padding through the Batcher: 3 slots pad to 4, results unaffected
    batcher = Batcher(max_batch=4)
    for rid, (_, Xblk) in enumerate(reqs):
        batcher.add(Slot(
            request_id=rid, session_id=f"sess{rid}", tenant="t", plan=plan,
            key=slots[rid]["key"], Xs=Xblk, deliver=np.ones(num, bool),
            state=ServeSessionState(
                params=result.params, alphas=result.alphas,
                valid=result.valid, key_data=jax.random.key_data(evolved),
                rem_session=jnp.asarray(big, jnp.int32),
                rem_link=jnp.asarray([big] * num, jnp.int32))))
    out = batcher.flush()
    assert batcher.stats()["padded_slots"] == 1
    for slot, res in out:
        np.testing.assert_array_equal(
            res.preds, np.asarray(batched.preds[slot.request_id]))


# ================================== serve-path adaptive controller parity
@pytest.mark.parametrize("stat", ["margin", "entropy"])
def test_serve_controller_eager_matches_compiled(blob, stat):
    """Satellite pin: ServeController picks the same rung per block on both
    backends — identical preds, ledger entries, accountant releases."""
    _, _, Xte, _ = blob
    ctl = ServeController(stat=stat)
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    runs = {}
    for backend in ("eager", "compiled"):
        proto, transport = _fit(
            blob, lambda: MeteredTransport(serve_controller=ctl,
                                           privacy=mech),
            backend=backend)
        preds = proto.predict_distributed(Xte)
        runs[backend] = (np.asarray(preds), transport)
    pe, te = runs["eager"]
    pc, tc = runs["compiled"]
    np.testing.assert_array_equal(pe, pc)
    assert te.log.entries == tc.log.entries
    assert te.accountant.releases == tc.accountant.releases
    blocks = [e for e in te.log.entries if e["kind"] == "score_block"]
    # the controller picked a real ladder rung (encoded, below raw fp32)
    shape = (Xte[0].shape[0], blob[3])
    assert blocks and all(e["bits"] < 32 * shape[0] * shape[1]
                          for e in blocks)


def test_serve_controller_respects_budget_floor(blob):
    """With both a budget ladder and a controller, the shipped rung is
    never finer than what the remaining budget affords — and both backends
    agree."""
    ctl = ServeController(stat="margin")
    spec = BudgetSpec(session_bits=24_000)
    _, _, Xte, _ = blob
    runs = {}
    for backend in ("eager", "compiled"):
        proto, transport = _fit(
            blob, lambda: BudgetedTransport(spec, serve_controller=ctl),
            backend=backend)
        p1 = np.asarray(proto.predict_distributed(Xte))
        p2 = np.asarray(proto.predict_distributed(Xte))
        runs[backend] = (p1, p2, transport)
    assert runs["eager"][2].log.entries == runs["compiled"][2].log.entries
    np.testing.assert_array_equal(runs["eager"][0], runs["compiled"][0])
    np.testing.assert_array_equal(runs["eager"][1], runs["compiled"][1])


# ======================================================== engine plumbing
def test_engine_rejects_unfit_and_duplicate(blob, fleet):
    protos, _ = fleet
    proto, _ = protos["s0"]
    engine = ServeEngine(cache_capacity=2)
    engine.add_session("s0", proto)
    with pytest.raises(ValueError, match="already registered"):
        engine.add_session("s0", proto)
    eager, _ = _fit(blob, MeteredTransport, backend="eager", rounds=1,
                    steps=5)
    with pytest.raises(ValueError, match="compiled"):
        engine.add_session("e0", eager)
    with pytest.raises(KeyError):
        engine.submit("t", "missing", [jnp.ones((4, 2))] * 3)
    engine.close()


def test_summary_schema(blob, fleet):
    protos, _ = fleet
    engine = ServeEngine(cache_capacity=2, max_batch=4)
    for sid, (proto, _) in protos.items():
        engine.add_session(sid, proto)
    for rid, (sid, Xblk) in enumerate(_requests(blob, list(protos), 5)):
        engine.submit(f"t{rid % 2}", sid, Xblk, request=rid)
    engine.flush()
    s = engine.summary()
    assert set(s) == {"tenants", "cache", "batcher", "sessions",
                      "total_bits", "requests"}
    assert s["requests"] == 5
    assert sum(t["served"] for t in s["tenants"].values()) == 5
    assert s["batcher"]["slots_run"] == 5
    engine.close()
