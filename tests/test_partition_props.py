"""Property tests (hypothesis): the non-IID horizontal partitioners behind
the scenario engine's ``partition`` knob.  The contracts every scenario
relies on: shards cover range(n) exactly once, every shard is nonempty when
the roster fits, the draw is a pure function of the seed, and the skew
knobs move imbalance monotonically in the documented direction."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.partition import (dirichlet_label_partition,
                                  quantity_partition, quantity_proportions)
from repro.scenarios import Scenario

SEEDS = st.integers(0, 2 ** 31 - 1)
AGENTS = st.integers(1, 12)


def _classes(seed, n, k):
    return np.random.default_rng(seed ^ 0xC1A55).integers(0, k, size=n)


def _assert_exact_cover(shards, n):
    flat = np.concatenate(shards) if shards else np.array([], np.int64)
    assert flat.size == n
    np.testing.assert_array_equal(np.sort(flat), np.arange(n))


# ================================================================ dirichlet
@given(seed=SEEDS, num_agents=AGENTS, n=st.integers(12, 200),
       k=st.integers(2, 8), alpha=st.floats(0.05, 10.0))
@settings(max_examples=40, deadline=None)
def test_dirichlet_exact_cover_nonempty_deterministic(seed, num_agents, n,
                                                      k, alpha):
    classes = _classes(seed, n, k)
    shards = dirichlet_label_partition(seed, classes, num_agents,
                                       alpha=alpha)
    _assert_exact_cover(shards, n)
    assert all(s.size >= 1 for s in shards)
    replay = dirichlet_label_partition(seed, classes, num_agents,
                                       alpha=alpha)
    for a, b in zip(shards, replay):
        np.testing.assert_array_equal(a, b)


@given(seed=SEEDS, num_agents=st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_dirichlet_small_alpha_concentrates_labels(seed, num_agents):
    """Pathological alpha puts each class (mostly) on few agents; near-IID
    alpha spreads it — measured as the mean per-class max-agent share,
    averaged over classes (1.0 = fully concentrated, 1/M = uniform)."""
    n, k = 400, 4
    classes = _classes(seed, n, k)

    def concentration(alpha):
        shards = dirichlet_label_partition(seed, classes, num_agents,
                                           alpha=alpha)
        shares = []
        for c in range(k):
            per_agent = np.array(
                [np.sum(classes[s] == c) for s in shards], np.float64)
            if per_agent.sum() > 0:
                shares.append(per_agent.max() / per_agent.sum())
        return float(np.mean(shares))

    assert concentration(0.05) >= concentration(100.0) - 0.05


# ================================================================= quantity
@given(seed=SEEDS, num_agents=AGENTS, n=st.integers(12, 200),
       skew=st.floats(0.0, 4.0))
@settings(max_examples=40, deadline=None)
def test_quantity_exact_cover_nonempty_deterministic(seed, num_agents, n,
                                                     skew):
    shards = quantity_partition(seed, n, num_agents, skew=skew)
    _assert_exact_cover(shards, n)
    assert all(s.size >= 1 for s in shards)
    replay = quantity_partition(seed, n, num_agents, skew=skew)
    for a, b in zip(shards, replay):
        np.testing.assert_array_equal(a, b)


@given(num_agents=st.integers(2, 12),
       skews=st.lists(st.floats(0.0, 4.0), min_size=2, max_size=6,
                      unique=True))
@settings(max_examples=40, deadline=None)
def test_quantity_spread_monotone_in_skew(num_agents, skews):
    """max/min proportion = num_agents^skew: strictly increasing in skew,
    uniform at skew = 0 — the deterministic imbalance contract."""
    skews = sorted(skews)
    spreads = []
    for skew in skews:
        p = quantity_proportions(num_agents, skew)
        assert p.sum() == pytest.approx(1.0)
        assert (np.diff(p) <= 1e-15).all()      # largest agent first
        spreads.append(p.max() / p.min())
        assert spreads[-1] == pytest.approx(num_agents ** skew)
    assert all(b > a or b == pytest.approx(a)
               for a, b in zip(spreads, spreads[1:]))


def test_quantity_uniform_at_zero_skew():
    p = quantity_proportions(7, 0.0)
    np.testing.assert_allclose(p, np.full(7, 1 / 7))


# =========================================== scenario shard-weight glue
@given(seed=SEEDS, num_agents=st.integers(2, 6),
       part=st.sampled_from(["dirichlet", "quantity"]))
@settings(max_examples=20, deadline=None)
def test_scenario_shard_weights_partition_rows(seed, num_agents, part):
    """The [M, n] fit-weight masks the engine consumes are exactly the
    partition: each column (sample) active for exactly one agent."""
    n = 80
    classes = _classes(seed, n, 4)
    sc = Scenario("p", partition=part, skew=0.7, seed=seed)
    masks = np.asarray(sc.shard_weights(classes, num_agents))
    assert masks.shape == (num_agents, n)
    np.testing.assert_array_equal(masks.sum(axis=0), np.ones(n))
    assert set(np.unique(masks)) <= {0.0, 1.0}
