"""Mesh-native ring interchange vs the host-side reference (subprocess:
needs >1 placeholder device)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.collectives import make_ring_interchange
    from repro.core import scores

    mesh = jax.make_mesh((4, 2), ("agent", "data"))
    M, n = 4, 64
    key = jax.random.key(0)
    w = jax.random.dirichlet(key, jnp.ones(n))
    ws = jnp.tile(w[None], (M, 1))
    r = (jax.random.uniform(jax.random.fold_in(key, 1), (M, n)) > 0.4
         ).astype(jnp.float32)
    alpha = jnp.asarray([0.5, 1.0, 1.5, 2.0])
    step = make_ring_interchange(mesh)
    out = step(ws, r, alpha)
    # reference: each agent updates its replica, then ring-shifts
    ref = jnp.stack([scores.ignorance_update(ws[m], r[m], alpha[m])
                     for m in range(M)])
    ref = jnp.roll(ref, 1, axis=0)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-6, err
    print("RING_OK", err)
""")


@pytest.mark.slow
def test_ring_interchange_matches_reference():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                         text=True, env=env, timeout=560)
    assert "RING_OK" in out.stdout, out.stdout + out.stderr
