"""Property tests (hypothesis): the three implementations of the eqs.
(10)/(12) interchange update agree across dtypes, shapes, and edge cases —
the pure-jnp surrogate (`scores.ignorance_update`), the beyond-paper exact
exponential-loss reweight (`scores.ignorance_update_exact`, equal to the
surrogate at the rescaled alpha' = alpha * K/(K-1)^2), and the fused Pallas
kernel (`kernels.ignorance.ignorance_update_unnormalized`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import scores
from repro.kernels import ops
from repro.kernels.ignorance import ignorance_update_unnormalized

# n values exercise: sub-tile, one exact tile, multi-tile (bn = 1024)
SHAPES = st.sampled_from([4, 64, 257, 1024, 2048])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])
ALPHAS = st.floats(0.0, 8.0)


def _wr(n, dtype, seed):
    key = jax.random.key(seed)
    w = jax.random.dirichlet(key, jnp.ones(n)).astype(dtype)
    r = (jax.random.uniform(jax.random.fold_in(key, 1), (n,)) > 0.4
         ).astype(dtype)
    return w, r


@given(n=SHAPES, alpha=ALPHAS, dtype=DTYPES, k=st.integers(2, 10))
@settings(max_examples=25, deadline=None)
def test_exact_reweight_is_rescaled_surrogate(n, alpha, dtype, k):
    """After normalization the exact exponential-loss reweight equals the
    SAMME-style surrogate at alpha' = alpha * K/(K-1)^2 (the per-round
    constant exp(-alpha/(K-1)) cancels)."""
    w, r = _wr(n, dtype, n + k)
    a = jnp.asarray(alpha, jnp.float32)
    exact = scores.ignorance_update_exact(w, r, a, k)
    rescaled = scores.ignorance_update(w, r, a * k / (k - 1) ** 2)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(exact, np.float32),
                               np.asarray(rescaled, np.float32),
                               rtol=tol, atol=tol)


@given(n=SHAPES, alpha=ALPHAS, dtype=DTYPES)
@settings(max_examples=25, deadline=None)
def test_kernel_matches_host_formula(n, alpha, dtype):
    """The fused Pallas kernel (unnormalized + per-tile partial sums) equals
    the host formula for every tiling regime and input dtype."""
    w, r = _wr(n, dtype, n + 1)
    a = jnp.asarray(alpha, jnp.float32)
    host = scores.ignorance_update(w.astype(jnp.float32),
                                   r.astype(jnp.float32), a)
    fused = ops.ignorance_update(w, r, a)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(host),
                               rtol=1e-6, atol=1e-8)
    # and the raw kernel output: w * exp(alpha (1 - r)), tile sums
    w_new, psums = ignorance_update_unnormalized(w, r, a, interpret=True)
    ref = np.asarray(w, np.float32) * np.exp(
        float(a) * (1.0 - np.asarray(r, np.float32)))
    np.testing.assert_allclose(np.asarray(w_new), ref, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(float(jnp.sum(psums)), ref.sum(), rtol=1e-5)


@given(n=SHAPES, dtype=DTYPES)
@settings(max_examples=10, deadline=None)
def test_alpha_zero_only_renormalizes(n, dtype):
    """alpha -> 0: no reweighting, every implementation returns w/sum(w)."""
    w, r = _wr(n, dtype, n + 2)
    a = jnp.asarray(0.0, jnp.float32)
    expected = np.asarray(w, np.float32)
    expected = expected / expected.sum()
    for out in (scores.ignorance_update(w.astype(jnp.float32), r, a),
                scores.ignorance_update_exact(w.astype(jnp.float32), r, a, 3),
                ops.ignorance_update(w, r, a)):
        np.testing.assert_allclose(np.asarray(out, np.float32), expected,
                                   rtol=1e-6, atol=1e-8)


@given(n=SHAPES, alpha=ALPHAS, dtype=DTYPES)
@settings(max_examples=10, deadline=None)
def test_all_correct_reward_only_renormalizes(n, alpha, dtype):
    """r = 1 everywhere (the alpha -> +inf degeneracy the alpha_cap guards):
    the surrogate exp(alpha*(1-r)) is identically 1, so the update reduces
    to renormalization for ANY alpha — on every implementation."""
    w, _ = _wr(n, dtype, n + 3)
    r = jnp.ones((n,), jnp.float32)
    a = jnp.asarray(alpha, jnp.float32)
    expected = np.asarray(w, np.float32)
    expected = expected / expected.sum()
    for out in (scores.ignorance_update(w.astype(jnp.float32), r, a),
                ops.ignorance_update(w, r, a)):
        np.testing.assert_allclose(np.asarray(out, np.float32), expected,
                                   rtol=1e-6, atol=1e-8)
    # exact reweight multiplies every sample by the same exp(-alpha/(K-1)):
    # cancels under normalization too
    out = scores.ignorance_update_exact(w.astype(jnp.float32), r, a, 4)
    np.testing.assert_allclose(np.asarray(out, np.float32), expected,
                               rtol=1e-5, atol=1e-7)


def test_update_preserves_probability_simplex():
    """Outputs are nonnegative and sum to 1 (the 'ignorance' semantics)."""
    w, r = _wr(1024, jnp.float32, 9)
    for alpha in (0.0, 0.5, 4.0, 20.0):
        out = ops.ignorance_update(w, r, jnp.asarray(alpha))
        assert float(jnp.min(out)) >= 0.0
        np.testing.assert_allclose(float(jnp.sum(out)), 1.0, rtol=1e-5)
