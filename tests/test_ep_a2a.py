"""Expert-parallel all-to-all MoE vs the dense oracle (8-device subprocess
— the multi-device XLA flag must not leak into this test process)."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ArchConfig
    from repro.models.moe import moe_init, moe_apply
    from repro.sharding.context import mesh_context

    cfg = ArchConfig(name="t", arch_type="moe", num_layers=1, d_model=32,
                     num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                     vocab_size=128, num_experts=8, top_k=2, moe_d_ff=16,
                     dtype="float32", capacity_factor=8.0)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    key = jax.random.key(0)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 4, cfg.d_model))
    y_ref, _ = moe_apply(params, x, cfg, impl="dense")
    pspec = {"router": P(), "wi_gate": P("data", None, "model"),
             "wi_up": P("data", None, "model"), "wo": P("data", "model", None)}
    with mesh, mesh_context(mesh):
        f = jax.jit(lambda p, x: moe_apply(p, x, cfg, impl="ep_a2a"),
                    in_shardings=(jax.tree.map(
                        lambda s: NamedSharding(mesh, s), pspec,
                        is_leaf=lambda z: isinstance(z, P)),
                        NamedSharding(mesh, P("data", None, None))))
        y_ep, _ = f(params, x)
    err = float(jnp.max(jnp.abs(y_ep - y_ref)))
    assert err < 1e-4, err
    print("EP_OK", err)
""")


@pytest.mark.slow
def test_ep_a2a_matches_dense_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                         text=True, env=env, timeout=560)
    assert "EP_OK" in out.stdout, out.stdout + out.stderr


def test_ep_a2a_falls_back_without_mesh(key):
    """On a single host with no mesh context, ep_a2a degrades to gmm."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ArchConfig
    from repro.models.moe import moe_init, moe_apply
    cfg = ArchConfig(name="t", arch_type="moe", num_layers=1, d_model=16,
                     num_heads=2, num_kv_heads=2, head_dim=8, d_ff=32,
                     vocab_size=64, num_experts=4, top_k=2, moe_d_ff=16,
                     dtype="float32")
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 4, 16))
    y_ep, _ = moe_apply(params, x, cfg, impl="ep_a2a")
    y_ref, _ = moe_apply(params, x, cfg, impl="dense")
    assert float(jnp.max(jnp.abs(y_ep - y_ref))) < 1e-4
