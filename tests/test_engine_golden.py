"""Golden back-compat: the engine-backed `protocol.fit` must reproduce the
pre-refactor host loop (tests/golden_legacy_protocol.py) exactly — same
alphas, same component lists, same predictions, same metered bits — for
every variant and a fixed seed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from golden_legacy_protocol import (LegacyASCIIConfig, legacy_fit)
from repro.core.protocol import ASCIIConfig, fit
from repro.core.transport import TransportLog
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.learners.tree import DecisionTree


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=300)
    tr, te = train_test_split(0, 300)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


def _run_both(blob, variant, **cfg_kw):
    Xtr, ctr, Xte, cte, k = blob
    learners = [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr]
    new_log, old_log = TransportLog(), TransportLog()
    new = fit(jax.random.key(11), Xtr, ctr, learners,
              ASCIIConfig(num_classes=k, max_rounds=4, variant=variant,
                          **cfg_kw),
              transport=new_log)
    old = legacy_fit(jax.random.key(11), Xtr, ctr, learners,
                     LegacyASCIIConfig(num_classes=k, max_rounds=4,
                                       variant=variant, **cfg_kw),
                     transport=old_log)
    return new, old, new_log, old_log, Xte


@pytest.mark.parametrize("variant", ["ascii", "simple", "random", "async"])
def test_engine_matches_legacy(blob, variant):
    new, old, new_log, old_log, Xte = _run_both(blob, variant)
    # identical component lists: same agents, rounds, alphas, params
    assert [(c.agent, c.round) for c in new.components] == \
           [(c.agent, c.round) for c in old.components]
    np.testing.assert_array_equal(
        np.asarray([c.alpha for c in new.components]),
        np.asarray([c.alpha for c in old.components]))
    for cn, co in zip(new.components, old.components):
        for ln, lo in zip(jax.tree.leaves(cn.params),
                          jax.tree.leaves(co.params)):
            np.testing.assert_array_equal(np.asarray(ln), np.asarray(lo))
    # identical round history
    assert new.history == old.history
    # identical predictions
    np.testing.assert_array_equal(np.asarray(new.predict(Xte)),
                                  np.asarray(old.predict(Xte)))
    # identical metered traffic, entry for entry
    assert new_log.entries == old_log.entries


def test_engine_matches_legacy_exact_reweight(blob):
    new, old, _, _, Xte = _run_both(blob, "ascii", exact_reweight=True)
    np.testing.assert_array_equal(
        np.asarray([c.alpha for c in new.components]),
        np.asarray([c.alpha for c in old.components]))
    np.testing.assert_array_equal(np.asarray(new.predict(Xte)),
                                  np.asarray(old.predict(Xte)))


def test_engine_matches_legacy_cv_stop(blob):
    Xtr, ctr, Xte, cte, k = blob
    learners = [LogisticRegression(steps=60) for _ in Xtr]
    cfg_kw = dict(num_classes=k, max_rounds=6, cv_fraction=0.25,
                  cv_patience=1)
    new = fit(jax.random.key(5), Xtr, ctr, learners, ASCIIConfig(**cfg_kw))
    old = legacy_fit(jax.random.key(5), Xtr, ctr, learners,
                     LegacyASCIIConfig(**cfg_kw))
    assert new.history == old.history
    assert new.num_rounds == old.num_rounds
    np.testing.assert_array_equal(np.asarray(new.predict(Xte)),
                                  np.asarray(old.predict(Xte)))
