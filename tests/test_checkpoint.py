"""Checkpoint save/restore roundtrip over realistic param pytrees."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.models import api
from repro.optim.optimizers import adamw
from repro.train import checkpoint


def test_roundtrip(tmp_path, key):
    cfg = ARCHS["mamba2-130m"].reduced()
    params = api.init_params(key, cfg)
    opt = adamw(1e-3)
    state = {"params": params, "opt": opt.init(params)}
    checkpoint.save(str(tmp_path), 7, state)
    restored, step = checkpoint.restore(str(tmp_path), state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention(tmp_path, key):
    tree = {"x": jnp.zeros((3,))}
    for step in range(5):
        checkpoint.save(str(tmp_path), step, tree, max_keep=2)
    import os
    ckpts = [p for p in os.listdir(tmp_path) if p.startswith("ckpt_")]
    assert len(ckpts) == 2


def test_restore_specific_step(tmp_path):
    for step in (1, 2):
        checkpoint.save(str(tmp_path), step,
                        {"x": jnp.full((2,), float(step))}, max_keep=5)
    restored, step = checkpoint.restore(str(tmp_path),
                                        {"x": jnp.zeros((2,))}, step=1)
    assert step == 1 and float(restored["x"][0]) == 1.0
