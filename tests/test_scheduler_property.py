"""Property suite for the in-scan budget-permutation rule (PR 9).

The compiled backend lowers :meth:`BudgetAwareScheduler.round_order` into
the session scan as :func:`traced_round_order` — a ``lexsort`` over the
ascending ``(spent bits, -reward EMA, agent id)`` key.  These properties
pin the two implementations to each other over *arbitrary* spend/EMA
states, not just the trajectories the parity tests happen to walk:

  * the traced rank equals the eager sort for any fleet size, any spend
    vector (dense ties included), any f32 EMA vector;
  * the live scheduler object — stub transport state, observed-reward
    EMAs fed through ``observe`` — picks the exact order the traced rule
    does from the same state;
  * ``observe`` is replay-deterministic: re-feeding the same accuracy
    stream reproduces the same f32 EMAs bit for bit;
  * ``state_dict``/``load_state_dict`` round-trips are resume-exact: a
    restored scheduler orders every subsequent round identically.

Runs under Hypothesis when the container ships it (shrinking search);
falls back to a seeded example sweep of the same properties otherwise —
the property body is identical, only the driver differs.
"""
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.control.scheduler import (BudgetAwareScheduler,
                                     jitted_reward_ema,
                                     traced_round_order)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # no install allowed: seeded sweep fallback
    given = None

N_EXAMPLES = 100


def property_seeds(n=N_EXAMPLES):
    """Drive a property from one integer seed: Hypothesis draws (and
    shrinks) it when available, else a fixed seeded sweep."""
    if given is not None:
        def deco(f):
            return settings(max_examples=n, deadline=None)(
                given(seed=st.integers(min_value=0,
                                       max_value=2**63 - 1))(f))
        return deco
    return pytest.mark.parametrize("seed", [2_654_435_761 * i % (2**31)
                                            for i in range(n)])


def _draw_state(seed):
    """An arbitrary scheduler state: fleet size 1..8, spend vector drawn
    from a tie-prone or full-int32 range, EMA vector f32 in [0, 1] with a
    tie-prone discrete mode."""
    rng = np.random.default_rng(seed)
    M = int(rng.integers(1, 9))
    high = int(rng.choice([2, 4, 1000, 2**31 - 1]))
    spent = rng.integers(0, high, size=M, dtype=np.int64)
    if rng.integers(2):      # dense EMA ties: the id tie-break must decide
        ema = rng.choice(np.float32([0.0, 0.25, 0.5]), size=M)
    else:
        ema = rng.random(size=M, dtype=np.float32)
    return M, spent, ema


def _eager_rule(M, spent, ema):
    return sorted(range(M), key=lambda m: (int(spent[m]),
                                           -float(ema[m]), m))


@property_seeds()
def test_traced_rank_matches_eager_rule(seed):
    M, spent, ema = _draw_state(seed)
    traced = np.asarray(traced_round_order(jnp.asarray(spent, jnp.int32),
                                           jnp.asarray(ema, jnp.float32)))
    assert traced.tolist() == _eager_rule(M, spent, ema)


def _stub_transport(M, spent):
    """A budgeted-transport stand-in: per-link spend rows summing to the
    drawn per-sender totals, endpoint names the scheduler resolves ids
    through."""
    eps = {m: SimpleNamespace(agent_id=m, name=f"agent{m}")
           for m in range(M)}
    link_spent = {}
    for m in range(M):
        # split each sender's total across two destination links so the
        # scheduler's per-link row-sum actually exercises aggregation
        a = int(spent[m]) // 2
        link_spent[(f"agent{m}", f"agent{(m + 1) % M}")] = a
        link_spent[(f"agent{m}", f"agent{(m + 2) % max(M, 1)}")] = \
            int(spent[m]) - a
    return SimpleNamespace(link_spent=link_spent, _endpoints=eps)


@property_seeds()
def test_live_scheduler_matches_traced_rule(seed):
    """The object the eager engine consults and the traced twin pick the
    same permutation from the same transport + EMA state."""
    M, spent, ema = _draw_state(seed)
    sched = BudgetAwareScheduler()
    sched.bind_transport(_stub_transport(M, spent))
    sched._reward_ema = {m: float(ema[m]) for m in range(M)}
    order = sched.round_order(0, list(range(M)))
    traced = np.asarray(traced_round_order(jnp.asarray(spent, jnp.int32),
                                           jnp.asarray(ema, jnp.float32)))
    assert order == traced.tolist()


@property_seeds(n=50)
def test_observe_replay_deterministic(seed):
    """Feeding the same accuracy stream twice yields bit-identical f32
    EMAs — and they equal the shared ``reward_ema_update`` scan the
    compiled backend carries."""
    rng = np.random.default_rng(seed)
    beta = float(np.float32(rng.random()) * np.float32(0.99))
    accs = rng.random(size=int(rng.integers(1, 12)), dtype=np.float32)
    agent = int(rng.integers(0, 4))

    def run():
        s = BudgetAwareScheduler(reward_smoothing=beta)
        for a in accs:
            s.observe(agent, float(a))
        return s._reward_ema[agent]

    first, second = run(), run()
    assert first == second
    ema = None
    for a in accs:
        ema = float(jitted_reward_ema(beta)(
            0.0 if ema is None else ema, float(a), ema is None))
    assert first == ema


@property_seeds(n=50)
def test_state_roundtrip_resume_exact(seed):
    """state_dict -> fresh scheduler -> load_state_dict reproduces the
    exact order for every later round (arbitrary active subsets too)."""
    rng = np.random.default_rng(seed)
    M, spent, ema = _draw_state(seed)
    transport = _stub_transport(M, spent)
    sched = BudgetAwareScheduler()
    sched.bind_transport(transport)
    for m in range(M):
        sched.observe(m, float(ema[m]))
    resumed = BudgetAwareScheduler()
    resumed.bind_transport(transport)
    resumed.load_state_dict(sched.state_dict())
    for _ in range(4):
        size = int(rng.integers(1, M + 1))
        active = sorted(rng.choice(M, size=size, replace=False).tolist())
        assert sched.round_order(0, active) == \
            resumed.round_order(0, active)
