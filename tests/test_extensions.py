"""Extension coverage: int8 KV cache, CV stop criterion, heterogeneous
agents, scan-vs-unroll equivalence, optimizer behaviour, dry-run parsers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import api


class TestQuantKV:
    def test_int8_decode_close_and_argmax_stable(self, key):
        cfg = ARCHS["h2o-danube-3-4b"].reduced().with_overrides(window=8)
        params = api.init_params(key, cfg)
        B, S = 2, 24
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        _, caches, _ = api.forward(params, {"tokens": tokens[:, :-1]}, cfg)
        caches = api.pad_prefill_cache(caches, cfg, S + 4)
        pos = jnp.asarray(S - 1, jnp.int32)
        logits_fp, _ = api.decode_step(params, caches, tokens[:, -1:], pos, cfg)
        qc = api.quantize_cache(caches, cfg)
        logits_q, _ = api.decode_step(params, qc, tokens[:, -1:], pos, cfg)
        a = np.asarray(logits_fp[:, -1], np.float32)
        b = np.asarray(logits_q[:, -1], np.float32)
        rel = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert rel < 0.05, rel
        assert (a.argmax(-1) == b.argmax(-1)).all()

    def test_quant_cache_halves_bytes(self, key):
        cfg = ARCHS["qwen3-0.6b"].reduced()
        fp = api.init_cache(cfg, 2, 32)
        q = api.init_cache(cfg.with_overrides(kv_quant=True), 2, 32)
        fp_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(fp))
        q_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(q))
        assert q_bytes < 0.65 * fp_bytes

    def test_roundtrip_error_bounded(self, key):
        from repro.models.attention import dequantize_kv, quantize_kv
        x = jax.random.normal(key, (2, 8, 4, 64)) * 3
        q, s = quantize_kv(x)
        x2 = dequantize_kv(q, s, jnp.float32)
        # absmax int8: error <= scale/2 = max|x|/254 per (token, head)
        bound = np.asarray(jnp.max(jnp.abs(x), -1) / 254.0 + 1e-6)
        err = np.asarray(jnp.max(jnp.abs(x - x2), -1))
        assert (err <= bound + 1e-5).all()


class TestCVStop:
    def test_cv_criterion_stops_on_plateau(self, key):
        from repro.core.protocol import ASCIIConfig, fit
        from repro.data.synthetic import blob_fig3
        from repro.data.partition import vertical_split
        from repro.learners.tree import DecisionTree
        ds = blob_fig3(key, n=300)
        Xs = vertical_split(ds.X, (2, 6))
        cfg = ASCIIConfig(num_classes=10, max_rounds=12, cv_fraction=0.3,
                          cv_patience=1, stop_on_negative_alpha=False)
        fitted = fit(jax.random.key(1), Xs, ds.classes,
                     [DecisionTree(depth=3, num_thresholds=8)] * 2, cfg)
        assert fitted.num_rounds < 12          # plateaued and stopped
        assert "val_acc" in fitted.history[0]


class TestHeterogeneousAgents:
    def test_mixed_learner_families(self, key):
        """The paper's model-free claim: tree + logistic + MLP agents in one
        chain."""
        from repro.core.protocol import ASCIIConfig, fit
        from repro.data.synthetic import blob_fig3
        from repro.data.partition import train_test_split, vertical_split
        from repro.learners.logistic import LogisticRegression
        from repro.learners.mlp import MLP
        from repro.learners.tree import DecisionTree
        ds = blob_fig3(key, n=400)
        tr, te = train_test_split(0, 400)
        Xs = vertical_split(ds.X, (2, 3, 3))
        learners = [DecisionTree(depth=3, num_thresholds=8),
                    LogisticRegression(steps=100),
                    MLP(hidden=(32,), steps=100)]
        cfg = ASCIIConfig(num_classes=10, max_rounds=4)
        fitted = fit(jax.random.key(2), [x[tr] for x in Xs], ds.classes[tr],
                     learners, cfg)
        acc = float(jnp.mean(
            fitted.predict([x[te] for x in Xs]) == ds.classes[te]))
        single = float(jnp.mean(ds.classes[te] == 0))
        assert acc > 0.5                        # far above 10-class chance


class TestScanUnrollEquivalence:
    @pytest.mark.parametrize("arch", ["qwen3-0.6b", "jamba-v0.1-52b",
                                      "whisper-tiny"])
    def test_forward_identical(self, arch, key):
        cfg = ARCHS[arch].reduced()
        params = api.init_params(key, cfg)
        batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
        if cfg.frontend == "audio":
            batch["frames"] = jax.random.normal(key, (2, cfg.encoder_seq,
                                                      cfg.d_model))
        logits_scan, _, _ = api.forward(params, batch, cfg)
        logits_unroll, _, _ = api.forward(
            params, batch, cfg.with_overrides(scan_layers=False))
        np.testing.assert_allclose(np.asarray(logits_scan),
                                   np.asarray(logits_unroll),
                                   rtol=1e-5, atol=1e-5)


class TestOptim:
    def test_adamw_minimizes_quadratic(self):
        from repro.optim.optimizers import adamw
        opt = adamw(0.1)
        params = {"x": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        for i in range(200):
            grads = jax.tree.map(lambda p: 2 * p, params)
            params, state = opt.update(grads, state, params,
                                       jnp.asarray(i, jnp.int32))
        assert float(jnp.max(jnp.abs(params["x"]))) < 1e-2

    def test_grad_clip(self):
        from repro.optim.optimizers import clip_by_global_norm, global_norm
        g = {"a": jnp.full((4,), 100.0)}
        clipped = clip_by_global_norm(g, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5

    def test_cosine_schedule_shape(self):
        from repro.optim.schedules import cosine_with_warmup
        f = cosine_with_warmup(1.0, 10, 100)
        assert float(f(jnp.asarray(0))) == 0.0
        assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(f(jnp.asarray(100))) < 1e-3


class TestDryrunParsers:
    def test_collective_bytes_parser(self):
        import importlib
        dr = importlib.import_module("repro.launch.dryrun")
        hlo = """
  %ag = f32[16,128]{1,0:T(8)} all-gather(%x), replica_groups=[2]<=[2]
  %ar = bf16[64]{0} all-reduce(%y), to_apply=%add
  %tup = (f32[8,8]{1,0}, f32[4]{0}) all-to-all(%a, %b)
  %cp.1 = s32[10]{0} collective-permute-start(%c)
"""
        out = dr.collective_bytes(hlo)
        assert out["all-gather"] == 16 * 128 * 4
        assert out["all-reduce"] == 64 * 2 * 2          # bf16, wire 2x
        assert out["all-to-all"] == 8 * 8 * 4 + 4 * 4
        assert out["collective-permute"] == 10 * 4

    def test_model_flops_moe_active_params(self):
        import importlib
        dr = importlib.import_module("repro.launch.dryrun")
        from repro.configs.base import INPUT_SHAPES
        dense = dr.model_flops(ARCHS["qwen3-0.6b"], INPUT_SHAPES["train_4k"])
        assert dense == pytest.approx(6 * 0.596e9 * 4096 * 256, rel=0.05)
        moe_total = dr.model_flops(ARCHS["qwen3-moe-235b-a22b"],
                                   INPUT_SHAPES["train_4k"])
        # active ~22B of 235B total
        assert moe_total == pytest.approx(6 * 22e9 * 4096 * 256, rel=0.25)
