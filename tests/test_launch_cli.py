"""Launch-CLI pins for the session driver's argparse surface (PR 9).

Clock-skew must be rejected *at argparse time* on every path — the
explicit ``--clock-skew`` flag with the default scheduler used to fall
through to ``Scenario.validate`` with a message that never named the
flags — and the combinations the compiled backend newly accepts
(async variant, budget-aware scheduler) must actually run end to end.
"""
import sys

import pytest

from repro.launch import session as cli
from repro.scenarios import Scenario


def run_cli(monkeypatch, argv):
    monkeypatch.setattr(sys, "argv", ["session.py"] + argv)
    cli.main()


# ----------------------------------------------------------- clock-skew pins
def test_clock_skew_explicit_flag_errors_at_argparse(monkeypatch, capsys):
    """The hoisted check: explicit --clock-skew with the default variant
    dies in argparse with a message naming both flags, not deep in the
    session."""
    with pytest.raises(SystemExit) as exc:
        run_cli(monkeypatch, ["--clock-skew", "0,0,1,2", "--rounds", "2"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "--clock-skew" in err and "--variant async" in err


def test_clock_skew_preset_conflict_errors_at_argparse(monkeypatch, capsys):
    """The preset path keeps its own argparse-time rejection."""
    with pytest.raises(SystemExit) as exc:
        run_cli(monkeypatch, ["--scenario", "clean",
                              "--clock-skew", "0,0,1,2"])
    assert exc.value.code == 2
    assert "presets fix the scenario knobs" in capsys.readouterr().err


def test_clock_skew_malformed_value_errors(monkeypatch, capsys):
    with pytest.raises(SystemExit) as exc:
        run_cli(monkeypatch, ["--variant", "async",
                              "--clock-skew", "a,b"])
    assert exc.value.code == 2
    assert "comma-separated" in capsys.readouterr().err


def test_clock_skew_scenario_validate_still_rejects_nonasync():
    """The underlying Scenario.validate guard the CLI check hoists — kept
    as the backstop for non-CLI constructions."""
    from repro.core.engine import SequentialScheduler
    from repro.scenarios import make_variant
    scenario = Scenario("unit", clock_skew=(0, 0, 1, 2))
    with pytest.raises(ValueError, match="async"):
        scenario.validate(4, SequentialScheduler(), make_variant("ascii"))


def test_clock_skew_async_runs(monkeypatch, capsys):
    run_cli(monkeypatch, ["--variant", "async", "--clock-skew", "0,0,1,2",
                          "--rounds", "1", "--n", "120"])
    assert "async,metered" in capsys.readouterr().out


# ------------------------------------------- newly-legal compiled CLI combos
def test_compiled_async_accepted(monkeypatch, capsys):
    """PR 9: --backend compiled --variant async (with a wire codec) runs —
    both rejections this combination used to hit are gone."""
    run_cli(monkeypatch, ["--variant", "async", "--backend", "compiled",
                          "--learner", "logistic", "--steps", "10",
                          "--rounds", "1", "--n", "120",
                          "--codec", "int8"])
    out = capsys.readouterr().out
    assert "async,metered,compiled" in out


def test_compiled_budget_aware_accepted(monkeypatch, capsys):
    run_cli(monkeypatch, ["--scheduler", "budget-aware", "--backend",
                          "compiled", "--learner", "logistic", "--steps",
                          "10", "--rounds", "1", "--n", "120",
                          "--byte-budget", "6000"])
    out = capsys.readouterr().out
    assert "compiled" in out and "budget: spent=" in out


def test_compiled_async_still_rejects_controller(monkeypatch, capsys):
    with pytest.raises(SystemExit) as exc:
        run_cli(monkeypatch, ["--variant", "async",
                              "--controller", "resid"])
    assert exc.value.code == 2
    assert "per barrier" in capsys.readouterr().err
