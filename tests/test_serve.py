"""Serving path: prefill->decode equals full forward; ring-buffer (SWA)
cache equals full cache within the window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.models import api

B, S = 2, 24


def _setup(arch, key, window=None):
    cfg = ARCHS[arch].reduced()
    if window is not None:
        cfg = cfg.with_overrides(window=window)
    params = api.init_params(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    off = 0
    if cfg.frontend == "vision":
        batch["patch_emb"] = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model))
        off = cfg.num_frontend_tokens
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq,
                                                  cfg.d_model))
    return cfg, params, batch, tokens, off


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-130m",
                                  "jamba-v0.1-52b", "minicpm3-4b",
                                  "whisper-tiny", "internvl2-2b",
                                  "granite-moe-1b-a400m"])
def test_decode_matches_forward(arch, key):
    cfg, params, batch, tokens, off = _setup(arch, key)
    logits_full, _, _ = api.forward(params, batch, cfg)
    pre = {**batch, "tokens": tokens[:, :-1]}
    _, caches, _ = api.forward(params, pre, cfg)
    caches = api.pad_prefill_cache(caches, cfg, off + S + 4)
    logits_dec, _ = api.decode_step(params, caches, tokens[:, -1:],
                                    jnp.asarray(off + S - 1, jnp.int32), cfg)
    a = np.asarray(logits_full[:, -1, :], np.float32)
    b = np.asarray(logits_dec[:, -1, :], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 5e-3, err


def test_ring_cache_matches_full_for_swa(key):
    """With window W, decoding via a ring buffer of length W must equal
    decoding with the unbounded cache (h2o-danube SWA pathway)."""
    W = 8
    cfg, params, batch, tokens, off = _setup("h2o-danube-3-4b", key, window=W)
    # prefill W tokens, then decode several more both ways
    n_dec = 6
    pre = {**batch, "tokens": tokens[:, :S - n_dec]}
    _, caches, _ = api.forward(params, pre, cfg)
    full = api.pad_prefill_cache(caches, cfg, S + 4)
    # build the ring cache from the last W prefill positions
    from repro.models.attention import KVCache
    start = S - n_dec

    def ring_leaf(a):
        sl = a[:, :, start - W:start]
        # ring layout: slot = pos % W
        idx = (jnp.arange(start - W, start)) % W
        out = jnp.zeros((a.shape[0], a.shape[1], W) + a.shape[3:], a.dtype)
        return out.at[:, :, idx].set(sl)

    ring = jax.tree.map(ring_leaf, caches,
                        is_leaf=lambda x: False) if False else \
        {k: KVCache(ring_leaf(v.k), ring_leaf(v.v))
         for k, v in caches.items()}

    tok = tokens[:, start:start + 1]
    tok_r = tok
    for i in range(n_dec):
        pos = jnp.asarray(start + i, jnp.int32)
        logits_f, full = api.decode_step(params, full, tok, pos, cfg, "full")
        logits_r, ring = api.decode_step(params, ring, tok_r, pos, cfg, "ring")
        a = np.asarray(logits_f[:, -1], np.float32)
        b = np.asarray(logits_r[:, -1], np.float32)
        err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
        assert err < 5e-3, (i, err)
        tok = jnp.argmax(logits_f[:, -1:], -1).astype(jnp.int32)
        tok_r = jnp.argmax(logits_r[:, -1:], -1).astype(jnp.int32)


def test_greedy_generation_deterministic(key):
    cfg, params, batch, tokens, off = _setup("qwen3-0.6b", key)
    prefill = api.make_prefill_step(cfg)
    serve = api.make_serve_step(cfg)
    logits, caches = prefill(params, batch)
    caches = api.pad_prefill_cache(caches, cfg, off + S + 8)
    tok = jnp.argmax(logits[:, -1, :], -1).astype(jnp.int32)[:, None]
    outs = []
    for i in range(4):
        tok, _, caches = serve(params, caches, tok,
                               jnp.asarray(off + S + i, jnp.int32))
        outs.append(tok)
    assert jnp.concatenate(outs, 1).shape == (B, 4)
