"""Wire channel through both engine backends: eager and compiled must
produce bit-identical trajectories AND identical encoded-bit ledgers for
every codec, the budget must degrade/defer identically, byte accounting must
stay consistent under agent dropout and late joins, and codec state must
checkpoint/resume exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.comm.codecs import Fp16Codec, QuantCodec
from repro.core.compiled import compiled_session, plan_for, quant_sweep_run
from repro.core.engine import (AsyncStaleScheduler, MeteredTransport,
                               Protocol, SessionConfig, endpoints_for)
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.learners.tree import DecisionTree

CODECS = ["fp32", "fp16", "int8", "int4", "topk"]


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


def _fit(blob, transport, backend, rounds=3, steps=40, **cfg_kw):
    Xtr, ctr, _, _, k = blob
    cfg = SessionConfig(num_classes=k, max_rounds=rounds, **cfg_kw)
    learners = [LogisticRegression(steps=steps) for _ in Xtr]
    fitted = Protocol(cfg, transport=transport, backend=backend).fit(
        jax.random.key(11), endpoints_for(learners, Xtr), ctr)
    return fitted


def _assert_identical(eager, comp, Xte):
    assert [(c.agent, c.round) for c in eager.components] == \
           [(c.agent, c.round) for c in comp.components]
    np.testing.assert_array_equal(
        np.asarray([c.alpha for c in eager.components]),
        np.asarray([c.alpha for c in comp.components]))
    assert eager.history == comp.history
    np.testing.assert_array_equal(np.asarray(eager.predict(Xte)),
                                  np.asarray(comp.predict(Xte)))


# ================================================ eager == compiled, per codec
@pytest.mark.parametrize("name", CODECS)
def test_compiled_matches_eager_per_codec(blob, name):
    """The acceptance pin: identical trajectories AND identical encoded-bit
    ledgers, entry for entry, for every codec."""
    te_, tc = (MeteredTransport(codec=make_codec(name)) for _ in range(2))
    eager = _fit(blob, te_, "eager")
    comp = _fit(blob, tc, "compiled")
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    if name != "fp32":
        # the ledger books *encoded* bits, strictly below raw fp32
        n = blob[0][0].shape[0]
        ign = [e for e in te_.log.entries if e["kind"] == "ignorance"]
        assert ign and all(e["bits"] < 32 * n for e in ign)
        assert all(e["bits"] == make_codec(name).wire_bits(n) for e in ign)


def test_compiled_matches_eager_with_privacy(blob):
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    te_, tc = (MeteredTransport(privacy=mech) for _ in range(2))
    eager = _fit(blob, te_, "eager")
    comp = _fit(blob, tc, "compiled")
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    assert te_.accountant.releases == tc.accountant.releases
    assert te_.accountant.report(mech) == tc.accountant.report(mech)


def test_compiled_matches_eager_with_privacy_and_codec(blob):
    mech = GaussianMechanism(epsilon=3.0, clip=0.1)
    te_, tc = (MeteredTransport(codec=make_codec("int8"), privacy=mech)
               for _ in range(2))
    eager = _fit(blob, te_, "eager")
    comp = _fit(blob, tc, "compiled")
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries


def test_compiled_matches_eager_under_budget(blob):
    """The degrade-then-skip ladder walk picks identical rungs hop for hop
    on both backends: same ledger, same per-link spend, same skip set, same
    exhaustion — and exhaustion stops the session early."""
    # n=168: setup books 32256 bits, then the greedy ladder walk ships
    # fp32, fp32, fp16, int8, int4, skip -> every rung exercised
    spec = BudgetSpec(session_bits=48_000)
    te_, tc = (BudgetedTransport(spec) for _ in range(2))
    eager = _fit(blob, te_, "eager", rounds=5,
                 stop_on_negative_alpha=False)
    comp = _fit(blob, tc, "compiled", rounds=5,
                stop_on_negative_alpha=False)
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    assert te_.link_spent == tc.link_spent
    assert sorted(te_.skipped) == sorted(tc.skipped)
    assert te_.exhausted and tc.exhausted
    assert eager.num_rounds < 5                    # budget ended the session
    # the ladder actually degraded: several distinct ignorance wire sizes
    ign_sizes = {e["bits"] for e in te_.log.entries
                 if e["kind"] == "ignorance"}
    assert len(ign_sizes) >= 2
    if spec.session_bits is not None:
        assert te_.total_bits <= spec.session_bits  # the cap held


def test_compiled_matches_eager_budget_plus_privacy(blob):
    """Budget and DP compose: the scan factors the (rung-independent) noise
    out of the ladder walk — still bit-identical to the eager fused
    channel."""
    spec = BudgetSpec(session_bits=48_000)
    mech = GaussianMechanism(epsilon=3.0, clip=0.1)
    te_, tc = (BudgetedTransport(spec, privacy=mech) for _ in range(2))
    eager = _fit(blob, te_, "eager", rounds=5, stop_on_negative_alpha=False)
    comp = _fit(blob, tc, "compiled", rounds=5,
                stop_on_negative_alpha=False)
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    assert te_.accountant.releases == tc.accountant.releases
    assert te_.link_spent == tc.link_spent
    assert te_.exhausted == tc.exhausted


def test_budget_per_link_cap(blob):
    """A per-link cap starves each link independently of the session cap."""
    n = blob[0][0].shape[0]
    link_cap = Fp16Codec().wire_bits(n) + 32 + QuantCodec(bits=4
                                                          ).wire_bits(n) + 32
    spec = BudgetSpec(link_bits=link_cap,
                      ladder=(Fp16Codec(), QuantCodec(bits=4)))
    t = BudgetedTransport(spec)
    _fit(blob, t, "eager", rounds=4, stop_on_negative_alpha=False)
    assert not t.exhausted            # link caps never exhaust the session
    assert t.skipped                  # but every link eventually starves
    for spent in t.link_spent.values():
        assert spent <= link_cap


# =============================================== dropout / late-join accounting
def test_byte_accounting_under_dropout_and_late_join(blob):
    """Satellite pin: with churn mid-session and a codec active, the ledger
    stays internally consistent (per-entry sum == total_bits == by-kind sum)
    and every booked hop carries the codec's encoded size."""
    Xtr, ctr, _, _, k = blob
    codec = make_codec("int8")
    transport = MeteredTransport(codec=codec)
    cfg = SessionConfig(num_classes=k, max_rounds=4,
                        stop_on_negative_alpha=False)
    session = Protocol(cfg, transport=transport).start(
        jax.random.key(8),
        endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                       for _ in Xtr[:2]], Xtr[:2]), ctr)
    session.step()
    session.endpoints[1].active = False                      # dropout
    session.step()
    session.add_endpoint(DecisionTree(depth=3, num_thresholds=8), Xtr[2])
    session.run()
    log = transport.log
    assert sum(e["bits"] for e in log.entries) == log.total_bits
    assert sum(transport.bits_by_kind().values()) == log.total_bits
    n = int(ctr.shape[0])
    hops = len(session.state.components)
    kinds = transport.bits_by_kind()
    assert kinds["ignorance"] == hops * codec.wire_bits(n)
    assert kinds["model_weight"] == hops * 32
    # collation setup: one (labels + sample_ids) pair per non-head agent,
    # including the late joiner
    assert kinds["labels"] == 2 * n * 32
    assert kinds["sample_ids"] == 2 * n * 32


# ================================================== checkpoint / stale / sweep
def test_checkpoint_resume_with_stateful_codec(blob, tmp_path):
    """Top-k error-feedback residuals ride SessionState: resuming mid-run
    reproduces the uninterrupted lossy-channel trajectory exactly."""
    Xtr, ctr, Xte, cte, k = blob
    cfg = SessionConfig(num_classes=k, max_rounds=4,
                        stop_on_negative_alpha=False)

    def make():
        return (Protocol(cfg, transport=MeteredTransport(
                    codec=make_codec("topk"))),
                endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                               for _ in Xtr], Xtr))

    eng, eps = make()
    full = eng.start(jax.random.key(9), eps, ctr)
    full.run()
    assert full.state.codec_state                   # residuals accumulated

    eng, eps = make()
    part = eng.start(jax.random.key(9), eps, ctr)
    part.step()
    part.step()
    ckpt = str(tmp_path / "comm")
    part.checkpoint(ckpt)
    eng2, eps2 = make()
    resumed = eng2.resume(ckpt, eps2, ctr)
    assert resumed.state.codec_state.keys() == \
        part.state.codec_state.keys()
    resumed.run()
    assert resumed.state.history == full.state.history
    np.testing.assert_array_equal(np.asarray(resumed.state.w),
                                  np.asarray(full.state.w))
    np.testing.assert_array_equal(np.asarray(resumed.fitted().predict(Xte)),
                                  np.asarray(full.fitted().predict(Xte)))


def test_budget_and_privacy_survive_resume(blob, tmp_path):
    """Budget spend and DP release counts cross the pause/resume boundary:
    the resumed run continues under the same session cap (carryover bits)
    and the accountant keeps composing — identical trajectory, ledger
    split across the two processes, same final channel state as the
    uninterrupted run."""
    Xtr, ctr, _, _, k = blob
    spec = BudgetSpec(session_bits=48_000)
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    cfg = SessionConfig(num_classes=k, max_rounds=5,
                        stop_on_negative_alpha=False)

    def make():
        t = BudgetedTransport(spec, privacy=mech)
        return Protocol(cfg, transport=t), t

    def eps():
        return endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                              for _ in Xtr], Xtr)

    eng, t_full = make()
    full = eng.start(jax.random.key(9), eps(), ctr)
    full.run()
    assert t_full.exhausted                       # the cap actually bound

    eng, t_part = make()
    part = eng.start(jax.random.key(9), eps(), ctr)
    part.step()
    ckpt = str(tmp_path / "budget")
    part.checkpoint(ckpt)
    eng2, t_res = make()
    resumed = eng2.resume(ckpt, eps(), ctr)
    assert t_res.carryover_bits == t_part.log.total_bits
    resumed.run()

    assert resumed.state.history == full.state.history
    assert [(c.agent, c.round, c.alpha) for c in resumed.state.components] \
        == [(c.agent, c.round, c.alpha) for c in full.state.components]
    # the session cap held across both processes, not per process
    assert (t_part.log.total_bits + t_res.log.total_bits
            == t_full.log.total_bits)
    assert t_res.link_spent == t_full.link_spent
    assert t_res.exhausted == t_full.exhausted
    # epsilon composed across the boundary
    assert t_res.accountant.releases == t_full.accountant.releases


def test_stale_scheduler_rejects_controller(blob):
    """Per-barrier release narrowed the async rejection (PR 9): codec/DP/
    budget channels are legal on the stale path now — only adaptive
    controllers (a per-hop rung policy with no barrier analogue) stay
    rejected."""
    from repro.control import AdaptiveController
    Xtr, ctr, _, _, k = blob
    eng = Protocol(SessionConfig(num_classes=k, max_rounds=2),
                   scheduler=AsyncStaleScheduler(),
                   transport=MeteredTransport(controller=AdaptiveController()))
    with pytest.raises(ValueError, match="stale"):
        eng.start(jax.random.key(0),
                  endpoints_for([DecisionTree(depth=2) for _ in Xtr], Xtr),
                  ctr)
    # the previously-rejected codec channel now runs: one encoded barrier
    # release per executed round, booked from the synthetic "barrier" sender
    t = MeteredTransport(codec=make_codec("int8"))
    eng = Protocol(SessionConfig(num_classes=k, max_rounds=2),
                   scheduler=AsyncStaleScheduler(), transport=t)
    sess = eng.start(jax.random.key(0),
                     endpoints_for([DecisionTree(depth=2) for _ in Xtr],
                                   Xtr), ctr)
    sess.run()
    assert any(e["src"] == "barrier" and e["kind"] == "ignorance"
               for e in t.log.entries)


ASYNC_CHANNELS = {
    "plain": lambda: MeteredTransport(),
    "codec": lambda: MeteredTransport(codec=make_codec("int8")),
    "dp": lambda: MeteredTransport(
        privacy=GaussianMechanism(epsilon=2.0, clip=0.1)),
    "budget": lambda: BudgetedTransport(
        BudgetSpec(session_bits=40_000,
                   ladder=(QuantCodec(bits=8), QuantCodec(bits=4)))),
    # tight cap: the barrier walk runs out mid-session, skipping releases
    # (published score stays stale) and flipping exhausted
    "budget-tight": lambda: BudgetedTransport(
        BudgetSpec(session_bits=12_000,
                   ladder=(QuantCodec(bits=8), QuantCodec(bits=4)))),
}


@pytest.mark.parametrize("name", sorted(ASYNC_CHANNELS))
def test_async_compiled_matches_eager(blob, name):
    """PR 9 acceptance pin: channelized async fleets run on both backends
    with one ledger — per-barrier DP/codec/budget releases bit-identical to
    eager, including the skip path and the serve round-trip."""
    Xtr, ctr, Xte, _, k = blob
    te_, tc = ASYNC_CHANNELS[name](), ASYNC_CHANNELS[name]()
    cfg = SessionConfig(num_classes=k, max_rounds=4)
    learners = [LogisticRegression(steps=40) for _ in Xtr]
    pe = Protocol(cfg, scheduler=AsyncStaleScheduler(), transport=te_)
    pc = Protocol(cfg, scheduler=AsyncStaleScheduler(), transport=tc,
                  backend="compiled")
    fe = pe.fit(jax.random.key(11), endpoints_for(learners, Xtr), ctr)
    fc = pc.fit(jax.random.key(11), endpoints_for(learners, Xtr), ctr)
    _assert_identical(fe, fc, Xte)
    assert te_.log.entries == tc.log.entries
    if hasattr(te_, "link_spent"):
        assert te_.link_spent == tc.link_spent
        assert te_.skipped == tc.skipped
        assert te_.exhausted == tc.exhausted
    if te_.accountant is not None:
        assert te_.accountant.releases == tc.accountant.releases
    np.testing.assert_array_equal(np.asarray(pe.predict_distributed(Xte)),
                                  np.asarray(pc.predict_distributed(Xte)))


def test_quant_sweep_matches_per_config_runs(blob):
    """One vmapped program sweeping qmax == separate compiled runs with the
    statically-configured codecs — codec configs sweep inside one XLA
    program."""
    Xtr, ctr, _, _, k = blob
    learners = [LogisticRegression(steps=30) for _ in Xtr]
    plan8 = plan_for(learners, k, max_rounds=2, codec=make_codec("int8"))
    plan4 = plan_for(learners, k, max_rounds=2, codec=make_codec("int4"))
    key = jax.random.key(0)
    sweep = quant_sweep_run(plan8, jnp.stack([key, key]), Xtr, ctr,
                            jnp.asarray([127.0, 7.0]))
    for row, plan in ((0, plan8), (1, plan4)):
        single = compiled_session(plan, key, Xtr, ctr)
        np.testing.assert_array_equal(np.asarray(sweep.alphas[row]),
                                      np.asarray(single.alphas))
        np.testing.assert_array_equal(np.asarray(sweep.w[row]),
                                      np.asarray(single.w))
