"""Serve-path channel: prediction-time ScoreBlockMsg traffic through the
wire subsystem.  Pins eager vs compiled ``predict_distributed`` bit-for-bit
per codec (predictions, transport entries, bits_by_kind, accountant state),
the budget degrade -> head-only fallback with no free bits, serve-traffic
checkpoint/resume, the serve-axis codec sweep, and the fig4 frontier JSON
schema."""
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.core.compiled import plan_for, quant_sweep_run, serve_session
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.learners.tree import DecisionTree

CODECS = ["fp32", "fp16", "int8", "int4", "topk"]


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


def _engines(blob, make_transport, rounds=3, steps=40, **cfg_kw):
    """Two identically-configured engines (eager, compiled), fitted."""
    Xtr, ctr, _, _, k = blob
    out = []
    for backend in ("eager", "compiled"):
        transport = make_transport()
        engine = Protocol(
            SessionConfig(num_classes=k, max_rounds=rounds, **cfg_kw),
            transport=transport, backend=backend)
        engine.fit(jax.random.key(11),
                   endpoints_for([LogisticRegression(steps=steps)
                                  for _ in Xtr], Xtr), ctr)
        out.append((engine, transport))
    return out


# ============================================== eager == compiled, per codec
@pytest.mark.parametrize("name", CODECS)
def test_serve_compiled_matches_eager_per_codec(blob, name):
    """The serve-path acceptance pin: identical distributed predictions AND
    identical encoded-bit ledgers, entry for entry, for every codec rung."""
    Xtr, _, Xte, _, k = blob
    (pe, te_), (pc, tc) = _engines(
        blob, lambda: MeteredTransport(codec=make_codec(name)))
    p_e = pe.predict_distributed(Xte)
    p_c = pc.predict_distributed(Xte)
    np.testing.assert_array_equal(np.asarray(p_e), np.asarray(p_c))
    assert te_.log.entries == tc.log.entries
    assert te_.bits_by_kind() == tc.bits_by_kind()
    blocks = [e for e in te_.log.entries if e["kind"] == "score_block"]
    assert len(blocks) == len(Xtr) - 1          # head ships nothing
    shape = (Xte[0].shape[0], k)
    assert all(e["bits"] == make_codec(name).wire_bits(shape)
               for e in blocks)
    if name != "fp32":
        # the serve ledger books *encoded* bits, strictly below raw fp32
        assert all(e["bits"] < 32 * shape[0] * shape[1] for e in blocks)


def test_serve_max_round_parity(blob):
    """max_round masking (partial-ensemble serving) stays pinned across
    backends too."""
    Xtr, _, Xte, _, _ = blob
    (pe, te_), (pc, tc) = _engines(
        blob, lambda: MeteredTransport(codec=make_codec("int8")))
    np.testing.assert_array_equal(
        np.asarray(pe.predict_distributed(Xte, max_round=0)),
        np.asarray(pc.predict_distributed(Xte, max_round=0)))
    assert te_.log.entries == tc.log.entries


def test_serve_compiled_matches_eager_with_privacy(blob):
    """DP serve blocks: same noise draws, same ledger, and the accountant
    composes one release per shipped block per agent on both backends."""
    Xtr, _, Xte, _, _ = blob
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    (pe, te_), (pc, tc) = _engines(
        blob, lambda: MeteredTransport(codec=make_codec("int8"),
                                       privacy=mech))
    before = dict(te_.accountant.releases)
    p_e = pe.predict_distributed(Xte)
    p_c = pc.predict_distributed(Xte)
    np.testing.assert_array_equal(np.asarray(p_e), np.asarray(p_c))
    assert te_.log.entries == tc.log.entries
    assert te_.accountant.releases == tc.accountant.releases
    assert te_.accountant.report(mech) == tc.accountant.report(mech)
    # every non-head agent released exactly one noised block; the head's
    # own block never crosses the wire, so it spends no epsilon
    delta = {a: te_.accountant.releases[a] - before.get(a, 0)
             for a in te_.accountant.releases}
    assert delta == {f"agent{m}": (1 if m else 0) for m in range(len(Xtr))}


def test_serve_codec_override(blob):
    """serve_codec channels only the prediction traffic: training hops stay
    raw fp32 (bit-identical to a channel-less run), serve blocks encode —
    on both backends, identically."""
    Xtr, _, Xte, _, k = blob
    (pe, te_), (pc, tc) = _engines(
        blob, lambda: MeteredTransport(serve_codec=make_codec("int8")))
    (pr, tr_), _ = _engines(blob, MeteredTransport)
    p_e = pe.predict_distributed(Xte)
    p_c = pc.predict_distributed(Xte)
    np.testing.assert_array_equal(np.asarray(p_e), np.asarray(p_c))
    assert te_.log.entries == tc.log.entries
    ign = [e for e in te_.log.entries if e["kind"] == "ignorance"]
    n = Xtr[0].shape[0]
    assert all(e["bits"] == 32 * n for e in ign)        # training stays raw
    shape = (Xte[0].shape[0], k)
    blocks = [e for e in te_.log.entries if e["kind"] == "score_block"]
    assert all(e["bits"] == make_codec("int8").wire_bits(shape)
               for e in blocks)
    # training trajectory unaffected by the serve-only channel
    train_e = [e for e in te_.log.entries if e["kind"] != "score_block"]
    train_r = [e for e in tr_.log.entries if e["kind"] != "score_block"]
    assert train_e == train_r


def test_serve_default_key_identical_across_backends(blob):
    """Both backends derive the *same* default serve key — the session's
    evolved post-run ``state.key`` (the only anchor a resumed session can
    reproduce) — pinned directly on the key data, so a divergence cannot
    hide behind argmax-stable predictions.  Covers the full run and the
    alpha<=0 early stop (where the compiled scan keeps splitting masked
    slots the eager loop never reaches)."""
    from dataclasses import dataclass

    from repro.learners.base import Learner, LearnerCore

    @dataclass(frozen=True)
    class _ConstCore(LearnerCore):
        num_classes: int

        def init(self, key, shapes):
            return {"z": jnp.zeros(())}

        def fit(self, params, key, X, onehot, w):
            return params

        def logits(self, params, X):
            return (jnp.zeros((X.shape[0], self.num_classes))
                    .at[:, 0].set(1.0) + params["z"])

    @dataclass(frozen=True)
    class _ConstLearner(Learner):
        num_classes: int
        functional = True

        def core(self, num_classes):
            return _ConstCore(num_classes)

        def fit(self, key, X, classes, w, num_classes):
            core = self.core(num_classes)
            return core.fit(core.init(key, X.shape[1:]), key, X,
                            jax.nn.one_hot(classes, num_classes), w)

        def predict(self, params, X):
            return jnp.argmax(
                _ConstCore(self.num_classes).logits(params, X), axis=-1)

    Xtr, ctr, _, _, k = blob

    def keys_for(learners):
        out = []
        for backend in ("eager", "compiled"):
            engine = Protocol(
                SessionConfig(num_classes=k, max_rounds=3),
                transport=MeteredTransport(codec=make_codec("int8")),
                backend=backend)
            engine.fit(jax.random.key(11),
                       endpoints_for(learners(), Xtr[:len(learners())]),
                       ctr)
            if backend == "eager":
                out.append(engine._session.state.key)
            else:
                _, _, result = engine._compiled_ctx
                out.append(engine._evolved_key(result))
        return out

    full = keys_for(lambda: [LogisticRegression(steps=40) for _ in Xtr])
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(full[0])),
        np.asarray(jax.random.key_data(full[1])))

    stopped = keys_for(lambda: [LogisticRegression(steps=40),
                                _ConstLearner(k),
                                LogisticRegression(steps=40)])
    np.testing.assert_array_equal(
        np.asarray(jax.random.key_data(stopped[0])),
        np.asarray(jax.random.key_data(stopped[1])))


# =========================================== budget: degrade -> head-only
def _squeeze_serve_budget(transport, spec, shape, leave_rungs):
    """Shrink the remaining session budget (via the resume carryover
    mechanism) so the next predict can afford exactly the cheapest
    ``leave_rungs`` serve blocks."""
    costs = spec.serve_costs(shape)
    transport.carryover_bits = (spec.session_bits - transport.log.total_bits
                                - costs[-1] * leave_rungs)


def test_serve_budget_exhaustion_head_only(blob):
    """Budget-exhaustion mid-predict: the first block degrades down the
    ladder, later blocks skip (head-only fallback), the transport flags
    exhausted, and not one bit is booked for a skipped block — identically
    on both backends."""
    Xtr, _, Xte, cte, k = blob
    spec = BudgetSpec(session_bits=10 ** 8)
    shape = (Xte[0].shape[0], k)
    (pe, te_), (pc, tc) = _engines(blob, lambda: BudgetedTransport(spec))
    for t in (te_, tc):
        _squeeze_serve_budget(t, spec, shape, leave_rungs=1)
    total_before = {id(t): t.log.total_bits for t in (te_, tc)}
    p_e = pe.predict_distributed(Xte)
    p_c = pc.predict_distributed(Xte)
    np.testing.assert_array_equal(np.asarray(p_e), np.asarray(p_c))
    assert te_.log.entries == tc.log.entries
    assert te_.link_spent == tc.link_spent
    assert sorted(te_.skipped) == sorted(tc.skipped)
    assert te_.exhausted and tc.exhausted
    blocks = [e for e in te_.log.entries if e["kind"] == "score_block"]
    # exactly one block shipped, degraded to the cheapest rung (int4)
    assert len(blocks) == 1
    assert blocks[0]["bits"] == spec.ladder[-1].wire_bits(shape)
    # the other agents' blocks were dropped, not priced: no free bits
    assert len(te_.skipped) == len(Xtr) - 2
    spent = te_.log.total_bits - total_before[id(te_)]
    assert spent == blocks[0]["bits"]
    assert te_.log.total_bits + te_.carryover_bits <= spec.session_bits


def test_serve_budget_full_skip_is_head_only_prediction(blob):
    """With no serve budget at all, every remote block skips and the answer
    equals the head agent predicting from its own components alone."""
    Xtr, _, Xte, _, k = blob
    spec = BudgetSpec(session_bits=10 ** 8)
    shape = (Xte[0].shape[0], k)
    (pe, te_), _ = _engines(blob, lambda: BudgetedTransport(spec))
    _squeeze_serve_budget(te_, spec, shape, leave_rungs=0)
    preds = pe.predict_distributed(Xte)
    assert len(te_.skipped) == len(Xtr) - 1
    assert not any(e["kind"] == "score_block" for e in te_.log.entries)
    session = pe._session
    head_block = session.endpoints[0].score_block(
        session.state.components, k, X=Xte[0])
    np.testing.assert_array_equal(
        np.asarray(preds), np.asarray(jnp.argmax(head_block, axis=-1)))


# ======================================================= checkpoint / resume
def test_serve_traffic_survives_resume(blob, tmp_path):
    """Serve-path DP releases and budget spend cross the pause/resume
    boundary (extends test_budget_and_privacy_survive_resume to
    ScoreBlockMsg traffic): a mid-session predict books bits and epsilon
    that the resumed run keeps counting against the same caps."""
    Xtr, ctr, Xte, _, k = blob
    spec = BudgetSpec(session_bits=60_000)
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    cfg = SessionConfig(num_classes=k, max_rounds=5,
                        stop_on_negative_alpha=False)

    def make():
        t = BudgetedTransport(spec, privacy=mech)
        return Protocol(cfg, transport=t), t

    def eps():
        return endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                              for _ in Xtr], Xtr)

    def serve_then_continue(session):
        preds = session.predict_distributed(Xte)
        session.run()
        return preds

    eng, t_full = make()
    full = eng.start(jax.random.key(9), eps(), ctr)
    full.step()
    p_full = serve_then_continue(full)
    assert any(e["kind"] == "score_block" for e in t_full.log.entries)

    eng, t_part = make()
    part = eng.start(jax.random.key(9), eps(), ctr)
    part.step()
    p_part = part.predict_distributed(Xte)
    np.testing.assert_array_equal(np.asarray(p_part), np.asarray(p_full))
    ckpt = str(tmp_path / "serve")
    part.checkpoint(ckpt)
    eng2, t_res = make()
    resumed = eng2.resume(ckpt, eps(), ctr)
    # the paused run's serve traffic counts against the resumed session cap
    assert t_res.carryover_bits == t_part.log.total_bits
    assert any(e["kind"] == "score_block" for e in t_part.log.entries)
    # ... and its DP releases keep composing
    assert t_res.accountant.releases == t_part.accountant.releases
    resumed.run()

    assert resumed.state.history == full.state.history
    assert (t_part.log.total_bits + t_res.log.total_bits
            == t_full.log.total_bits)
    assert t_res.link_spent == t_full.link_spent
    assert t_res.exhausted == t_full.exhausted
    assert t_res.accountant.releases == t_full.accountant.releases


# ================================================================ codec sweep
def test_quant_sweep_serve_axis(blob):
    """quant_sweep_run's serve axis: the vmapped (session + serve) program
    matches per-config compiled runs followed by serve_session — identical
    distributed predictions and wire metadata (sent / codec rung), blocks
    equal to the quantization-scale ulp.  (Exact block equality is not
    claimed across the static- and traced-qmax programs: XLA folds a
    compile-time qmax into the absmax/qmax scale division differently than
    a runtime one, one ulp in the scale.  The acceptance pin — eager ==
    compiled predict_distributed, both static-qmax — is exact; see
    test_serve_compiled_matches_eager_per_codec.)"""
    Xtr, ctr, Xte, _, k = blob
    learners = [LogisticRegression(steps=30) for _ in Xtr]
    plan8 = plan_for(learners, k, max_rounds=2, codec=make_codec("int8"))
    plan4 = plan_for(learners, k, max_rounds=2, codec=make_codec("int4"))
    key = jax.random.key(0)
    from repro.comm.codecs import SERVE_FOLD
    from repro.core.compiled import compiled_session
    res, serve = quant_sweep_run(plan8, jnp.stack([key, key]), Xtr, ctr,
                                 jnp.asarray([127.0, 7.0]), serve_Xs=Xte)
    for row, plan in ((0, plan8), (1, plan4)):
        single = compiled_session(plan, key, Xtr, ctr)
        np.testing.assert_array_equal(np.asarray(res.alphas[row]),
                                      np.asarray(single.alphas))
        single_serve = serve_session(
            plan, single, jax.random.fold_in(key, SERVE_FOLD), Xte)
        np.testing.assert_array_equal(np.asarray(serve.preds[row]),
                                      np.asarray(single_serve.preds))
        np.testing.assert_array_equal(np.asarray(serve.sent[row]),
                                      np.asarray(single_serve.sent))
        np.testing.assert_array_equal(np.asarray(serve.codec_idx[row]),
                                      np.asarray(single_serve.codec_idx))
        np.testing.assert_allclose(np.asarray(serve.blocks[row]),
                                   np.asarray(single_serve.blocks),
                                   rtol=1e-6, atol=1e-6)


# ========================================================== frontier schema
def test_fig4_frontier_json_schema(tmp_path):
    """Smoke the emitted BENCH_comm.json schema at toy sizes: every row
    carries the train AND serve axes, and the quantized-oracle serve
    baselines are present and ordered."""
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    try:
        from benchmarks.fig4_transmission import frontier
    finally:
        sys.path.pop(0)
    out = str(tmp_path / "comm.json")
    res = frontier(out=out, sizes=(160, 2, 15))
    with open(out) as f:
        assert json.load(f) == res
    points = [r["point"] for r in res["rows"]]
    assert points[:5] == ["fp32", "fp16", "int8", "int4", "topk"]
    for r in res["rows"]:
        for field in ("acc", "interchange_bits", "serve_acc", "serve_bits",
                      "total_bits", "bits_by_kind", "rounds",
                      "bits_ratio_vs_fp32", "acc_drop_vs_fp32",
                      "serve_bits_ratio_vs_fp32", "serve_acc_drop_vs_fp32"):
            assert field in r, (r["point"], field)
        if not r["point"].startswith("budget50pct"):
            assert r["serve_bits"] == r["bits_by_kind"].get("score_block", 0)
            assert r["serve_bits"] > 0
        # a fully-skipped serve (head-only fallback, zero bits) reports a
        # null ratio, never a huge bogus compression number
        if r["serve_bits"] == 0:
            assert r["serve_bits_ratio_vs_fp32"] is None
        else:
            assert r["serve_bits_ratio_vs_fp32"] > 0
    base = res["rows"][0]
    assert base["serve_bits_ratio_vs_fp32"] == 1.0
    oracle = res["oracle_serve_bits"]
    assert oracle["fp32"] > oracle["fp16"] > oracle["int8"] > oracle["int4"]
    budget = next(r for r in res["rows"] if r["point"] == "budget50pct")
    assert "skipped_hops" in budget and "exhausted" in budget
    # control-plane points: the adaptive controller and the RDP-accounted
    # DP trace ride the same schema
    assert "adaptive" in points
    rdp = next(r for r in res["rows"] if r["point"] == "int8+dp1+rdp")
    for agent, entry in rdp["dp"].items():
        assert entry["epsilon"] <= entry["epsilon_additive"] + 1e-9
    # scheduler demo: same link caps, both round orders, full schema
    demo = res["scheduler_demo"]
    assert demo["agents"] >= 3          # 2 agents cannot distinguish orders
    for order in ("sequential", "budget_aware"):
        for field in ("acc", "skipped_hops", "interchange_bits"):
            assert field in demo[order], (order, field)
