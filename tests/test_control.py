"""Control-plane subsystem: the adaptive codec controller must be
bit-identical across engine backends (trajectories, ledgers, rung choices)
per codec ladder, compose with budgets as a floor on the ladder walk, and
checkpoint/resume exactly; the budget-aware scheduler must order rounds by
remaining link budget deterministically (and replay that order across
resume); the RDP accountant must never report more epsilon than additive
composition, and accountant reads must be monotone-safe."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        PrivacyAccountant, make_codec)
from repro.comm.codecs import Fp16Codec, Fp32Codec, QuantCodec
from repro.control import (AdaptiveController, BudgetAwareScheduler,
                           RDPAccountant, make_accountant)
from repro.control.accounting import rdp_epsilon
from repro.control.adaptive import DEFAULT_LADDER
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.learners.tree import DecisionTree

LADDERS = {
    "default": DEFAULT_LADDER,
    "two-rung": (Fp16Codec(), QuantCodec(bits=4)),
}


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


def _fit(blob, transport, backend, rounds=3, steps=40, scheduler=None,
         **cfg_kw):
    Xtr, ctr, _, _, k = blob
    cfg = SessionConfig(num_classes=k, max_rounds=rounds, **cfg_kw)
    learners = [LogisticRegression(steps=steps) for _ in Xtr]
    engine = Protocol(cfg, transport=transport, backend=backend,
                      scheduler=scheduler)
    return engine.fit(jax.random.key(11), endpoints_for(learners, Xtr), ctr)


def _assert_identical(eager, comp, Xte):
    assert [(c.agent, c.round) for c in eager.components] == \
           [(c.agent, c.round) for c in comp.components]
    np.testing.assert_array_equal(
        np.asarray([c.alpha for c in eager.components]),
        np.asarray([c.alpha for c in comp.components]))
    assert eager.history == comp.history
    np.testing.assert_array_equal(np.asarray(eager.predict(Xte)),
                                  np.asarray(comp.predict(Xte)))


# ============================================================ controller unit
def test_controller_validation():
    with pytest.raises(ValueError, match="at least one"):
        AdaptiveController(ladder=())
    with pytest.raises(ValueError, match="stateless"):
        AdaptiveController(ladder=(make_codec("topk"),))
    with pytest.raises(ValueError, match="thresholds"):
        AdaptiveController(thresholds=(0.5,))
    with pytest.raises(ValueError, match="descend"):
        AdaptiveController(thresholds=(0.1, 0.5, 0.9))
    with pytest.raises(ValueError, match="beta"):
        AdaptiveController(beta=1.0)
    with pytest.raises(ValueError, match="stat"):
        AdaptiveController(stat="kurtosis")


def test_controller_rung_policy_branchless():
    """The rung is sum(ema < thresholds): a quiet channel decays down the
    ladder, a loud one snaps back up — and the computation is pure/jittable
    (it must ride the session scan)."""
    c = AdaptiveController(thresholds=(0.75, 0.3, 0.03), beta=0.0)
    n = 64
    uniform = jnp.full((n,), 1.0 / n)
    spike = jnp.zeros((n,)).at[0].set(1.0)
    ema = c.init_state()
    # no innovation: statistic 0 -> coarsest rung
    rung, ema2 = jax.jit(c.step)(uniform, uniform, ema)
    assert int(rung) == 3 and float(ema2) == 0.0
    # maximal innovation (uniform -> delta): TV ~ 1 -> finest rung
    rung, ema3 = jax.jit(c.step)(uniform, spike, ema2)
    assert int(rung) == 0
    # mid innovation lands on a middle rung
    mid = (uniform + spike) / 2.0
    rung, _ = jax.jit(c.step)(uniform, mid, ema2)
    assert int(rung) in (1, 2)


def test_controller_entropy_stat_monotone():
    c = AdaptiveController(stat="entropy", beta=0.0)
    n = 256
    uniform = jnp.full((n,), 1.0 / n)
    conc = jnp.zeros((n,)).at[:4].set(0.25)
    s_u = float(c.observe(uniform, uniform))
    s_c = float(c.observe(uniform, conc))
    assert s_u == pytest.approx(1.0, abs=1e-6)
    assert s_c < 0.3
    # l2 participation ratio agrees on the ordering
    c2 = AdaptiveController(stat="l2", beta=0.0)
    assert float(c2.observe(uniform, uniform)) == pytest.approx(1.0, 1e-6)
    assert float(c2.observe(uniform, conc)) < 0.1


# ================================================= eager == compiled, per ladder
@pytest.mark.parametrize("ladder", sorted(LADDERS))
def test_compiled_matches_eager_adaptive(blob, ladder):
    """The tentpole pin: identical trajectories, identical encoded-bit
    ledgers, and identical per-hop rung choices on both backends, per codec
    ladder."""
    mk = lambda: AdaptiveController(ladder=LADDERS[ladder])  # noqa: E731
    te_, tc = (MeteredTransport(controller=mk()) for _ in range(2))
    eager = _fit(blob, te_, "eager")
    comp = _fit(blob, tc, "compiled")
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    # rung choice is observable through the encoded ignorance sizes
    n = blob[0][0].shape[0]
    sizes = {e["bits"] for e in te_.log.entries if e["kind"] == "ignorance"}
    allowed = {c.wire_bits(n) for c in LADDERS[ladder]}
    assert sizes <= allowed and sizes


def test_compiled_matches_eager_adaptive_entropy_stat(blob):
    """The entropy statistic decays hop over hop on this cohort, so several
    distinct rungs ship — still bit-identical across backends."""
    mk = lambda: AdaptiveController(stat="entropy")  # noqa: E731
    te_, tc = (MeteredTransport(controller=mk()) for _ in range(2))
    eager = _fit(blob, te_, "eager", rounds=4)
    comp = _fit(blob, tc, "compiled", rounds=4)
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    n = blob[0][0].shape[0]
    sizes = {e["bits"] for e in te_.log.entries if e["kind"] == "ignorance"}
    assert len(sizes) >= 2          # the controller actually adapted


def test_compiled_matches_eager_adaptive_with_privacy(blob):
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    mk = lambda: MeteredTransport(controller=AdaptiveController(),  # noqa: E731
                                  privacy=mech)
    te_, tc = mk(), mk()
    eager = _fit(blob, te_, "eager")
    comp = _fit(blob, tc, "compiled")
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    assert te_.accountant.releases == tc.accountant.releases


def test_compiled_matches_eager_adaptive_under_budget(blob):
    """Controller + budget compose: the controller rung floors the ladder
    walk, the budget degrades past it when bits run low — identical rungs,
    ledgers, link spend, and exhaustion on both backends."""
    spec = BudgetSpec(session_bits=48_000)
    mk = lambda: BudgetedTransport(spec,  # noqa: E731
                                   controller=AdaptiveController())
    te_, tc = mk(), mk()
    eager = _fit(blob, te_, "eager", rounds=5, stop_on_negative_alpha=False)
    comp = _fit(blob, tc, "compiled", rounds=5, stop_on_negative_alpha=False)
    _assert_identical(eager, comp, blob[2])
    assert te_.log.entries == tc.log.entries
    assert te_.link_spent == tc.link_spent
    assert sorted(te_.skipped) == sorted(tc.skipped)
    assert te_.exhausted == tc.exhausted


def test_serve_parity_budget_with_controller(blob):
    """Regression: a budgeted transport with a controller must serve score
    blocks through the budget ladder (encoded, priced at the shipped rung)
    on BOTH backends — the controller's raw-serve bypass applies only to
    unbudgeted transports."""
    Xtr, ctr, Xte, cte, k = blob
    # cap sized so training finishes undegraded (~119k bits) but the serve
    # walk must degrade below fp32 blocks and skip the tail
    spec = BudgetSpec(session_bits=124_000)
    mk = lambda: BudgetedTransport(spec,  # noqa: E731
                                   controller=AdaptiveController())
    te_, tc = mk(), mk()
    preds = {}
    for backend, t in (("eager", te_), ("compiled", tc)):
        eng = Protocol(SessionConfig(num_classes=k, max_rounds=3),
                       transport=t, backend=backend)
        eng.fit(jax.random.key(11),
                endpoints_for([LogisticRegression(steps=40) for _ in Xtr],
                              Xtr), ctr)
        preds[backend] = np.asarray(eng.predict_distributed(Xte))
    np.testing.assert_array_equal(preds["eager"], preds["compiled"])
    assert te_.log.entries == tc.log.entries
    assert te_.link_spent == tc.link_spent
    assert te_.exhausted == tc.exhausted
    # the serve walk actually degraded (distinct rung sizes shipped) and
    # the session cap held — no raw blocks booked at encoded prices
    blocks = [e["bits"] for e in te_.log.entries
              if e["kind"] == "score_block"]
    assert len(blocks) >= 2 and min(blocks) < max(blocks)
    assert te_.skipped and te_.exhausted
    assert te_.total_bits <= spec.session_bits


def test_budgeted_controller_ladder_mismatch_rejected():
    spec = BudgetSpec(session_bits=10 ** 6)
    with pytest.raises(ValueError, match="share the budget's ladder"):
        BudgetedTransport(spec, controller=AdaptiveController(
            ladder=(Fp16Codec(), QuantCodec(bits=4))))


def test_controller_with_explicit_codec_rejected():
    with pytest.raises(ValueError, match="drives codec choice"):
        MeteredTransport(codec=make_codec("int8"),
                         controller=AdaptiveController())


def test_controller_floor_respected_under_budget(blob):
    """With an uncapped budget the walk starts at the controller's rung:
    the shipped sizes match a plain controlled transport hop for hop."""
    spec = BudgetSpec(session_bits=10 ** 8)
    tb = BudgetedTransport(spec, controller=AdaptiveController())
    tm = MeteredTransport(controller=AdaptiveController())
    _fit(blob, tb, "eager")
    _fit(blob, tm, "eager")
    ign_b = [e["bits"] for e in tb.log.entries if e["kind"] == "ignorance"]
    ign_m = [e["bits"] for e in tm.log.entries if e["kind"] == "ignorance"]
    assert ign_b == ign_m and ign_b


# ======================================================== checkpoint / resume
def test_controller_and_rdp_state_survive_resume(blob, tmp_path):
    """Satellite pin: adaptive-controller EMA state and RDP accountant
    state cross the pause/resume boundary — the resumed run picks identical
    rungs (no free bits) and keeps composing epsilon (no resets), matching
    the uninterrupted run exactly."""
    Xtr, ctr, Xte, cte, k = blob
    spec = BudgetSpec(session_bits=60_000)
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    cfg = SessionConfig(num_classes=k, max_rounds=4,
                        stop_on_negative_alpha=False)

    def make():
        t = BudgetedTransport(spec, privacy=mech,
                              controller=AdaptiveController(),
                              accountant=RDPAccountant())
        return Protocol(cfg, transport=t), t

    def eps():
        return endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                              for _ in Xtr], Xtr)

    eng, t_full = make()
    full = eng.start(jax.random.key(9), eps(), ctr)
    full.run()

    eng, t_part = make()
    part = eng.start(jax.random.key(9), eps(), ctr)
    part.step()
    ckpt = str(tmp_path / "ctrl")
    part.checkpoint(ckpt)
    assert part.state.comm.get("ctrl_state") is not None
    eng2, t_res = make()
    resumed = eng2.resume(ckpt, eps(), ctr)
    # the EMA crossed the boundary bit for bit
    np.testing.assert_array_equal(np.asarray(t_res.ctrl_state),
                                  np.asarray(t_part.ctrl_state))
    resumed.run()

    assert resumed.state.history == full.state.history
    np.testing.assert_array_equal(np.asarray(resumed.state.w),
                                  np.asarray(full.state.w))
    # no free bits: the split ledgers sum to the uninterrupted ledger
    assert (t_part.log.total_bits + t_res.log.total_bits
            == t_full.log.total_bits)
    assert t_res.link_spent == t_full.link_spent
    np.testing.assert_array_equal(np.asarray(t_res.ctrl_state),
                                  np.asarray(t_full.ctrl_state))
    # no epsilon resets: release counts and the RDP report compose across
    # the boundary
    assert t_res.accountant.releases == t_full.accountant.releases
    assert t_res.accountant.report(mech) == t_full.accountant.report(mech)


def test_accountant_reads_are_monotone_safe(blob, tmp_path):
    """Satellite regression: reading epsilon mid-session (spent/report),
    checkpointing, and resuming must neither double-count nor drop the last
    release — the final ledger equals a run with no reads at all."""
    Xtr, ctr, _, _, k = blob
    mech = GaussianMechanism(epsilon=1.0, clip=0.1)
    cfg = SessionConfig(num_classes=k, max_rounds=3,
                        stop_on_negative_alpha=False)

    def make(acct):
        t = MeteredTransport(privacy=mech, accountant=acct)
        return Protocol(cfg, transport=t), t

    def eps():
        return endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                              for _ in Xtr], Xtr)

    for acct_name in ("basic", "rdp"):
        eng, t_quiet = make(make_accountant(acct_name))
        quiet = eng.start(jax.random.key(3), eps(), ctr)
        quiet.run()

        eng, t_read = make(make_accountant(acct_name))
        sess = eng.start(jax.random.key(3), eps(), ctr)
        sess.step()
        before = t_read.accountant.spent("agent0", mech)
        assert t_read.accountant.spent("agent0", mech) == before  # pure
        t_read.accountant.report(mech)
        ckpt = str(tmp_path / f"acct-{acct_name}")
        sess.checkpoint(ckpt)
        t_read.accountant.report(mech)                 # read after snapshot
        eng2, t_res = make(make_accountant(acct_name))
        resumed = eng2.resume(ckpt, eps(), ctr)
        t_res.accountant.report(mech)                  # read after restore
        resumed.run()
        assert t_res.accountant.releases == t_quiet.accountant.releases
        assert t_res.accountant.report(mech) == t_quiet.accountant.report(mech)


# ============================================================= RDP accounting
def test_rdp_never_looser_than_additive():
    mech = GaussianMechanism(epsilon=1.0, delta=1e-5)
    for k in (1, 2, 5, 20, 100):
        eps, _, _ = rdp_epsilon(k, mech)
        assert eps <= k * mech.epsilon + 1e-12, (k, eps)
    # and strictly tighter once composition bites
    eps5, delta5, _ = rdp_epsilon(5, mech)
    assert eps5 < 5 * mech.epsilon * 0.75
    assert delta5 == mech.delta               # the RDP bound's own delta
    # sublinear growth: 4x the releases far less than 4x the epsilon
    eps20, _, _ = rdp_epsilon(20, mech)
    assert eps20 < 4 * eps5
    # monotone in k
    last = 0.0
    for k in range(1, 30):
        e, _, _ = rdp_epsilon(k, mech)
        assert e >= last - 1e-12
        last = e


def test_rdp_additive_cap_reports_proven_delta():
    """When the additive bound is the tighter epsilon (large per-release
    epsilon), the report must be the pair basic composition actually
    proves: (k*eps, k*delta) — not k*eps at the smaller per-release
    delta."""
    mech = GaussianMechanism(epsilon=20.0, delta=1e-5)
    eps, delta, order = rdp_epsilon(2, mech)
    assert eps == pytest.approx(40.0)         # cap binds
    assert delta == pytest.approx(2e-5)       # proven additive delta
    assert order == 0.0                       # marks the additive bound
    acct = RDPAccountant()
    acct.record("a"), acct.record("a")
    assert acct.spent("a", mech) == (eps, delta)
    assert acct.report(mech)["a"]["delta"] == pytest.approx(2e-5)


def test_rdp_accountant_interface_and_report():
    mech = GaussianMechanism(epsilon=0.5, delta=1e-6)
    acct = RDPAccountant()
    assert isinstance(acct, PrivacyAccountant)   # drop-in behind the engine
    assert acct.spent("agent0", mech) == (0.0, 0.0)
    for _ in range(8):
        acct.record("agent0")
    acct.record("agent1")
    eps, delta = acct.spent("agent0", mech)
    assert 0 < eps <= 8 * 0.5 and delta == mech.delta
    rep = acct.report(mech)
    assert list(rep) == ["agent0", "agent1"]
    assert rep["agent0"]["releases"] == 8
    assert rep["agent0"]["epsilon"] <= rep["agent0"]["epsilon_additive"]
    assert rep["agent1"]["epsilon_additive"] == pytest.approx(0.5)


def test_make_accountant_registry():
    assert isinstance(make_accountant("rdp"), RDPAccountant)
    assert type(make_accountant("basic")) is PrivacyAccountant
    with pytest.raises(ValueError, match="unknown accountant"):
        make_accountant("zcdp")


def test_compiled_replay_tallies_rdp_accountant(blob):
    """The compiled backend's post-run ledger replay feeds the same
    accountant interface: an RDP accountant on a compiled run reports
    exactly what the eager run reports."""
    mech = GaussianMechanism(epsilon=2.0, clip=0.1)
    mk = lambda: MeteredTransport(codec=make_codec("int8"),  # noqa: E731
                                  privacy=mech,
                                  accountant=RDPAccountant())
    te_, tc = mk(), mk()
    _fit(blob, te_, "eager")
    _fit(blob, tc, "compiled")
    assert te_.accountant.releases == tc.accountant.releases
    assert te_.accountant.report(mech) == tc.accountant.report(mech)
    rep = te_.accountant.report(mech)
    for agent in rep:
        assert rep[agent]["epsilon"] <= rep[agent]["epsilon_additive"] + 1e-12


def test_accountant_without_privacy_rejected():
    with pytest.raises(ValueError, match="accountant"):
        MeteredTransport(accountant=RDPAccountant())


# ====================================================== budget-aware scheduler
def test_scheduler_orders_by_remaining_link_budget(blob):
    """Agents that spent less as senders go first; reward EMA breaks ties;
    agent id keeps it deterministic."""
    Xtr, ctr, _, _, k = blob
    spec = BudgetSpec(session_bits=10 ** 8, link_bits=10 ** 7)
    t = BudgetedTransport(spec)
    t.bind(endpoints_for([DecisionTree(depth=2) for _ in Xtr], Xtr))
    sched = BudgetAwareScheduler()
    sched.bind_transport(t)
    active = [0, 1, 2, 3]
    # fresh transport: no spend anywhere -> id order
    assert sched.round_order(0, active) == [0, 1, 2, 3]
    # agent0 spent the most, agent2 a little, others nothing
    t.link_spent = {("agent0", "agent1"): 5000, ("agent2", "agent3"): 100}
    assert sched.round_order(1, active) == [1, 3, 2, 0]
    # reward EMA breaks the tie between the two zero-spend agents
    sched.observe(3, 0.9)
    sched.observe(1, 0.2)
    assert sched.round_order(2, active) == [3, 1, 2, 0]
    # state_dict round-trips through the comm snapshot format
    s2 = BudgetAwareScheduler()
    s2.load_state_dict(sched.state_dict())
    s2.bind_transport(t)
    assert s2.round_order(2, active) == [3, 1, 2, 0]


def test_scheduler_run_deterministic_and_resumable(blob, tmp_path):
    """A budget-aware run is deterministic, and pause/resume replays the
    identical round orders (scheduler state + link spend both cross the
    boundary)."""
    Xtr, ctr, _, _, k = blob
    spec = BudgetSpec(session_bits=48_000)
    cfg = SessionConfig(num_classes=k, max_rounds=5,
                        stop_on_negative_alpha=False)

    def run_full():
        t = BudgetedTransport(spec)
        eng = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=t)
        s = eng.start(jax.random.key(9), endpoints_for(
            [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr], Xtr),
            ctr)
        s.run()
        return s, t

    full_a, _ = run_full()
    full_b, t_b = run_full()
    assert full_a.state.history == full_b.state.history
    # the scheduler genuinely reordered at least one budget-starved round
    orders = [[c.agent for c in full_a.state.components if c.round == t]
              for t in range(full_a.state.round)]
    assert any(o != sorted(o) for o in orders if o), orders

    t = BudgetedTransport(spec)
    eng = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=t)
    part = eng.start(jax.random.key(9), endpoints_for(
        [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr], Xtr), ctr)
    part.step()
    part.step()
    ckpt = str(tmp_path / "sched")
    part.checkpoint(ckpt)
    t2 = BudgetedTransport(spec)
    eng2 = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=t2)
    resumed = eng2.resume(ckpt, endpoints_for(
        [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr], Xtr), ctr)
    resumed.run()
    assert resumed.state.history == full_a.state.history
    np.testing.assert_array_equal(np.asarray(resumed.state.w),
                                  np.asarray(full_a.state.w))


def test_scheduler_resume_on_plain_metered_transport(blob, tmp_path):
    """Regression: the scheduler's metered-ledger ordering signal is
    process-local, so it must cross the checkpoint through scheduler state
    — with unequal per-sender spend (dropout cohort), a resumed session
    must replay the uninterrupted run's round orders exactly."""
    Xtr, ctr, _, _, k = blob
    cfg = SessionConfig(num_classes=k, max_rounds=5,
                        stop_on_negative_alpha=False)

    def eps():
        return endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                              for _ in Xtr], Xtr)

    def start(key=9):
        t = MeteredTransport()
        eng = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=t)
        return eng, eng.start(jax.random.key(key), eps(), ctr)

    # uninterrupted run with a dropout: sender spends diverge
    _, full = start()
    full.step()
    full.endpoints[1].active = False
    full.step()
    full.endpoints[1].active = True
    full.run()

    _, part = start()
    part.step()
    part.endpoints[1].active = False
    part.step()
    part.endpoints[1].active = True
    ckpt = str(tmp_path / "metered-sched")
    part.checkpoint(ckpt)
    assert part.state.comm["scheduler"].get("spent_by_src")  # signal saved
    t2 = MeteredTransport()
    eng2 = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=t2)
    resumed = eng2.resume(ckpt, eps(), ctr)
    resumed.run()
    assert resumed.state.history == full.state.history
    np.testing.assert_array_equal(np.asarray(resumed.state.w),
                                  np.asarray(full.state.w))


def test_scheduler_compiled_matches_eager_metered(blob):
    """PR 9: the budget-aware permutation lowers into the scan for
    homogeneous fleets — the compiled backend runs it bit-identically
    instead of rejecting (wire-bit spend signal, EMA tie-break).  The
    remaining RandomScheduler rejection pin lives in test_compiled."""
    Xtr, ctr, Xte, _, k = blob
    te_, tc = MeteredTransport(), MeteredTransport()
    eager = _fit(blob, te_, "eager", rounds=4,
                 scheduler=BudgetAwareScheduler())
    comp = _fit(blob, tc, "compiled", rounds=4,
                scheduler=BudgetAwareScheduler())
    _assert_identical(eager, comp, Xte)
    assert te_.log.entries == tc.log.entries


def test_scheduler_compiled_matches_eager_budgeted(blob):
    """The full acceptance pin: budget-aware + budgeted transport compiled
    == eager — components, params, history, predictions, ledger entries
    (rung stamps included), link spend, skips, exhaustion, and the serve
    round-trip; and budget pressure genuinely permutes the round order."""
    Xtr, ctr, Xte, cte, k = blob
    spec = lambda: BudgetSpec(session_bits=40_000, link_bits=9_000,
                              ladder=(QuantCodec(bits=8),
                                      QuantCodec(bits=4)))
    te_, tc = BudgetedTransport(spec()), BudgetedTransport(spec())
    cfg = SessionConfig(num_classes=k, max_rounds=4)
    mk = lambda: [LogisticRegression(steps=40) for _ in Xtr]
    pe = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=te_)
    pc = Protocol(cfg, scheduler=BudgetAwareScheduler(), transport=tc,
                  backend="compiled")
    fe = pe.fit(jax.random.key(11), endpoints_for(mk(), Xtr), ctr)
    fc = pc.fit(jax.random.key(11), endpoints_for(mk(), Xtr), ctr)
    _assert_identical(fe, fc, Xte)
    for ce, cc in zip(fe.components, fc.components):
        for le, lc in zip(jax.tree.leaves(ce.params),
                          jax.tree.leaves(cc.params)):
            np.testing.assert_array_equal(np.asarray(le), np.asarray(lc))
    assert te_.log.entries == tc.log.entries
    assert te_.link_spent == tc.link_spent
    assert te_.skipped == tc.skipped
    assert te_.exhausted == tc.exhausted
    # the chosen rung rides the ledger entries on both backends
    assert any("rung" in e for e in te_.log.entries)
    # budget pressure reordered at least one round away from id order
    per_round: dict[int, list[int]] = {}
    for c in fe.components:
        per_round.setdefault(c.round, []).append(c.agent)
    assert any(agents != sorted(agents) for agents in per_round.values())
    np.testing.assert_array_equal(np.asarray(pe.predict_distributed(Xte)),
                                  np.asarray(pc.predict_distributed(Xte)))


def test_scheduler_validation():
    with pytest.raises(ValueError, match="reward_smoothing"):
        BudgetAwareScheduler(reward_smoothing=1.0)
