"""End-to-end behaviour tests for the ASCII system: the paper's claims on
small data, the LM training driver, and the benchmark harness."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.protocol import ASCIIConfig, fit, fit_single_agent_adaboost
from repro.core.transport import TransportLog, oracle_bits
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig4
from repro.learners.forest import RandomForest


def test_transmission_cost_advantage(key):
    """Fig. 4a claim: with wide redundant features, ASCII reaches
    near-oracle accuracy at a fraction of the raw-transfer bits."""
    ds = blob_fig4(key, n=400)
    tr, te = train_test_split(0, 400)
    Xs = vertical_split(ds.X, ds.splits)
    Xtr, Xte = [x[tr] for x in Xs], [x[te] for x in Xs]
    ctr, cte = ds.classes[tr], ds.classes[te]
    learners = [RandomForest(num_trees=4, depth=4, num_thresholds=8)
                for _ in Xs]
    cfg = ASCIIConfig(num_classes=10, max_rounds=3)
    log = TransportLog()
    fitted = fit(jax.random.key(1), Xtr, ctr, learners, cfg, transport=log)
    acc = float(jnp.mean(fitted.predict(Xte) == cte))
    assert acc > 0.5                          # far above 10-class chance
    raw = oracle_bits(len(tr), Xs[1].shape[1])
    assert raw / log.total_bits > 3.0         # paper reports ~10x here


def test_lm_driver_loss_decreases(key):
    """The end-to-end WST/LM trainer actually learns (few steps, tiny).

    Un-xfailed: the seed's token_stream had no next-token signal (the
    Markov map was applied to a pre-noise base sequence, so consecutive
    emitted tokens were independent).  With the fixed first-order chain at
    copy_prob=0.9 the loss drops several nats in ~24 steps — deterministic
    data + deterministic trainer, so the margin is structural, not luck."""
    from repro.configs.base import ArchConfig
    from repro.data.pipeline import lm_batches
    from repro.optim.optimizers import adamw
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = ArchConfig(name="tiny", arch_type="dense", num_layers=2,
                     d_model=64, num_heads=2, num_kv_heads=2, head_dim=32,
                     d_ff=128, vocab_size=128, dtype="float32")
    trainer = Trainer(cfg, adamw(1e-2), TrainerConfig(steps=24, log_every=8))
    data = lm_batches(key, vocab_size=128, batch=8, seq_len=64,
                      copy_prob=0.9)
    _, _, history = trainer.run(key, data)
    # a real dip, not jitter: at least 20% off the from-scratch loss
    assert history[-1]["loss"] < 0.8 * history[0]["loss"], history


def test_checkpointed_training_resumes(tmp_path, key):
    from repro.configs.base import ArchConfig
    from repro.models import api
    from repro.optim.optimizers import adamw
    from repro.train import checkpoint
    cfg = ArchConfig(name="tiny", arch_type="dense", num_layers=1,
                     d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
                     d_ff=64, vocab_size=64, dtype="float32")
    params = api.init_params(key, cfg)
    opt = adamw(1e-3)
    checkpoint.save(str(tmp_path), 3, {"params": params,
                                       "opt": opt.init(params)})
    restored, step = checkpoint.restore(str(tmp_path),
                                        {"params": params,
                                         "opt": opt.init(params)})
    assert step == 3
    step_fn = jax.jit(api.make_train_step(cfg, opt))
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, 64),
             "sample_weight": jnp.ones((2,))}
    _, _, m = step_fn(restored["params"], restored["opt"], batch,
                      jnp.asarray(step, jnp.int32))
    assert bool(jnp.isfinite(m["loss"]))


@pytest.mark.slow
def test_benchmark_harness_runs():
    from benchmarks import fig3_accuracy, fig6_variants
    rows = fig3_accuracy.run(reps=1, rounds=3, quick=True)
    assert {r["method"] for r in rows} == {"ascii", "single", "oracle"}
    rows6 = fig6_variants.run(reps=1, rounds=3, quick=True)
    methods = {r["method"] for r in rows6}
    assert "ascii" in methods and "ensemble_ada" in methods
