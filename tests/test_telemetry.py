"""Telemetry subsystem: metrics registry, span tracer, exporters, and the
observation-only invariant.

The defining pin: attaching a :class:`repro.telemetry.Telemetry` to a
protocol run changes NOTHING — predictions, ledger entries, and accountant
releases are bit-identical with telemetry on vs off, on both backends,
train and serve, including the budgeted + DP + adaptive-controller channel.
On top of that: the registry agrees with the transport ledger it shadows
(and eager agrees with compiled wherever the ledgers do), span trees are
well-formed, the JSONL trace round-trips back into an equal registry, the
exporters pass their own schema validators, and the serve-stack counter
surfaces (admission / cache / batcher / engine summary) keep their
pre-registry key schemas.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.control import AdaptiveController
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.core.transport import TransportLog
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.serve import (AdmissionController, AdmissionPolicy, ServeEngine,
                         SessionCache)
from repro.telemetry import MetricsRegistry, SpanTracer, Telemetry
from repro.telemetry import check as tcheck
from repro.telemetry import export as texport


@pytest.fixture(scope="module")
def blob():
    ds = blob_fig3(jax.random.key(0), n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr], [x[te] for x in Xs],
            ds.num_classes)


# --------------------------------------------------------------- registry
class TestRegistry:
    def test_counter_labels(self):
        r = MetricsRegistry()
        r.inc("hops_total", 1, src="a", dst="b")
        r.inc("hops_total", 2, dst="b", src="a")   # label order irrelevant
        r.inc("hops_total", 1, src="b", dst="a")
        assert r.value("hops_total", src="a", dst="b") == 3
        assert r.total("hops_total") == 4

    def test_label_named_name_does_not_collide(self):
        # span_seconds carries a label literally called "name"
        r = MetricsRegistry()
        r.inc("spans_total", 1, name="hop")
        r.observe("span_seconds", 0.5, name="hop")
        assert r.value("spans_total", name="hop") == 1
        assert r.histogram("span_seconds", name="hop")["count"] == 1

    def test_negative_increment_rejected(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError):
            r.inc("x", -1)

    def test_gauge_and_histogram(self):
        r = MetricsRegistry()
        r.set_gauge("depth", 3, link="a")
        r.set_gauge("depth", 1, link="a")           # last write wins
        assert r.gauge("depth", link="a") == 1
        for v in (2.0, 4.0, 1.0):
            r.observe("lat", v)
        h = r.histogram("lat")
        assert (h["count"], h["sum"], h["min"], h["max"]) == (3, 7.0, 1.0, 4.0)

    def test_event_round_trip(self):
        r = MetricsRegistry()
        r.inc("a_total", 5, k="x")
        r.set_gauge("g", 2.5)
        r.observe("h", 1.0, name="n")
        r2 = MetricsRegistry.from_events(r.to_events())
        assert r2.to_events() == r.to_events()

    def test_series_sorted_and_stable(self):
        r = MetricsRegistry()
        r.inc("t", 1, b="2")
        r.inc("t", 1, a="1")
        assert list(r.series("t")) == sorted(r.series("t"))


# ------------------------------------------------------------------ spans
class TestSpans:
    def test_tree_shape_and_timing(self):
        tr = SpanTracer(MetricsRegistry(), fence=False)
        with tr.span("session"):
            with tr.span("round", step=0):
                with tr.span("hop", src="a", dst="b"):
                    pass
            with tr.span("round", step=1):
                pass
        assert tr.well_formed()
        spans = tr.spans
        # recorded in open order
        assert [s.name for s in spans] == ["session", "round", "hop",
                                           "round"]
        by_name = {s.name: s for s in spans}
        hop = next(s for s in spans if s.name == "hop")
        parent = next(s for s in spans if s.span_id == hop.parent_id)
        assert parent.name == "round"
        assert by_name["session"].parent_id is None
        for s in spans:
            assert s.end_s >= s.start_s
        assert tr.registry.histogram(
            "span_seconds", name="round")["count"] == 2

    def test_unclosed_span_is_malformed(self):
        tr = SpanTracer(MetricsRegistry(), fence=False)
        cm = tr.span("dangling")
        cm.__enter__()
        assert not tr.well_formed()
        cm.__exit__(None, None, None)
        assert tr.well_formed()

    def test_fence_passthrough_and_disable(self):
        tr = SpanTracer(MetricsRegistry(), fence=False)
        x = jnp.arange(3)
        assert tr.fence(x) is x
        assert tr.fence(None) is None
        tr2 = SpanTracer(MetricsRegistry())
        assert (np.asarray(tr2.fence(jnp.arange(3))) == [0, 1, 2]).all()


# -------------------------------------------------- the bit-identity pin
def _channel(controller=False):
    t = BudgetedTransport(BudgetSpec(session_bits=600_000),
                          log=TransportLog(),
                          privacy=GaussianMechanism(epsilon=1.0),
                          controller=(AdaptiveController(stat="resid")
                                      if controller else None))
    return t


def _fit_serve(blob, backend, telemetry, controller=False):
    Xtr, ctr, Xte, k = blob
    transport = _channel(controller)
    proto = Protocol(SessionConfig(num_classes=k, max_rounds=3),
                     transport=transport, backend=backend,
                     telemetry=telemetry)
    eps = endpoints_for([LogisticRegression(steps=40) for _ in Xtr], Xtr)
    proto.fit(jax.random.key(7), eps, ctr)
    preds = np.asarray(proto.predict_distributed(Xte))
    return preds, transport


@pytest.mark.parametrize("backend", ["eager", "compiled"])
@pytest.mark.parametrize("controller", [False, True])
def test_telemetry_on_off_bit_identical(blob, backend, controller):
    tele = Telemetry()
    p_on, t_on = _fit_serve(blob, backend, tele, controller)
    p_off, t_off = _fit_serve(blob, backend, None, controller)
    assert (p_on == p_off).all()
    assert t_on.log.entries == t_off.log.entries
    assert t_on.accountant.releases == t_off.accountant.releases
    assert t_on.link_spent == t_off.link_spent
    # and the registry is a faithful shadow of the ledger it observed
    assert tele.registry.total("wire_bits_total") == t_on.log.total_bits
    assert tele.registry.total("messages_total") == t_on.log.hops
    assert (tele.registry.total("dp_releases_total")
            == sum(t_on.accountant.releases.values()))
    assert tele.tracer.well_formed()


def test_eager_registry_equals_compiled_registry(blob):
    regs = {}
    for backend in ("eager", "compiled"):
        tele = Telemetry()
        _fit_serve(blob, backend, tele)
        regs[backend] = {n: tele.registry.series(n)
                         for n in tele.registry.counter_names()}
    assert regs["eager"] == regs["compiled"]


def test_span_tree_hop_under_round(blob):
    tele = Telemetry()
    _fit_serve(blob, "eager", tele)
    spans = {s.span_id: s for s in tele.tracer.spans}
    names = [s.name for s in tele.tracer.spans]
    assert {"session", "round", "hop", "serve"} <= set(names)
    for s in tele.tracer.spans:
        if s.name == "hop":
            assert spans[s.parent_id].name == "round"
        if s.name == "round":
            assert spans[s.parent_id].name == "session"
            assert "step" in s.attrs


# ------------------------------------------------------------- exporters
def test_trace_round_trip_and_validators(blob, tmp_path):
    tele = Telemetry()
    _, transport = _fit_serve(blob, "compiled", tele)
    trace = tmp_path / "trace.jsonl"
    mjson = tmp_path / "metrics.json"
    mprom = tmp_path / "metrics.prom"
    tele.write_artifacts(trace=str(trace), metrics_out=str(mjson),
                         transport=transport)
    tele.write_artifacts(metrics_out=str(mprom), transport=transport)
    for p in (trace, mjson, mprom):
        assert tcheck.validate_file(str(p)) == []
    # JSONL -> registry round-trip reproduces every counter/gauge/histogram
    r2 = texport.load_registry(str(trace))
    assert r2.to_events() == tele.registry.to_events()
    # gauge sync put the budget state in the snapshot
    snap = json.loads(mjson.read_text())
    assert snap["schema"] == texport.SCHEMA
    spent = sum(snap["counters"]["wire_bits_total"].values())
    assert spent == transport.total_bits
    assert snap["gauges"]["budget_exhausted"][""] == int(transport.exhausted)


def test_check_cli_exit_codes(tmp_path):
    good = tmp_path / "ok.jsonl"
    r = MetricsRegistry()
    r.inc("x_total", 1)
    texport.write_trace(str(good), registry=r,
                        tracer=SpanTracer(r, fence=False))
    assert tcheck.main([str(good)]) == 0
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "metric"}\n')
    assert tcheck.main([str(bad)]) == 1
    assert tcheck.main([]) == 2


# ---------------------------------------------------- streaming trace export
def test_streaming_trace_spans_land_before_seal(tmp_path):
    """Crash durability: every closed span is on disk *before* the writer
    seals, and the sealed file is a fully valid trace whose metric events
    rebuild the registry."""
    tele = Telemetry()
    path = tmp_path / "stream.jsonl"
    tele.stream_trace(str(path))
    with tele.span("session"):
        with tele.span("round", step=0):
            pass
    tele.registry.inc("x_total", 3)
    pre = texport.load_events(str(path))
    # close order: round sealed first, then session — both already durable
    assert [e["type"] for e in pre] == ["meta", "span", "span"]
    assert [e["name"] for e in pre[1:]] == ["round", "session"]
    tele.write_artifacts(trace=str(path))       # seals the live stream
    assert tcheck.validate_file(str(path)) == []
    r2 = texport.load_registry(str(path))
    assert r2.to_events() == tele.registry.to_events()


def test_streaming_trace_killed_prefix(tmp_path):
    """A stream killed mid-run — open parent span never landed, final line
    torn mid-write — is rejected by the strict validator but accepted via
    --allow-partial, keeping every span that finished."""
    tele = Telemetry()
    path = tmp_path / "killed.jsonl"
    tele.stream_trace(str(path))
    with tele.span("session"):
        with tele.span("round", step=0):
            pass
    # simulate SIGKILL: the still-open session span's close line and the
    # metric events never land; the last write is torn mid-line
    lines = path.read_text().splitlines()
    path.write_text("\n".join(lines[:-1]) + '\n{"type": "span", "id"')
    assert any("unparseable" in e
               for e in tcheck.validate_file(str(path)))
    assert tcheck.validate_file(str(path), allow_partial=True) == []
    assert tcheck.main(["--allow-partial", str(path)]) == 0
    # the surviving events alone still fail strict validation: the round
    # span's parent never closed, so its id is dangling in the prefix
    events = texport.load_events(str(path), allow_partial=True)
    assert any("dangling" in e for e in tcheck.validate_events(events))


def test_streaming_trace_empty_prefix(tmp_path):
    """Killed before the meta line flushed: an empty file is a valid
    partial trace and an invalid complete one."""
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert tcheck.validate_file(str(empty), allow_partial=True) == []
    assert tcheck.validate_file(str(empty)) == ["empty trace: no events"]


def test_prometheus_text_shape():
    r = MetricsRegistry()
    r.inc("wire_bits_total", 64, src="a0", dst='a"1')
    r.set_gauge("budget_exhausted", 0)
    r.observe("span_seconds", 0.25, name="hop")
    text = texport.prometheus_text(r)
    assert '# TYPE wire_bits_total counter' in text
    assert 'wire_bits_total{dst="a\\"1",src="a0"} 64' in text
    assert "span_seconds_count" in text and "span_seconds_sum" in text
    assert tcheck.validate_prometheus(text) == []


# ------------------------------------------- transport ledger bookkeeping
def test_transport_log_snapshot_consistency():
    log = TransportLog()
    log.send_bits("a", "b", "ignorance", 128)
    log.send_bits("a", "b", "ignorance", 64)
    log.send_bits("b", "c", "score_block", 32)
    snap = log.snapshot()
    assert snap["total_bits"] == log.total_bits == 224
    assert snap["hops"] == log.hops == 3
    assert snap["by_kind_link"][("ignorance", "a", "b")] == 192
    assert log.bits_by_kind() == {"ignorance": 192, "score_block": 32}
    assert log.bits_by_src(("ignorance",)) == {"a": 192}
    # derived views always agree with a cold rebuild from the entry list
    rebuilt = TransportLog(entries=list(log.entries))
    assert rebuilt.snapshot() == snap


def test_transport_log_registry_emission():
    r = MetricsRegistry()
    log = TransportLog(registry=r)
    log.send_bits("a", "b", "ignorance", 128)
    log.send_bits("a", "c", "labels", 16)
    assert r.value("wire_bits_total", kind="ignorance", src="a",
                   dst="b") == 128
    assert r.total("messages_total") == 2


# ------------------------------------- serve counter surfaces (back-compat)
def test_serve_surfaces_read_from_shared_registry(blob, tmp_path):
    Xtr, ctr, Xte, k = blob
    proto = Protocol(SessionConfig(num_classes=k, max_rounds=2),
                     transport=MeteredTransport(
                         privacy=GaussianMechanism(epsilon=1.0),
                         serve_codec=make_codec("int8")),
                     backend="compiled")
    proto.fit(jax.random.key(3),
              endpoints_for([LogisticRegression(steps=40) for _ in Xtr],
                            Xtr), ctr)
    tele = Telemetry()
    engine = ServeEngine(
        cache_capacity=1, max_batch=4,
        admission=AdmissionController(AdmissionPolicy(),
                                      tenant_bits=10_000_000,
                                      mechanism=GaussianMechanism(
                                          epsilon=1.0)),
        telemetry=tele, spill_dir=str(tmp_path))
    engine.add_session("s0", proto)
    engine.add_session("s1", proto)
    for i in range(4):
        engine.submit(f"t{i % 2}", f"s{i % 2}", [x[:8] for x in Xte])
    engine.flush()
    summary = engine.summary()
    # one registry feeds every surface; the pre-registry key schemas hold
    counters = engine.admission.counters()
    for t in ("t0", "t1"):
        assert set(counters[t]) == {"served", "degraded", "denied", "bits",
                                    "released"}
        assert counters[t]["served"] == tele.registry.value(
            "admission_outcomes_total", tenant=t, outcome="served") == 2
    cache_stats = engine.cache.stats()
    assert set(cache_stats) >= {"capacity", "resident", "hits", "restores",
                                "spills"}
    assert cache_stats["spills"] == tele.registry.value(
        "cache_events_total", event="spill")
    batch_stats = engine.batcher.stats()
    assert batch_stats["slots_run"] == tele.registry.value(
        "batch_events_total", event="slot") == 4
    assert summary["requests"] == tele.registry.total(
        "serve_requests_total") == 4
    assert tele.registry.total("dp_releases_total") > 0
    assert tele.tracer.well_formed()
    flush_spans = [s.name for s in tele.tracer.spans]
    assert {"flush", "flush_wave", "bucket_dispatch"} <= set(flush_spans)
    engine.close()


def test_standalone_cache_private_registry(tmp_path):
    cache = SessionCache(capacity=1, spill_dir=str(tmp_path))
    assert cache.stats()["hits"] == 0
    assert cache.hits == 0
