"""Protocol-level behaviour: Algorithm 1 end-to-end on small data."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.protocol import (ASCIIConfig, fit, fit_ensemble_adaboost,
                                 fit_single_agent_adaboost)
from repro.core.transport import TransportLog
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3, gaussian_blobs
from repro.learners.logistic import LogisticRegression
from repro.learners.tree import DecisionTree


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=400)
    tr, te = train_test_split(0, 400)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


def _acc(pred, c):
    return float(jnp.mean(pred == c))


def test_ascii_beats_single_and_near_oracle(blob):
    Xtr, ctr, Xte, cte, k = blob
    cfg = ASCIIConfig(num_classes=k, max_rounds=6)
    learners = [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr]
    fitted = fit(jax.random.key(1), Xtr, ctr, learners, cfg)
    acc_ascii = _acc(fitted.predict(Xte), cte)
    single = fit_single_agent_adaboost(jax.random.key(2), Xtr[0], ctr,
                                       learners[0], cfg)
    acc_single = _acc(single.predict([Xte[0]]), cte)
    oracle = fit_single_agent_adaboost(jax.random.key(3),
                                       jnp.concatenate(Xtr, 1), ctr,
                                       DecisionTree(depth=3), cfg)
    acc_oracle = _acc(oracle.predict([jnp.concatenate(Xte, 1)]), cte)
    # the paper's core claims (Fig. 3)
    assert acc_ascii > acc_single + 0.1
    assert acc_ascii > acc_oracle - 0.05


def test_accuracy_improves_with_rounds(blob):
    Xtr, ctr, Xte, cte, k = blob
    cfg = ASCIIConfig(num_classes=k, max_rounds=6)
    learners = [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr]
    fitted = fit(jax.random.key(1), Xtr, ctr, learners, cfg)
    first = _acc(fitted.predict(Xte, max_round=0), cte)
    last = _acc(fitted.predict(Xte), cte)
    assert last >= first


def test_variants_run_and_stop(blob):
    Xtr, ctr, Xte, cte, k = blob
    learners = [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr]
    for variant in ("ascii", "simple", "random", "async"):
        cfg = ASCIIConfig(num_classes=k, max_rounds=3, variant=variant)
        fitted = fit(jax.random.key(4), Xtr, ctr, learners, cfg)
        assert len(fitted.components) > 0
        assert _acc(fitted.predict(Xte), cte) > 1.0 / k  # beats chance


def test_ensemble_adaboost_baseline(blob):
    Xtr, ctr, Xte, cte, k = blob
    learners = [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr]
    cfg = ASCIIConfig(num_classes=k, max_rounds=3)
    ens = fit_ensemble_adaboost(jax.random.key(5), Xtr, ctr, learners, cfg)
    assert _acc(ens.predict(Xte), cte) > 1.0 / k


def test_transport_accounting(blob):
    Xtr, ctr, Xte, cte, k = blob
    learners = [DecisionTree(depth=3, num_thresholds=8) for _ in Xtr]
    cfg = ASCIIConfig(num_classes=k, max_rounds=2,
                      stop_on_negative_alpha=False)
    log = TransportLog()
    fit(jax.random.key(6), Xtr, ctr, learners, cfg, transport=log)
    n = Xtr[0].shape[0]
    m = len(Xtr)
    # setup: labels + ids to M-1 agents; per round: M hops x (n floats + 1)
    expected = (m - 1) * 2 * n * 32 + 2 * m * ((n + 1) * 32)
    assert log.total_bits == expected
    kinds = log.bits_by_kind()
    assert kinds["ignorance"] == 2 * m * n * 32


def test_stop_on_unlearnable_labels():
    """Random labels: weighted acc ~ 1/K <= threshold => early stop."""
    key = jax.random.key(7)
    X = jax.random.normal(key, (200, 2))
    c = jax.random.randint(key, (200,), 0, 8)
    cfg = ASCIIConfig(num_classes=8, max_rounds=10)
    learner = LogisticRegression(steps=50)
    fitted = fit(jax.random.key(8), [X, X], c, [learner, learner], cfg)
    assert fitted.num_rounds < 10  # stopped early (alpha <= 0)


def test_single_agent_is_samme(blob):
    """M=1 ASCII reduces to multi-class AdaBoost: alphas follow eq. (9) and
    components all belong to agent 0."""
    Xtr, ctr, _, _, k = blob
    cfg = ASCIIConfig(num_classes=k, max_rounds=3,
                      stop_on_negative_alpha=False)
    fitted = fit_single_agent_adaboost(jax.random.key(9),
                                       jnp.concatenate(Xtr, 1), ctr,
                                       DecisionTree(depth=3), cfg)
    assert all(c.agent == 0 for c in fitted.components)
    for rec in fitted.history:
        rbar = rec["accs"][0]
        expected = np.log(rbar / (1 - rbar)) + np.log(k - 1)
        np.testing.assert_allclose(rec["alphas"][0],
                                   np.clip(expected, -20, 20), rtol=1e-3)
