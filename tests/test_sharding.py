"""Sharding rules: divisibility-validity for every (arch, mesh), plus a
real lower+compile on a small host-device mesh via subprocess (the 512-way
production dry-run runs separately; see launch/dryrun.py)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHS
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.sharding import rules

# An abstract 16x16 mesh for spec validation only (no devices needed).
from jax.sharding import AbstractMesh

# AbstractMesh takes a ((name, size), ...) shape tuple on this JAX version
# (the old (dims, names) signature was removed).
MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = ARCHS[arch]
    params_shape = jax.eval_shape(
        lambda: api.init_params(jax.random.key(0), cfg))
    specs = rules.param_specs(params_shape, cfg, mesh)
    flat_p = jax.tree.leaves(params_shape)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= len(leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, leaf.shape, tuple(spec))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "qwen3-moe-235b-a22b",
                                  "mamba2-130m", "jamba-v0.1-52b",
                                  "minicpm3-4b"])
def test_cache_specs_divisible(arch):
    cfg = ARCHS[arch]
    shape = INPUT_SHAPES["decode_32k"]
    caches = jax.eval_shape(
        lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
    specs = rules.cache_spec_tree(caches, cfg, MESH, shape.global_batch,
                                  shape.seq_len)
    flat_c = jax.tree.leaves(caches)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_c, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([MESH.shape[a] for a in axes]))
            assert dim % size == 0, (arch, leaf.shape, tuple(spec))


def test_tiny_models_skip_tp():
    assert not rules.use_tp(ARCHS["whisper-tiny"])
    assert not rules.use_tp(ARCHS["mamba2-130m"])
    assert rules.use_tp(ARCHS["gemma-7b"])


def test_production_mesh_shapes():
    # needs >= 512 devices only when actually building; validate shape logic
    # through the abstract path instead
    assert MESH.shape == {"data": 16, "model": 16}
    assert MESH3.shape == {"pod": 2, "data": 16, "model": 16}


@pytest.mark.slow
def test_dryrun_subprocess_smoke():
    """Real lower+compile of one pair through the actual dryrun entrypoint
    (spawns its own process so the 512-device XLA flag stays contained)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "decode_32k", "--tag", "_test"],
        capture_output=True, text=True, env=env, timeout=560)
    assert "OK" in out.stdout, out.stdout + out.stderr
