"""Live in-flight telemetry: streaming taps, SLOs, and the dashboard.

The live plane's contract (PR: live-run telemetry) extends the
observation-only invariant of tests/test_telemetry.py to emission that
happens *while the compiled program runs*:

  * **live-on == live-off** — enabling the in-flight taps changes no
    prediction, ledger entry, or accountant release, on either backend,
    loose or tight budget;
  * **live == replay** — when the program exits, the tap-fed ``live_*``
    counters equal the replay-booked ones (wire bits, messages, skips),
    so the stream was a faithful preview, not an estimate;
  * **eager == compiled** — both backends produce the same live series
    (the sink is commutative, compiled tap order is unordered);
  * fleets and control sweeps stream per-(session, round) taps that sum
    to the single-session series; shard_map fleets refuse live emission;
  * a killed run's streamed trace prefix validates under
    ``--allow-partial`` and still renders a dashboard frame;
  * bucketed quantile estimates land within one bucket of the true order
    statistic; per-tenant SLO burn does the error-budget arithmetic.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import BudgetSpec, BudgetedTransport, GaussianMechanism
from repro.core import compiled
from repro.core.compiled import (compiled_session, control_sweep_run,
                                 fleet_run, plan_for)
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.core.transport import TransportLog
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.logistic import LogisticRegression
from repro.serve import ServeEngine
from repro.telemetry import MetricsRegistry, Telemetry
from repro.telemetry import check as tcheck
from repro.telemetry import dash as tdash
from repro.telemetry.export import SCHEMA, load_events
from repro.telemetry.live import LiveSink, installed
from repro.telemetry.registry import BUCKET_BOUNDS, bucket_index
from repro.telemetry.slo import SLOConfig, SLOTracker

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:          # no install allowed: seeded sweep fallback
    given = None

N_EXAMPLES = 60


def property_seeds(n=N_EXAMPLES):
    """Drive a property from one integer seed: Hypothesis draws (and
    shrinks) it when available, else a fixed seeded sweep."""
    if given is not None:
        def deco(f):
            return settings(max_examples=n, deadline=None)(
                given(seed=st.integers(min_value=0,
                                       max_value=2**63 - 1))(f))
        return deco
    return pytest.mark.parametrize("seed", [2_654_435_761 * i % (2**31)
                                            for i in range(n)])


@pytest.fixture(scope="module")
def blob():
    ds = blob_fig3(jax.random.key(0), n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr], [x[te] for x in Xs],
            ds.num_classes)


LOOSE, TIGHT = 600_000, 20_000


def _fit_serve(blob, backend, telemetry, session_bits=LOOSE):
    Xtr, ctr, Xte, k = blob
    transport = BudgetedTransport(BudgetSpec(session_bits=session_bits),
                                  log=TransportLog(),
                                  privacy=GaussianMechanism(epsilon=1.0))
    proto = Protocol(SessionConfig(num_classes=k, max_rounds=3),
                     transport=transport, backend=backend,
                     telemetry=telemetry)
    eps = endpoints_for([LogisticRegression(steps=40) for _ in Xtr], Xtr)
    proto.fit(jax.random.key(7), eps, ctr)
    preds = np.asarray(proto.predict_distributed(Xte))
    return preds, transport


def _live_series(reg):
    return {name: reg.series(name) for name in reg.counter_names()
            if name.startswith("live_")}


# ----------------------------------------------------- train/serve parity
@pytest.mark.parametrize("backend", ["eager", "compiled"])
@pytest.mark.parametrize("session_bits", [LOOSE, TIGHT])
def test_live_on_off_identical_and_matches_replay(blob, backend,
                                                  session_bits):
    tele = Telemetry(live=True)
    p_on, t_on = _fit_serve(blob, backend, tele, session_bits)
    p_off, t_off = _fit_serve(blob, backend, None, session_bits)
    assert (p_on == p_off).all()
    assert t_on.log.entries == t_off.log.entries
    assert t_on.accountant.releases == t_off.accountant.releases

    reg = tele.registry
    assert (reg.total("live_wire_bits_total")
            == reg.total("wire_bits_total"))
    assert (reg.value("live_messages_total", kind="ignorance")
            == reg.value("messages_total", kind="ignorance"))
    assert (reg.value("live_messages_total", kind="score_block")
            == reg.value("messages_total", kind="score_block"))
    assert (reg.total("live_budget_skips_total")
            == reg.total("budget_skips_total"))
    if session_bits == TIGHT:      # the tight channel must actually skip
        assert reg.total("budget_skips_total") > 0
        assert reg.total("live_exhausted_total") >= 1


@pytest.mark.parametrize("session_bits", [LOOSE, TIGHT])
def test_live_eager_equals_compiled(blob, session_bits):
    series = {}
    for backend in ("eager", "compiled"):
        tele = Telemetry(live=True)
        _fit_serve(blob, backend, tele, session_bits)
        series[backend] = _live_series(tele.registry)
    assert series["eager"] == series["compiled"]
    assert series["eager"]            # and they actually streamed


def test_live_off_emits_nothing(blob):
    tele = Telemetry()
    _fit_serve(blob, "compiled", tele)
    assert _live_series(tele.registry) == {}


# ------------------------------------------------------- fleets and sweeps
def test_fleet_live_matches_dark_and_sums(blob):
    Xtr, ctr, _, k = blob
    plan = plan_for([LogisticRegression(steps=30) for _ in Xtr], k,
                    max_rounds=2)
    keys = jax.random.split(jax.random.key(3), 3)
    dark = fleet_run(plan, keys, Xtr, ctr)

    reg = MetricsRegistry()
    with installed(LiveSink(reg)):
        live = fleet_run(plan, keys, Xtr, ctr, live=True)
    np.testing.assert_array_equal(np.asarray(dark.alphas),
                                  np.asarray(live.alphas))
    np.testing.assert_array_equal(np.asarray(dark.w), np.asarray(live.w))

    singles = 0
    for s in range(3):
        r = MetricsRegistry()
        with installed(LiveSink(r)):
            compiled_session(plan, keys[s], Xtr, ctr, live=True)
        singles += r.total("live_wire_bits_total")
    assert reg.total("live_wire_bits_total") == singles
    assert reg.total("live_rounds_total") == 3 * 2


def test_fleet_live_refuses_shard_map(blob):
    Xtr, ctr, _, k = blob
    plan = plan_for([LogisticRegression(steps=30) for _ in Xtr], k,
                    max_rounds=2)
    keys = jax.random.split(jax.random.key(3), 2)
    with pytest.raises(ValueError, match="shard_map"):
        fleet_run(plan, keys, Xtr, ctr, shard_axis="data", live=True)


def test_control_sweep_live_matches_dark(blob):
    Xtr, ctr, _, k = blob
    plan = plan_for([LogisticRegression(steps=30) for _ in Xtr], k,
                    max_rounds=2, budget=BudgetSpec(session_bits=LOOSE))
    keys = jax.random.split(jax.random.key(5), 2)
    bits = [TIGHT, LOOSE]
    dark = control_sweep_run(plan, keys, Xtr, ctr, session_bits=bits)
    reg = MetricsRegistry()
    with installed(LiveSink(reg)):
        live = control_sweep_run(plan, keys, Xtr, ctr, session_bits=bits,
                                 live=True)
    np.testing.assert_array_equal(np.asarray(dark.alphas),
                                  np.asarray(live.alphas))
    # one tap per (config, executed round): the tight config's post-
    # exhaustion rounds stream as inactive and the sink drops them
    assert (reg.total("live_rounds_total")
            == int(np.asarray(dark.executed).any(-1).sum()))


# ------------------------------------------------------------- serve + SLO
def test_serve_engine_live_taps_and_slo(blob):
    Xtr, ctr, Xte, k = blob
    protos = {}
    for s in range(2):
        proto = Protocol(SessionConfig(num_classes=k, max_rounds=2),
                         transport=MeteredTransport(), backend="compiled")
        proto.fit(jax.random.key(100 + s),
                  endpoints_for([LogisticRegression(steps=30)
                                 for _ in Xtr], Xtr), ctr)
        protos[f"s{s}"] = proto

    tele = Telemetry(live=True)
    engine = ServeEngine(cache_capacity=2, max_batch=4, telemetry=tele,
                         slo=SLOConfig(threshold_s=60.0, objective=0.9))
    for sid, proto in protos.items():
        engine.add_session(sid, proto)
    for rid in range(6):
        engine.submit(f"t{rid % 2}", f"s{rid % 2}",
                      [x[:16] for x in Xte], request=rid)
    engine.flush()

    reg = tele.registry
    assert reg.total("live_serve_requests_total") == 6
    assert reg.total("serve_requests_total") == 6
    for t in ("t0", "t1"):
        hist = reg.histogram("request_seconds", tenant=t)
        assert hist is not None and hist["count"] == 3
        # nothing takes a minute: the generous SLO must be clean
        assert reg.value("slo_requests_total", tenant=t) == 3
        assert reg.value("slo_violations_total", tenant=t) == 0
    slo = engine.summary()["slo"]
    assert slo["objective"] == 0.9
    assert all(v["ok"] for v in slo["tenants"].values())
    engine.close()


class TestSLOTracker:
    def test_burn_arithmetic(self):
        tr = SLOTracker(SLOConfig(threshold_s=0.1, objective=0.9),
                        MetricsRegistry())
        for s in (0.01, 0.01, 0.25, 0.01, 0.01):   # 1 violation / 5
            tr.observe("a", s)
        # budget fraction is 0.1, so 1/5 violations == burn 2.0
        assert tr.burn("a") == pytest.approx(2.0)
        assert tr.report()["tenants"]["a"]["ok"] is False
        assert tr.registry.gauge("slo_burn", tenant="a") == \
            pytest.approx(2.0)

    def test_denial_counts_as_violation(self):
        tr = SLOTracker(SLOConfig(threshold_s=0.1, objective=0.5),
                        MetricsRegistry())
        tr.observe("a", 0.01)
        tr.record_denial("a")
        assert tr.registry.value("slo_requests_total", tenant="a") == 2
        assert tr.registry.value("slo_violations_total", tenant="a") == 1
        assert tr.burn("a") == pytest.approx(1.0)

    def test_unseen_tenant_burns_nothing(self):
        tr = SLOTracker(SLOConfig(), MetricsRegistry())
        assert tr.burn("ghost") == 0.0
        assert tr.report()["tenants"] == {}

    @pytest.mark.parametrize("kw", [{"threshold_s": 0.0},
                                    {"threshold_s": -1.0},
                                    {"objective": 0.0},
                                    {"objective": 1.0}])
    def test_config_validation(self, kw):
        with pytest.raises(ValueError):
            SLOConfig(**kw)


# ----------------------------------------------------- quantile estimation
@property_seeds()
def test_quantile_within_one_bucket(seed):
    """The bucketed estimate of any quantile lands in the true order
    statistic's bucket or an adjacent one — the histogram's resolution
    bound, for arbitrary positive samples across the bucket range."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 200))
    xs = np.exp(rng.uniform(np.log(BUCKET_BOUNDS[0]),
                            np.log(BUCKET_BOUNDS[-1]), size=n))
    reg = MetricsRegistry()
    for x in xs:
        reg.observe("lat", float(x))
    for q in (0.5, 0.9, 0.99):
        est = reg.quantile("lat", q)
        true = float(np.sort(xs)[min(n - 1, int(np.ceil(q * n)) - 1)])
        assert est is not None
        assert abs(bucket_index(est) - bucket_index(true)) <= 1, \
            f"q={q}: estimate {est} vs order statistic {true}"


# ------------------------------------------- killed runs and the dashboard
def _streamed_live_trace(blob, path):
    tele = Telemetry(live=True)
    tele.stream_trace(str(path))
    _fit_serve(blob, "compiled", tele, TIGHT)
    return tele


def test_killed_live_trace_validates_and_renders(blob, tmp_path, capsys):
    path = tmp_path / "trace.jsonl"
    _streamed_live_trace(blob, path)        # never sealed == killed run
    lines = path.read_text().splitlines()
    live_lines = [ln for ln in lines if '"type": "live"' in ln]
    assert live_lines, "live events must stream before the seal"
    # tear the final line mid-write, as a kill would
    torn = tmp_path / "torn.jsonl"
    torn.write_text("\n".join(lines[:-1]) + "\n" + lines[-1][:10])

    assert tcheck.validate_file(str(torn), allow_partial=True) == []
    assert tcheck.main([str(torn), "--allow-partial"]) == 0
    assert tcheck.main([str(torn)]) == 1
    capsys.readouterr()

    # the dashboard CLI renders a frame from the prefix and exits clean
    assert tdash.main([str(torn)]) == 0
    out = capsys.readouterr()
    frame = out.out + out.err
    assert "live events" in frame
    assert "round" in frame


def test_dashboard_render_sections(blob):
    tele = Telemetry(live=True)
    _fit_serve(blob, "compiled", tele, TIGHT)
    tele.registry.observe("request_seconds", 0.003, tenant="t0")
    sink = tele.live
    frame = tdash.render(tele.registry, sink=sink, title="unit")
    assert "unit" in frame
    assert "wire" in frame
    assert "p50" in frame and "p99" in frame
    assert "skips" in frame


def test_dashboard_events_drive_draw(tmp_path):
    import io
    reg = MetricsRegistry()
    stream = io.StringIO()
    dash = tdash.Dashboard(reg, title="t", min_interval=0.0,
                           stream=stream)
    sink = LiveSink(reg)
    dash.attach(sink)
    sink.round_tap(0, 128, 2, 0, 0)
    sink.serve_tap(64, 1, 0)
    dash.final()
    text = stream.getvalue()
    assert "t" in text and "wire" in text
    assert reg.total("live_rounds_total") == 1


# ------------------------------------------------------------ trace schema
def _meta(version):
    return {"type": "meta", "schema": SCHEMA, "version": version}


def test_v1_traces_still_validate():
    events = [_meta(1),
              {"type": "counter", "name": "wire_bits_total",
               "labels": {}, "value": 10}]
    assert tcheck.validate_events(events) == []


def test_live_events_rejected_in_v1_accepted_in_v2():
    live = {"type": "live", "tag": "round", "t": 0, "bits": 1,
            "sent": 1, "skipped": 0, "exhausted": 0, "t_s": 0.0}
    assert any("v1" in e
               for e in tcheck.validate_events([_meta(1), live]))
    assert tcheck.validate_events([_meta(2), live]) == []


def test_live_event_requires_tag():
    bad = {"type": "live", "bits": 1}
    errs = tcheck.validate_events([_meta(2), bad])
    assert any("tag" in e for e in errs)


def test_streamed_trace_reloads_equal_registry(blob, tmp_path):
    path = tmp_path / "trace.jsonl"
    tele = _streamed_live_trace(blob, path)
    tele.write_artifacts(trace=str(path))    # seal: registry + spans
    events = load_events(str(path))
    assert events[0]["version"] == 2
    reloaded = MetricsRegistry.from_events(
        [e for e in events if e["type"] in
         ("counter", "gauge", "histogram")])
    for name in tele.registry.counter_names():
        assert reloaded.series(name) == tele.registry.series(name)
