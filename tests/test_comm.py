"""Wire-format subsystem units: codec encode/decode/roundtrip properties
(hypothesis: quantize kernel vs host reference across dtypes and tilings),
top-k error feedback, the Gaussian mechanism + accountant, budget specs,
and the hardened TransportLog."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (BudgetSpec, GaussianMechanism, PrivacyAccountant,
                        make_codec)
from repro.comm.budget import MODEL_WEIGHT_BITS
from repro.comm.codecs import (Fp16Codec, Fp32Codec, QuantCodec, TopKCodec,
                               quant_bits_per_element)
from repro.core.transport import TransportLog
from repro.kernels import ops, ref


# ===================================================== quantize kernel vs ref
def _x(n, dtype, seed):
    key = jax.random.key(seed)
    return (jax.random.dirichlet(key, jnp.ones(n)) * 0.5).astype(dtype)


def test_kernel_matches_reference_grid():
    """The fused Pallas quantize-dequant equals the host reference bit for
    bit at every tiling regime (sub-tile, exact tile, multi-tile), input
    dtype, and quantization width — no hypothesis dependency needed for the
    core pin."""
    for n in (4, 64, 257, 1024, 2048):
        for dtype in (jnp.float32, jnp.bfloat16):
            for qmax in (127.0, 7.0):
                x = _x(n, dtype, n)
                u = jax.random.uniform(jax.random.key(n + 1), (n,))
                out_k = ops.quantize_dequant(x, u, qmax)
                out_r = ref.quantize_dequant(x, u, qmax)
                for a, b in zip(out_k, out_r):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))


def _block(n, k, dtype, seed):
    key = jax.random.key(seed)
    return (jax.random.normal(key, (n, k)) * 0.5).astype(dtype)


def test_block_kernel_matches_reference_grid():
    """The row-major 2-D quantize-dequant (ScoreBlockMsg payloads) equals
    the host reference bit for bit at every tiling regime — sub-tile (one
    global scale), exact row tiles, odd row counts — input dtype, and
    quantization width."""
    for (n, k) in ((4, 3), (60, 8), (128, 8), (257, 5), (1024, 8)):
        for dtype in (jnp.float32, jnp.bfloat16):
            for qmax in (127.0, 7.0):
                x = _block(n, k, dtype, n + k)
                u = jax.random.uniform(jax.random.key(n + 1), (n, k))
                out_k = ops.quantize_dequant_block(x, u, qmax)
                out_r = ref.quantize_dequant_block(x, u, qmax)
                for a, b in zip(out_k, out_r):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))


try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SHAPES = st.sampled_from([4, 64, 257, 1024, 2048])
    DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])
    QMAXES = st.sampled_from([127.0, 31.0, 7.0])

    @given(n=SHAPES, dtype=DTYPES, qmax=QMAXES, seed=st.integers(0, 99))
    @settings(max_examples=25, deadline=None)
    def test_kernel_matches_reference_prop(n, dtype, qmax, seed):
        """Property form of the kernel-vs-reference pin, plus the
        quantization-error bound: |xhat - x| <= one step (stochastic
        rounding moves at most one level past floor)."""
        x = _x(n, dtype, seed)
        u = jax.random.uniform(jax.random.key(seed + 1), (n,))
        xh_k, q_k, s_k = ops.quantize_dequant(x, u, qmax)
        xh_r, q_r, s_r = ref.quantize_dequant(x, u, qmax)
        np.testing.assert_array_equal(np.asarray(xh_k), np.asarray(xh_r))
        np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
        np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
        step = np.repeat(np.asarray(s_k), n // s_k.shape[0])
        err = np.abs(np.asarray(xh_k) - np.asarray(x, np.float32))
        assert (err <= step * (1 + 1e-5)).all()

    @given(n=SHAPES, dtype=DTYPES, bits=st.sampled_from([8, 4]),
           seed=st.integers(0, 99))
    @settings(max_examples=25, deadline=None)
    def test_quant_roundtrip_equals_encode_decode(n, dtype, bits, seed):
        """QuantCodec.roundtrip (fused kernel) == decode(encode(x)) (host
        wire halves) bit for bit — the codec contract."""
        codec = QuantCodec(bits=bits)
        x = _x(n, dtype, seed).astype(jnp.float32)
        key = jax.random.key(seed)
        fused, _ = codec.roundtrip(x, key)
        wire, _ = codec.encode(x, key)
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(codec.decode(wire)))

    @given(n=SHAPES, seed=st.integers(0, 99),
           frac=st.sampled_from([0.1, 0.25, 0.5]))
    @settings(max_examples=25, deadline=None)
    def test_topk_error_feedback_invariant(n, seed, frac):
        """decode(wire) + new_residual == x + old_residual exactly: the
        channel defers mass, never loses it."""
        codec = TopKCodec(fraction=frac)
        x = _x(n, jnp.float32, seed)
        resid = jax.random.normal(jax.random.key(seed + 7), (n,)) * 0.01
        wire, new_resid = codec.encode(x, state=resid)
        np.testing.assert_allclose(
            np.asarray(codec.decode(wire) + new_resid),
            np.asarray(x + resid), rtol=1e-6, atol=1e-7)

    # -------------------------------------------- 2-D score-block properties
    BLOCK_NS = st.sampled_from([4, 60, 128, 257, 1024])
    BLOCK_KS = st.sampled_from([2, 3, 8])

    @given(n=BLOCK_NS, k=BLOCK_KS, dtype=DTYPES, qmax=QMAXES,
           seed=st.integers(0, 99))
    @settings(max_examples=25, deadline=None)
    def test_block_kernel_matches_reference_prop(n, k, dtype, qmax, seed):
        """Property form of the 2-D kernel-vs-reference pin, plus the
        quantization-error bound: |xhat - x| <= one step of the row-tile
        the element lives in."""
        x = _block(n, k, dtype, seed)
        u = jax.random.uniform(jax.random.key(seed + 1), (n, k))
        out_k = ops.quantize_dequant_block(x, u, qmax)
        out_r = ref.quantize_dequant_block(x, u, qmax)
        for a, b in zip(out_k, out_r):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        xh, _, scales = out_k
        step = np.repeat(np.asarray(scales), n // scales.shape[0])[:, None]
        err = np.abs(np.asarray(xh) - np.asarray(x, np.float32))
        assert (err <= step * (1 + 1e-5)).all()

    @given(n=BLOCK_NS, k=BLOCK_KS, seed=st.integers(0, 99),
           name=st.sampled_from(["fp32", "fp16", "int8", "int4", "topk"]))
    @settings(max_examples=25, deadline=None)
    def test_codec_block_shape_dtype_preserved(n, k, seed, name):
        """Every codec, fed an [n, K] block: encode/decode reconstructs to
        the original shape in float32, and roundtrip == decode(encode(x))
        (the serve-path codec contract)."""
        codec = make_codec(name)
        x = _block(n, k, jnp.float32, seed)
        key = jax.random.key(seed)
        wire, state = codec.encode(x, key)
        dec = codec.decode(wire)
        assert dec.shape == (n, k) and dec.dtype == jnp.float32
        fused, _ = codec.roundtrip(x, key)
        assert fused.shape == (n, k) and fused.dtype == jnp.float32
        if not codec.stateful:          # fresh top-k state differs per call
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(dec))
        # int codecs: quantization error bounded by the tile step size; the
        # int4 wire is a packed 4-bit carrier (plus the original shape)
        if isinstance(codec, QuantCodec):
            if codec.bits == 4:
                packed, scales, shape = wire
                assert shape == (n, k)
                assert packed.shape[0] == (n * k + 1) // 2
                assert packed.dtype == jnp.int8
            else:
                q, scales = wire
            step = np.repeat(np.asarray(scales),
                             n // scales.shape[0])[:, None]
            err = np.abs(np.asarray(fused) - np.asarray(x, np.float32))
            assert (err <= step * (1 + 1e-5)).all()

    @given(n=st.sampled_from([16, 60, 257]), k=BLOCK_KS,
           seed=st.integers(0, 99), frac=st.sampled_from([0.1, 0.25]))
    @settings(max_examples=25, deadline=None)
    def test_topk_block_residual_carry_over_rounds(n, k, seed, frac):
        """Error feedback telescopes across serve rounds on [n, K] blocks:
        sum_t decode_t + final_residual == sum_t x_t + initial_residual —
        deferred mass is carried, round after round, never dropped."""
        codec = TopKCodec(fraction=frac)
        keys = jax.random.split(jax.random.key(seed), 3)
        xs = [_block(n, k, jnp.float32, seed + 11 * t) for t in range(3)]
        resid = codec.init_state((n, k))
        shipped = jnp.zeros((n, k), jnp.float32)
        for t, x in enumerate(xs):
            wire, resid = codec.encode(x, keys[t], state=resid)
            assert resid.shape == (n, k)
            shipped = shipped + codec.decode(wire)
        np.testing.assert_allclose(
            np.asarray(shipped + resid),
            np.asarray(sum(xs)), rtol=1e-5, atol=1e-6)


def test_pack_int4_kernel_matches_reference_grid():
    """The int4 pack/unpack Pallas pass equals the host reference bit for
    bit and round-trips exactly — at even sizes, odd sizes (padded high
    nibble), multi-tile sizes, and the full nibble range [-8, 7]."""
    rng = np.random.default_rng(0)
    for n in (2, 7, 64, 257, 1024, 2048, 4096):
        q = jnp.asarray(rng.integers(-8, 8, n), jnp.int8)
        p_k = ops.pack_int4(q)
        p_r = ref.pack_int4(q)
        np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
        assert p_k.shape == ((n + 1) // 2,) and p_k.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(ops.unpack_int4(p_k, n)),
                                      np.asarray(q))
        np.testing.assert_array_equal(np.asarray(ref.unpack_int4(p_r, n)),
                                      np.asarray(q))
    # every nibble value survives the trip
    q = jnp.asarray(np.arange(-8, 8), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(ops.unpack_int4(ops.pack_int4(q), 16)), np.asarray(q))
    # 2-D payloads flatten row-major
    q2 = jnp.asarray(rng.integers(-8, 8, (60, 3)), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(ops.unpack_int4(ops.pack_int4(q2), 180).reshape(60, 3)),
        np.asarray(q2))
    with pytest.raises(ValueError, match="cannot hold"):
        ops.unpack_int4(jnp.zeros((3,), jnp.int8), 100)


def test_int4_codec_wire_is_packed():
    """The int4 codec's wire array is a real 4-bit carrier: ceil(m/2) int8
    bytes, decode unpacks losslessly, and decode(encode(x)) still equals
    the fused kernel roundtrip."""
    codec = QuantCodec(bits=4)
    for n in (64, 257, 600):
        x = _x(n, jnp.float32, n)
        key = jax.random.key(n)
        (packed, scales, shape), _ = codec.encode(x, key)
        assert packed.shape == ((n + 1) // 2,) and packed.dtype == jnp.int8
        assert shape == (n,)
        fused, _ = codec.roundtrip(x, key)
        np.testing.assert_array_equal(
            np.asarray(fused),
            np.asarray(codec.decode((packed, scales, shape))))
    # int8 stays an unpacked (q, scales) wire
    wire8, _ = QuantCodec(bits=8).encode(_x(64, jnp.float32, 1),
                                         jax.random.key(0))
    q8, _ = wire8
    assert q8.shape == (64,)


def test_stochastic_rounding_unbiased():
    """E[dequant] over rounding draws approaches x (the reason int8 wires
    survive many hops where deterministic rounding collapses)."""
    n, reps = 256, 400
    x = _x(n, jnp.float32, 0)
    codec = QuantCodec(bits=8)
    keys = jax.random.split(jax.random.key(1), reps)
    outs = jax.vmap(lambda k: codec.roundtrip(x, k)[0])(keys)
    mean = np.asarray(jnp.mean(outs, axis=0))
    scale = float(jnp.max(jnp.abs(x))) / codec.qmax
    np.testing.assert_allclose(mean, np.asarray(x), atol=0.15 * scale)


def test_wire_bits_formulas():
    n = 600
    assert Fp32Codec().wire_bits(n) == 32 * n
    assert Fp16Codec().wire_bits(n) == 16 * n
    assert QuantCodec(bits=8).wire_bits(n) == 8 * n + 32      # one tile
    assert QuantCodec(bits=4).wire_bits(n) == 4 * n + 32
    assert QuantCodec(bits=8).wire_bits(2048) == 8 * 2048 + 2 * 32
    # int4 prices whole packed wire bytes: odd element counts round up to
    # the padded nibble, even counts reduce to the nominal 4 bits/element
    assert QuantCodec(bits=4).wire_bits(257) == 8 * 129 + 32
    assert QuantCodec(bits=8).wire_bits(257) == 8 * 257 + 32
    k = TopKCodec(fraction=0.25).k_for(n)
    assert TopKCodec(fraction=0.25).wire_bits(n) == k * (32 + 10)  # log2(600)
    assert quant_bits_per_element(127) == 8
    assert quant_bits_per_element(7) == 4


def test_wire_bits_formulas_2d():
    """Score-block wire sizes: elementwise codecs scale by n*K; the quant
    codecs add one fp32 scale per row tile (rows_for: ~1024 elements per
    tile when the row count divides evenly, else one global tile)."""
    from repro.kernels.quantize import rows_for
    shape = (600, 8)                     # 4800 elements
    assert Fp32Codec().wire_bits(shape) == 32 * 4800
    assert Fp16Codec().wire_bits(shape) == 16 * 4800
    # 600 rows of k=8: 1024 // 8 = 128-row tiles don't divide 600 -> one
    # global tile, a single fp32 scale
    assert rows_for(600, 8) == 600
    assert QuantCodec(bits=8).wire_bits(shape) == 8 * 4800 + 32
    assert QuantCodec(bits=4).wire_bits(shape) == 4 * 4800 + 32
    # 1024 rows of k=8 tile into 8 row groups of 128 -> 8 scales
    assert rows_for(1024, 8) == 128
    assert QuantCodec(bits=8).wire_bits((1024, 8)) == 8 * 8192 + 8 * 32
    # top-k flattens: k_for and index width follow the element count
    t = TopKCodec(fraction=0.25)
    assert t.k_for(4800) == 1200
    assert t.wire_bits(shape) == 1200 * (32 + 13)      # ceil(log2(4800))


def test_codec_registry():
    assert isinstance(make_codec("int8"), QuantCodec)
    assert make_codec("int4").bits == 4
    assert isinstance(make_codec("topk"), TopKCodec)
    with pytest.raises(ValueError, match="unknown codec"):
        make_codec("zstd")


def test_fp16_codec_roundtrip_is_half_precision():
    x = _x(257, jnp.float32, 3)
    out, _ = Fp16Codec().roundtrip(x)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(x, np.float16).astype(np.float32))


# ==================================================================== privacy
def test_gaussian_mechanism_calibration_and_clip():
    mech = GaussianMechanism(epsilon=2.0, delta=1e-5, clip=0.5)
    assert mech.sigma == pytest.approx(
        0.5 * np.sqrt(2 * np.log(1.25 / 1e-5)) / 2.0)
    x = jnp.full((64,), 10.0)          # norm 80 >> clip
    out = mech.apply(x, jax.random.key(0))
    assert float(jnp.min(out)) >= 0.0  # clamped (post-processing)
    # determinism per key, fresh noise per key
    out2 = mech.apply(x, jax.random.key(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    out3 = mech.apply(x, jax.random.key(1))
    assert np.abs(np.asarray(out) - np.asarray(out3)).max() > 0


def test_gaussian_mechanism_validation():
    for bad in (dict(epsilon=0.0), dict(delta=0.0), dict(delta=1.5),
                dict(clip=-1.0)):
        with pytest.raises(ValueError):
            GaussianMechanism(**bad)


def test_privacy_accountant_composition():
    mech = GaussianMechanism(epsilon=0.5, delta=1e-6)
    acct = PrivacyAccountant()
    for _ in range(3):
        acct.record("agent0")
    acct.record("agent1")
    assert acct.spent("agent0", mech) == pytest.approx((1.5, 3e-6))
    assert acct.spent("agent2", mech) == (0.0, 0.0)
    rep = acct.report(mech)
    assert list(rep) == ["agent0", "agent1"]          # deterministic order
    assert rep["agent0"]["releases"] == 3


# ===================================================================== budget
def test_budget_spec_validation():
    with pytest.raises(ValueError, match="at least one"):
        BudgetSpec(ladder=())
    with pytest.raises(ValueError, match="stateless"):
        BudgetSpec(ladder=(TopKCodec(),))
    with pytest.raises(ValueError, match="positive"):
        BudgetSpec(session_bits=0)


def test_budget_choose_rule():
    spec = BudgetSpec(session_bits=10 ** 9)
    n = 100
    costs = spec.hop_costs(n)
    assert costs[0] == 32 * n + MODEL_WEIGHT_BITS
    assert list(costs) == sorted(costs, reverse=True)   # ladder degrades
    assert spec.choose(n, float("inf"), float("inf")) == 0
    # only the cheapest rung affordable
    assert spec.choose(n, costs[-1], float("inf")) == len(costs) - 1
    # nothing affordable -> skip
    assert spec.choose(n, costs[-1] - 1, float("inf")) is None
    # the link cap binds too
    assert spec.choose(n, float("inf"), costs[-1]) == len(costs) - 1


# =============================================================== TransportLog
def test_transport_log_rejects_bad_counts():
    log = TransportLog()
    with pytest.raises(ValueError, match=">= 0"):
        log.send("a", "b", "ignorance", -1)
    with pytest.raises(TypeError, match="integer"):
        log.send("a", "b", "ignorance", 2.5)
    with pytest.raises(TypeError, match="integer"):
        log.send("a", "b", "ignorance", True)
    with pytest.raises(ValueError, match=">= 0"):
        log.send_bits("a", "b", "ignorance", -8)
    with pytest.raises(TypeError, match="integer"):
        log.send_bits("a", "b", "ignorance", 8.0)
    assert log.entries == []                  # nothing booked on rejection
    log.send("a", "b", "ignorance", np.int64(4), 32)   # np ints are fine
    assert log.total_bits == 128


def test_transport_log_bits_by_kind_deterministic_order():
    log = TransportLog()
    log.send("a", "b", "score_block", 2)
    log.send("a", "b", "ignorance", 4)
    log.send_bits("a", "b", "model_weight", 32)
    log.send("a", "b", "ignorance", 1)
    kinds = log.bits_by_kind()
    assert list(kinds) == sorted(kinds)       # name-ordered, JSON-diff-stable
    assert kinds["ignorance"] == 5 * 32
    assert sum(kinds.values()) == log.total_bits
