"""Per-arch smoke tests: REDUCED variant of every assigned architecture,
one forward + one weighted train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models import api
from repro.optim.optimizers import adamw

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        batch["patch_emb"] = jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model))
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.encoder_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_and_train_step(arch, key):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers <= max(2, len(cfg.layer_pattern or ())) and \
        cfg.d_model <= 512 and (cfg.num_experts or 0) <= 4
    params = api.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, caches, aux = api.forward(params, batch, cfg)
    s_total = S + (cfg.num_frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (B, s_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = adamw(1e-3)
    step = jax.jit(api.make_train_step(cfg, opt))
    batch["sample_weight"] = jnp.asarray([0.25, 0.75])  # ignorance weights
    params2, _, metrics = step(params, opt.init(params), batch,
                               jnp.zeros((), jnp.int32))
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(changed))


def test_weighted_loss_respects_ignorance(key):
    """Zero ignorance weight on a sample removes it from the loss (WST)."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    params = api.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, _, _ = api.forward(params, batch, cfg)
    from repro.models.api import weighted_next_token_loss
    l_a = weighted_next_token_loss(
        logits, {**batch, "sample_weight": jnp.asarray([1.0, 0.0])}, cfg)
    # loss over sample 0 alone equals the weighted loss with w=[1,0]
    b0 = {k: v[:1] for k, v in batch.items()}
    logits0, _, _ = api.forward(params, b0, cfg)
    l_b = weighted_next_token_loss(logits0, b0, cfg)
    assert abs(float(l_a) - float(l_b)) < 1e-4
