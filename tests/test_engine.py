"""Engine-level behaviour: transport parity, session checkpoint/resume,
agent dropout and late joins, and distributed score-block prediction."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (AgentEndpoint, InProcessTransport,
                               MeshRingTransport, MeteredTransport, Protocol,
                               RandomScheduler, SequentialScheduler,
                               SessionConfig, SessionState, endpoints_for,
                               variant_setup)
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.tree import DecisionTree


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=300)
    tr, te = train_test_split(0, 300)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


def _endpoints(Xtr):
    return endpoints_for([DecisionTree(depth=3, num_thresholds=8)
                          for _ in Xtr], Xtr)


def _cfg(k, rounds=3, **kw):
    return SessionConfig(num_classes=k, max_rounds=rounds, **kw)


# ------------------------------------------------------------------ transports
def test_transport_parity_inprocess_vs_metered(blob):
    """The byte-metered simulator and the plain in-process transport must be
    bit-identical: metering is passive."""
    Xtr, ctr, Xte, cte, k = blob
    runs = {}
    for name, transport in [("plain", InProcessTransport()),
                            ("metered", MeteredTransport())]:
        session = Protocol(_cfg(k), transport=transport).start(
            jax.random.key(2), _endpoints(Xtr), ctr)
        session.run()
        runs[name] = session
    a, b = runs["plain"], runs["metered"]
    np.testing.assert_array_equal(np.asarray(a.state.w),
                                  np.asarray(b.state.w))
    assert [(c.agent, c.round, c.alpha) for c in a.state.components] == \
           [(c.agent, c.round, c.alpha) for c in b.state.components]
    assert a.state.history == b.state.history
    np.testing.assert_array_equal(np.asarray(a.fitted().predict(Xte)),
                                  np.asarray(b.fitted().predict(Xte)))


def test_metered_totals_match_fig4_accounting(blob):
    """Engine-metered totals reproduce the Fig. 4 formula: one-time
    (labels + sample IDs) to M-1 agents, then (n + 1) floats per hop, one
    hop per appended component."""
    Xtr, ctr, _, _, k = blob
    transport = MeteredTransport()
    session = Protocol(_cfg(k, rounds=2, stop_on_negative_alpha=False),
                       transport=transport).start(
        jax.random.key(6), _endpoints(Xtr), ctr)
    session.run()
    n = Xtr[0].shape[0]
    m = len(Xtr)
    hops = len(session.state.components)
    expected = (m - 1) * 2 * n * 32 + hops * (n + 1) * 32
    assert transport.total_bits == expected
    kinds = transport.bits_by_kind()
    assert kinds["ignorance"] == hops * n * 32
    assert kinds["model_weight"] == hops * 32
    assert kinds["labels"] == (m - 1) * n * 32


def test_mesh_ring_transport_matches_host(blob):
    """The device-kernel hop (Pallas ignorance_update) behind the same
    Transport interface tracks the host trajectory."""
    Xtr, ctr, Xte, cte, k = blob
    host = Protocol(_cfg(k), transport=InProcessTransport()).start(
        jax.random.key(2), _endpoints(Xtr), ctr)
    host.run()
    ring = Protocol(_cfg(k), transport=MeshRingTransport()).start(
        jax.random.key(2), _endpoints(Xtr), ctr)
    ring.run()
    np.testing.assert_allclose(
        np.asarray([c.alpha for c in ring.state.components]),
        np.asarray([c.alpha for c in host.state.components]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ring.state.w),
                               np.asarray(host.state.w), atol=1e-6)
    agree = float(jnp.mean(ring.fitted().predict(Xte)
                           == host.fitted().predict(Xte)))
    assert agree > 0.99


_RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.engine import MeshRingTransport
    from repro.core import scores

    mesh = jax.make_mesh((4, 2), ("agent", "data"))
    M, n = 4, 64
    key = jax.random.key(0)
    w = jax.random.dirichlet(key, jnp.ones(n))
    ws = jnp.tile(w[None], (M, 1))
    r = (jax.random.uniform(jax.random.fold_in(key, 1), (M, n)) > 0.4
         ).astype(jnp.float32)
    alpha = jnp.asarray([0.5, 1.0, 1.5, 2.0])
    out = MeshRingTransport(mesh).ring_step(ws, r, alpha)
    ref = jnp.stack([scores.ignorance_update(ws[m], r[m], alpha[m])
                     for m in range(M)])
    ref = jnp.roll(ref, 1, axis=0)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-6, err
    print("ENGINE_RING_OK", err)
""")


@pytest.mark.slow
def test_mesh_ring_collective_step():
    """ring_step on a real (host-device) mesh: one shard_map'd ppermute hop
    delivers agent m's updated score to agent m+1."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _RING_SCRIPT],
                         capture_output=True, text=True, env=env, timeout=560)
    assert "ENGINE_RING_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------- checkpoint / resume
@pytest.mark.parametrize("scheduler_fn", [
    lambda: SequentialScheduler(), lambda: RandomScheduler(seed=3)],
    ids=["sequential", "random"])
def test_checkpoint_and_resume_identical(blob, tmp_path, scheduler_fn):
    """Save mid-run, resume in a fresh session: identical final state and
    predictions (PRNG key is part of SessionState; scheduler RNG
    fast-forwards)."""
    Xtr, ctr, Xte, cte, k = blob
    cfg = _cfg(k, rounds=4)

    full = Protocol(cfg, scheduler=scheduler_fn()).start(
        jax.random.key(9), _endpoints(Xtr), ctr)
    full.run()

    part = Protocol(cfg, scheduler=scheduler_fn()).start(
        jax.random.key(9), _endpoints(Xtr), ctr)
    part.step()
    part.step()
    ckpt_dir = str(tmp_path / "sess")
    part.checkpoint(ckpt_dir)

    resumed = Protocol(cfg, scheduler=scheduler_fn()).resume(
        ckpt_dir, _endpoints(Xtr), ctr)
    assert resumed.state.round == 2
    resumed.run()

    assert [(c.agent, c.round, c.alpha) for c in resumed.state.components] == \
           [(c.agent, c.round, c.alpha) for c in full.state.components]
    assert resumed.state.history == full.state.history
    np.testing.assert_array_equal(np.asarray(resumed.state.w),
                                  np.asarray(full.state.w))
    np.testing.assert_array_equal(np.asarray(resumed.fitted().predict(Xte)),
                                  np.asarray(full.fitted().predict(Xte)))


def test_checkpoint_resume_exact_with_dropout(blob, tmp_path):
    """Resume stays bit-identical even when the active set changed mid-run:
    the scheduler RNG replays with the recorded per-round active counts and
    endpoint active flags are part of the checkpoint."""
    Xtr, ctr, Xte, cte, k = blob
    cfg = _cfg(k, rounds=5, stop_on_negative_alpha=False)

    def run(resume_dir=None):
        session = Protocol(cfg, scheduler=RandomScheduler(seed=3)).start(
            jax.random.key(9), _endpoints(Xtr), ctr)
        session.step()
        session.endpoints[1].active = False     # dropout after round 0
        session.step()
        if resume_dir is not None:
            session.checkpoint(resume_dir)
            session = Protocol(cfg, scheduler=RandomScheduler(seed=3)).resume(
                resume_dir, _endpoints(Xtr), ctr)
            assert not session.endpoints[1].active   # flag restored
        session.run()
        return session

    full = run()
    resumed = run(str(tmp_path / "churn"))
    assert resumed.state.history == full.state.history
    assert [(c.agent, c.round, c.alpha) for c in resumed.state.components] \
        == [(c.agent, c.round, c.alpha) for c in full.state.components]
    np.testing.assert_array_equal(np.asarray(resumed.fitted().predict(Xte)),
                                  np.asarray(full.fitted().predict(Xte)))


def test_all_agents_dropped_stops_session(blob):
    Xtr, ctr, _, _, k = blob
    session = Protocol(_cfg(k, rounds=5)).start(jax.random.key(1),
                                                _endpoints(Xtr), ctr)
    session.step()
    for ep in session.endpoints:
        ep.active = False
    rounds_before = session.state.round
    session.run()
    assert session.state.stopped
    assert session.state.round == rounds_before   # no empty spin rounds


def test_session_state_roundtrip(blob, tmp_path):
    Xtr, ctr, _, _, k = blob
    session = Protocol(_cfg(k, rounds=2)).start(jax.random.key(1),
                                                _endpoints(Xtr), ctr)
    session.run()
    st = session.state
    st.save(str(tmp_path))
    back = SessionState.restore(str(tmp_path))
    np.testing.assert_array_equal(np.asarray(back.w), np.asarray(st.w))
    np.testing.assert_array_equal(jax.random.key_data(back.key),
                                  jax.random.key_data(st.key))
    assert back.round == st.round and back.stopped == st.stopped
    assert [(c.agent, c.round, c.alpha) for c in back.components] == \
           [(c.agent, c.round, c.alpha) for c in st.components]
    for cb, cs in zip(back.components, st.components):
        for lb, ls in zip(jax.tree.leaves(cb.params),
                          jax.tree.leaves(cs.params)):
            np.testing.assert_array_equal(np.asarray(lb), np.asarray(ls))


# ------------------------------------------------------- dropout and late joins
def test_agent_dropout_mid_session(blob):
    """An endpoint going inactive mid-session: later rounds run without it,
    its earlier components stay in the ensemble, training continues."""
    Xtr, ctr, Xte, cte, k = blob
    session = Protocol(_cfg(k, rounds=4, stop_on_negative_alpha=False)).start(
        jax.random.key(4), _endpoints(Xtr), ctr)
    session.step()
    dropped = session.endpoints[1]
    dropped.active = False
    session.run()
    comps = session.state.components
    assert any(c.agent == 1 and c.round == 0 for c in comps)
    assert not any(c.agent == 1 and c.round >= 1 for c in comps)
    assert any(c.agent == 0 and c.round >= 1 for c in comps)
    acc = float(jnp.mean(session.fitted().predict(Xte) == cte))
    assert acc > 1.0 / k


def test_late_join(blob):
    """A fresh endpoint joins a live session after round 0: it receives the
    collation setup and contributes components from the next round."""
    Xtr, ctr, Xte, cte, k = blob
    transport = MeteredTransport()
    session = Protocol(_cfg(k, rounds=4, stop_on_negative_alpha=False),
                       transport=transport).start(
        jax.random.key(8), _endpoints(Xtr[:2]), ctr)
    session.step()
    newcomer = session.add_endpoint(DecisionTree(depth=3, num_thresholds=8),
                                    Xtr[2])
    assert newcomer.latest("labels") is not None        # got collation setup
    session.run()
    comps = session.state.components
    assert not any(c.agent == 2 and c.round == 0 for c in comps)
    assert any(c.agent == 2 and c.round >= 1 for c in comps)
    acc = float(jnp.mean(session.fitted().predict(Xte) == cte))
    assert acc > 1.0 / k


# -------------------------------------------------- score-block prediction path
def test_distributed_prediction_matches_host(blob):
    """predict_distributed (endpoints shipping ScoreBlockMsg to the head)
    equals the host-side FittedASCII.predict, and the O(nK) traffic is
    metered."""
    Xtr, ctr, Xte, cte, k = blob
    transport = MeteredTransport()
    session = Protocol(_cfg(k), transport=transport).start(
        jax.random.key(3), _endpoints(Xtr), ctr)
    session.run()
    before = transport.total_bits
    pred = session.predict_distributed(Xte)
    np.testing.assert_array_equal(np.asarray(pred),
                                  np.asarray(session.fitted().predict(Xte)))
    n = Xte[0].shape[0]
    shipped = transport.bits_by_kind().get("score_block", 0)
    assert shipped == (len(Xtr) - 1) * n * k * 32
    assert transport.total_bits == before + shipped


def test_variant_setup_mapping():
    sch, up = variant_setup("ascii")
    assert isinstance(sch, SequentialScheduler) and up and not sch.stale
    sch, up = variant_setup("simple")
    assert isinstance(sch, SequentialScheduler) and not up
    sch, up = variant_setup("random", seed=7)
    assert isinstance(sch, RandomScheduler) and sch.seed == 7
    sch, _ = variant_setup("async")
    assert sch.stale
    with pytest.raises(ValueError):
        variant_setup("bogus")
