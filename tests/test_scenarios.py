"""Scenario-engine pins: FedAvg eager vs compiled bit-identity across every
channel configuration, seeded-churn replay and mid-run resume determinism
(schedule AND byte ledger), Assisted-Learning round semantics on the shared
wire, scenario/CLI-level coherence validation, and subsampled-RDP
amplification bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm import (BudgetSpec, BudgetedTransport, GaussianMechanism,
                        make_codec)
from repro.control import AdaptiveController, make_accountant
from repro.control.accounting import (RDPAccountant, SubsampledRDPAccountant,
                                      rdp_epsilon, sgm_rdp,
                                      subsampled_rdp_epsilon)
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.data.synthetic import gaussian_blobs
from repro.learners.logistic import LogisticRegression
from repro.scenarios import (PRESETS, AssistedLearningVariant, FedAvgVariant,
                             Scenario, make_variant)

K = 4


def _cohort(n=60, agents=3, feats=2, seed=0):
    X, classes = gaussian_blobs(jax.random.key(seed), n=n,
                                num_features=agents * feats, num_classes=K,
                                cluster_std=1.2)
    return ([X[:, m * feats:(m + 1) * feats] for m in range(agents)],
            classes)


def _fit(backend, transport, *, variant=None, scenario=None, rounds=4,
         steps=25, seed=7):
    Xs, classes = _cohort()
    engine = Protocol(SessionConfig(num_classes=K, max_rounds=rounds),
                      transport=transport, backend=backend,
                      variant=variant or FedAvgVariant(), scenario=scenario)
    endpoints = endpoints_for(
        [LogisticRegression(steps=steps) for _ in Xs], Xs)
    return engine.fit(jax.random.key(seed), endpoints, classes)


# ===================================== FedAvg: eager == compiled, bit for bit
def _dp():
    # FedAvg deltas are signed; the interchange's nonneg clamp must be off
    return GaussianMechanism(epsilon=2.0, clip=1.0, nonneg=False)


CHANNELS = {
    "plain": lambda: MeteredTransport(),
    "fp16": lambda: MeteredTransport(codec=make_codec("fp16")),
    "int8": lambda: MeteredTransport(codec=make_codec("int8")),
    "dp": lambda: MeteredTransport(privacy=_dp(),
                                   accountant=make_accountant("rdp")),
    "fp16+dp": lambda: MeteredTransport(codec=make_codec("fp16"),
                                        privacy=_dp(),
                                        accountant=make_accountant("rdp")),
    "budget": lambda: BudgetedTransport(BudgetSpec(session_bits=9000)),
    "budget-tight": lambda: BudgetedTransport(BudgetSpec(session_bits=4000)),
    "link-cap": lambda: BudgetedTransport(BudgetSpec(link_bits=700)),
    "mix": lambda: BudgetedTransport(BudgetSpec(session_bits=8000),
                                     privacy=_dp(),
                                     accountant=make_accountant("rdp")),
}

SCENARIO_MIX = Scenario("mix", subsample=0.9, straggle=0.2, seed=5)


def _assert_parity(te, tc, fe, fc):
    np.testing.assert_array_equal(np.asarray(fe.g), np.asarray(fc.g))
    assert fe.history == fc.history
    assert te.total_bits == tc.total_bits
    assert te.bits_by_kind() == tc.bits_by_kind()
    if te.privacy is not None:
        assert te.accountant.report(te.privacy) == \
            tc.accountant.report(tc.privacy)
    if hasattr(te, "budget"):
        assert te.exhausted == tc.exhausted
        assert te.link_spent == tc.link_spent
        assert te.skipped == tc.skipped


@pytest.mark.parametrize("channel", sorted(CHANNELS))
def test_fedavg_compiled_matches_eager(channel):
    """The lax.scan lowering reproduces the eager loop exactly — final
    params, round history, byte ledger, DP tally, budget state — under
    every wire configuration."""
    te, tc = CHANNELS[channel](), CHANNELS[channel]()
    fe = _fit("eager", te)
    fc = _fit("compiled", tc)
    _assert_parity(te, tc, fe, fc)
    assert te.total_bits > 0


@pytest.mark.parametrize("channel", ["plain", "fp16", "mix"])
def test_fedavg_compiled_matches_eager_under_churn(channel):
    """Same pin with subsampling + stragglers: the compiled scan consumes
    the identical participation mask the eager engine churns by, including
    the PRNG discipline on empty/stopped rounds."""
    te, tc = CHANNELS[channel](), CHANNELS[channel]()
    fe = _fit("eager", te, scenario=SCENARIO_MIX, rounds=5)
    fc = _fit("compiled", tc, scenario=SCENARIO_MIX, rounds=5)
    _assert_parity(te, tc, fe, fc)


def test_fedavg_budget_exhaustion_parity():
    """A cap below even the setup bits stops the session immediately on
    both backends, with identical exhausted flags and ledgers."""
    bits = []
    for backend in ("eager", "compiled"):
        t = BudgetedTransport(BudgetSpec(session_bits=1500))
        f = _fit(backend, t)
        bits.append((f.num_rounds, t.total_bits, t.exhausted))
    assert bits[0] == bits[1]


# ==================================== churn determinism: replay and resume
def test_participation_schedule_is_deterministic():
    sc = PRESETS["churn"]
    m1 = sc.participation(8, 5)
    m2 = sc.participation(8, 5)
    np.testing.assert_array_equal(m1, m2)
    assert m1.dtype == bool and m1.shape == (8, 5)
    # churn actually bites at these probabilities
    assert not m1.all()
    # a reseeded scenario draws a different schedule
    sc2 = Scenario("churn2", straggle=0.25, dropout=0.05, seed=99)
    assert not np.array_equal(m1, sc2.participation(8, 5))


def test_dropout_is_permanent():
    sc = Scenario("drop", dropout=0.3, seed=4)
    m = sc.participation(12, 6)
    for a in range(6):
        gone = np.flatnonzero(~m[:, a])
        if gone.size:
            assert not m[gone[0]:, a].any()


def test_churn_replay_is_bit_identical():
    """Two fresh runs of the same seeded scenario produce the same
    participant lists, history floats, and byte ledger."""
    outs = []
    for _ in range(2):
        t = MeteredTransport(codec=make_codec("fp16"))
        f = _fit("eager", t, scenario=PRESETS["churn"], rounds=5)
        outs.append((f.history, t.total_bits, np.asarray(f.g)))
    assert outs[0][0] == outs[1][0]
    assert outs[0][1] == outs[1][1]
    np.testing.assert_array_equal(outs[0][2], outs[1][2])


@pytest.mark.parametrize("variant_cls", [FedAvgVariant,
                                         AssistedLearningVariant])
def test_midrun_resume_reproduces_churn_and_ledger(tmp_path, variant_cls):
    """Save/restore mid-run under churn + DP + codec: the resumed session
    replays the exact remaining churn schedule and books exactly the
    remaining bytes — predictions, history, and DP tallies all equal the
    uninterrupted run."""
    Xs, classes = _cohort()
    sc = Scenario("mix", straggle=0.25, dropout=0.1, seed=3)
    cfg = SessionConfig(num_classes=K, max_rounds=5)

    def mk_engine():
        t = MeteredTransport(codec=make_codec("fp16"), privacy=_dp(),
                             accountant=make_accountant("rdp"))
        return Protocol(cfg, transport=t, variant=variant_cls(),
                        scenario=sc), t

    def mk_eps():
        return endpoints_for([LogisticRegression(steps=25) for _ in Xs], Xs)

    full_eng, t_full = mk_engine()
    s = full_eng.start(jax.random.key(7), mk_eps(), classes)
    s.run()
    f_full = s.fitted()

    a_eng, t_a = mk_engine()
    s = a_eng.start(jax.random.key(7), mk_eps(), classes)
    s.run(max_rounds=2)
    s.checkpoint(str(tmp_path))
    b_eng, t_b = mk_engine()
    s2 = b_eng.resume(str(tmp_path), mk_eps(), classes)
    s2.run()
    f_res = s2.fitted()

    np.testing.assert_array_equal(np.asarray(f_full.predict(Xs)),
                                  np.asarray(f_res.predict(Xs)))
    assert f_full.history == f_res.history
    assert t_full.total_bits == t_a.total_bits + t_b.total_bits
    # the resumed accountant carries the pre-pause releases forward
    assert t_full.accountant.report(t_full.privacy) == \
        t_b.accountant.report(t_b.privacy)


# ============================================================ Assisted Learning
def test_al_residual_boosting_learns():
    t = MeteredTransport()
    f = _fit("eager", t, variant=AssistedLearningVariant(), rounds=4)
    accs = [r["train_acc"] for r in f.history]
    assert accs[-1] >= accs[0] and accs[-1] > 0.8
    assert len(f.components) == 4 * 3          # every hop keeps a component
    # residual shrinks monotonically under L2 boosting on a clean channel
    norms = [r["resid_norm"] for r in f.history]
    assert norms == sorted(norms, reverse=True)
    assert t.bits_by_kind().get("residual", 0) > 0


def test_al_budget_skip_leaves_residual_stale():
    """A link cap that starves the ring mid-session skips ResidualMsg hops;
    the receiver fits yesterday's residual but the session still runs to
    completion with a full component set."""
    costs = BudgetSpec().payload_costs((60, K))
    t = BudgetedTransport(BudgetSpec(link_bits=costs[-1] * 2))
    f = _fit("eager", t, variant=AssistedLearningVariant(), rounds=4)
    assert len(t.skipped) > 0
    assert len(f.components) == 4 * 3
    # stale hops stall the residual: no longer strictly decreasing
    norms = [r["resid_norm"] for r in f.history]
    assert norms[-1] >= min(norms) - 1e-6


def test_fedavg_rejects_heterogeneous_roster():
    Xs, classes = _cohort()
    Xs[1] = jnp.concatenate([Xs[1], Xs[1][:, :1]], axis=1)  # 3-wide block
    engine = Protocol(SessionConfig(num_classes=K, max_rounds=2),
                      variant=FedAvgVariant())
    with pytest.raises(ValueError, match="equal widths"):
        engine.fit(jax.random.key(0),
                   endpoints_for([LogisticRegression(steps=5)
                                  for _ in Xs], Xs), classes)


# ======================================================== coherence validation
def test_scenario_knob_ranges():
    with pytest.raises(ValueError, match="subsample"):
        Scenario("bad", subsample=1.5)
    with pytest.raises(ValueError, match="dropout"):
        Scenario("bad", dropout=1.0)
    with pytest.raises(ValueError, match="partition"):
        Scenario("bad", partition="bogus")
    with pytest.raises(ValueError, match="clock_skew"):
        Scenario("bad", clock_skew=(0, -1))


def test_scenario_validate_rejects_incoherent_combos():
    class Stale:
        stale = True

    class Seq:
        stale = False

    with pytest.raises(ValueError, match="empty round"):
        Scenario("s", subsample=0.05).validate(4, Seq(), FedAvgVariant())
    with pytest.raises(ValueError, match="async"):
        Scenario("s", clock_skew=(0, 1, 0, 0)).validate(
            4, Seq(), make_variant("ascii"))
    with pytest.raises(ValueError, match="fedavg"):
        Scenario("s", clock_skew=(0, 1, 0, 0)).validate(
            4, Stale(), FedAvgVariant())
    with pytest.raises(ValueError, match="roster has"):
        Scenario("s", clock_skew=(0, 1)).validate(
            4, Stale(), make_variant("ascii"))
    # the coherent combos pass
    Scenario("s", subsample=0.5).validate(4, Seq(), FedAvgVariant())
    Scenario("s", clock_skew=(0, 1, 0, 0)).validate(
        4, Stale(), make_variant("ascii"))


def test_engine_rejects_controller_on_variant_traffic():
    Xs, classes = _cohort()
    t = MeteredTransport(controller=AdaptiveController(stat="l2"))
    engine = Protocol(SessionConfig(num_classes=K, max_rounds=2),
                      transport=t, variant=FedAvgVariant())
    with pytest.raises(ValueError, match="controller"):
        engine.start(jax.random.key(0),
                     endpoints_for([LogisticRegression(steps=5)
                                    for _ in Xs], Xs), classes)


def test_al_has_no_compiled_lowering():
    with pytest.raises(ValueError, match="no compiled lowering"):
        _fit("compiled", MeteredTransport(),
             variant=AssistedLearningVariant())


# ========================================================== subsampled RDP
MECH = GaussianMechanism(epsilon=2.0, clip=1.0)


def test_sgm_rdp_reduces_to_full_batch_at_q1():
    nu = MECH.sigma / MECH.clip
    for a in (2, 4, 16):
        assert sgm_rdp(a, 1.0, nu) == pytest.approx(a / (2 * nu * nu))


def test_subsampled_epsilon_amplifies_and_caps():
    for k in (1, 3, 10):
        full = rdp_epsilon(k, MECH)[0]
        # q = 1: exactly the full-batch bound
        assert subsampled_rdp_epsilon(k, MECH, 1.0)[0] == pytest.approx(full)
        # q < 1 amplifies, monotonically in q, never above the cap
        prev = 0.0
        for q in (0.1, 0.3, 0.6, 0.9):
            eps = subsampled_rdp_epsilon(k, MECH, q)[0]
            assert eps <= full + 1e-12
            assert eps >= prev - 1e-12
            prev = eps


def test_subsampled_accountant_report_carries_cap():
    acct = SubsampledRDPAccountant(q=0.5)
    for _ in range(4):
        acct.record("a1")
    rep = acct.report(MECH)["a1"]
    assert rep["releases"] == 4 and rep["q"] == 0.5
    assert rep["epsilon"] <= rep["epsilon_full_batch"] + 1e-12
    assert rep["epsilon_full_batch"] <= rep["epsilon_additive"] + 1e-12
    # matches the RDP accountant's full-batch figure on the same trace
    full = RDPAccountant()
    for _ in range(4):
        full.record("a1")
    assert rep["epsilon_full_batch"] == \
        pytest.approx(full.report(MECH)["a1"]["epsilon"])
    with pytest.raises(ValueError, match="q must be"):
        SubsampledRDPAccountant(q=0.0)


def test_make_accountant_upgrades_on_q():
    assert isinstance(make_accountant("subsampled-rdp", q=0.4),
                      SubsampledRDPAccountant)
    assert isinstance(make_accountant("rdp", q=0.4),
                      SubsampledRDPAccountant)
    assert isinstance(make_accountant("rdp"), RDPAccountant)
    assert not isinstance(make_accountant("rdp"), SubsampledRDPAccountant)
