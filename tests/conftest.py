import jax
import pytest

jax.config.update("jax_platform_name", "cpu")
# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
