"""SSD chunked scan vs naive recurrence oracle + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked


def naive_ssd(x, dt, A_log, B, C):
    """Token-by-token recurrence: H_t = exp(dt a) H_{t-1} + dt B x;
    y_t = C H_t."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    a = -np.exp(np.asarray(A_log, np.float64))
    H = np.zeros((b, h, n, p))
    ys = []
    x = np.asarray(x, np.float64)
    dt = np.asarray(dt, np.float64)
    B_ = np.asarray(B, np.float64)
    C_ = np.asarray(C, np.float64)
    for t in range(s):
        dec = np.exp(dt[:, t] * a)                    # [b,h]
        upd = np.einsum("bn,bhp,bh->bhnp", B_[:, t], x[:, t], dt[:, t])
        H = H * dec[:, :, None, None] + upd
        ys.append(np.einsum("bn,bhnp->bhp", C_[:, t], H))
    return np.stack(ys, 1), H


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_matches_naive(chunk):
    key = jax.random.key(0)
    b, s, h, p, n = 2, 32, 3, 4, 5
    ks = jax.random.split(key, 5)
    x = _rand(ks[0], b, s, h, p)
    dt = jax.nn.softplus(_rand(ks[1], b, s, h))
    A_log = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    B = _rand(ks[2], b, s, n)
    C = _rand(ks[3], b, s, n)
    y, H = ssd_chunked(x, dt, A_log, B, C, chunk)
    y_ref, H_ref = naive_ssd(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(H), H_ref, rtol=2e-4, atol=2e-4)


@given(st.integers(1, 3), st.sampled_from([8, 16, 32]), st.integers(1, 4),
       st.sampled_from([2, 4]), st.sampled_from([3, 8]))
@settings(max_examples=8, deadline=None)
def test_chunked_matches_naive_property(b, s, h, p, n):
    key = jax.random.key(b * 1000 + s)
    ks = jax.random.split(key, 4)
    x = _rand(ks[0], b, s, h, p)
    dt = jax.nn.softplus(_rand(ks[1], b, s, h)) * 0.5
    A_log = jnp.linspace(-1.0, 1.0, h)
    B = _rand(ks[2], b, s, n)
    C = _rand(ks[3], b, s, n)
    chunk = min(8, s)
    y, _ = ssd_chunked(x, dt, A_log, B, C, chunk)
    y_ref, _ = naive_ssd(x, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=5e-4, atol=5e-4)


def test_state_handoff_across_calls():
    """Running two half-sequences with state handoff == one full pass."""
    key = jax.random.key(1)
    b, s, h, p, n = 1, 16, 2, 4, 3
    ks = jax.random.split(key, 4)
    x = _rand(ks[0], b, s, h, p)
    dt = jax.nn.softplus(_rand(ks[1], b, s, h))
    A_log = jnp.zeros((h,))
    B = _rand(ks[2], b, s, n)
    C = _rand(ks[3], b, s, n)
    y_full, H_full = ssd_chunked(x, dt, A_log, B, C, 8)
    y1, H1 = ssd_chunked(x[:, :8], dt[:, :8], A_log, B[:, :8], C[:, :8], 8)
    y2, H2 = ssd_chunked(x[:, 8:], dt[:, 8:], A_log, B[:, 8:], C[:, 8:], 8,
                         h0=H1)
    np.testing.assert_allclose(np.asarray(y_full[:, 8:]), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(H_full), np.asarray(H2),
                               rtol=1e-4, atol=1e-4)
