"""Verbatim copy of the pre-engine `repro.core.protocol.fit` host loop.

This is the golden reference for tests/test_engine_golden.py: the engine-backed
`protocol.fit` must reproduce this loop's alphas, component lists, and
predictions exactly (same seed, same variant).  Do not "fix" or modernise this
file — its value is that it is frozen at the seed commit's behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scores
from repro.core.encoding import encode_labels
from repro.core.transport import TransportLog
from repro.learners.base import Learner

PyTree = Any


@dataclass(frozen=True)
class LegacyASCIIConfig:
    num_classes: int
    max_rounds: int = 20
    variant: str = "ascii"              # ascii | simple | random | async
    stop_on_negative_alpha: bool = True
    cv_fraction: float = 0.0
    cv_patience: int = 2
    alpha_cap: float = 20.0
    exact_reweight: bool = False
    seed: int = 0


@dataclass
class LegacyComponent:
    agent: int
    round: int
    alpha: float
    params: PyTree


@dataclass
class LegacyFittedASCII:
    components: list[LegacyComponent]
    learners: Sequence[Learner]
    num_classes: int
    history: list[dict] = field(default_factory=list)

    def decision_scores(self, Xs: Sequence[jnp.ndarray],
                        max_round: int | None = None) -> jnp.ndarray:
        n = Xs[0].shape[0]
        k = self.num_classes
        total = jnp.zeros((n, k), jnp.float32)
        for comp in self.components:
            if max_round is not None and comp.round > max_round:
                continue
            pred = self.learners[comp.agent].predict(comp.params, Xs[comp.agent])
            total = total + comp.alpha * encode_labels(pred, k)
        return total

    def predict(self, Xs: Sequence[jnp.ndarray],
                max_round: int | None = None) -> jnp.ndarray:
        return jnp.argmax(self.decision_scores(Xs, max_round), axis=-1)

    @property
    def num_rounds(self) -> int:
        return max((c.round for c in self.components), default=-1) + 1


def _meter_setup(transport: TransportLog | None, n: int, num_agents: int) -> None:
    if transport is None:
        return
    for m in range(1, num_agents):
        transport.send("agent0", f"agent{m}", "labels", n)
        transport.send("agent0", f"agent{m}", "sample_ids", n)


def _meter_hop(transport: TransportLog | None, src: int, dst: int, n: int) -> None:
    if transport is None:
        return
    transport.send(f"agent{src}", f"agent{dst}", "ignorance", n)
    transport.send(f"agent{src}", f"agent{dst}", "model_weight", 1)


def legacy_fit(key: jax.Array, Xs: Sequence[jnp.ndarray], classes: jnp.ndarray,
               learners: Sequence[Learner], cfg: LegacyASCIIConfig,
               transport: TransportLog | None = None) -> LegacyFittedASCII:
    """The seed repo's host loop for Algorithm 1 / Section IV, frozen."""
    num_agents = len(Xs)
    assert len(learners) == num_agents
    Xs_val, c_val = None, None
    if cfg.cv_fraction > 0.0:
        cut = int(round((1.0 - cfg.cv_fraction) * Xs[0].shape[0]))
        Xs_val = [x[cut:] for x in Xs]
        c_val = classes[cut:]
        Xs = [x[:cut] for x in Xs]
        classes = classes[:cut]
    n = Xs[0].shape[0]
    k = cfg.num_classes
    w = scores.init_ignorance(n)
    rng = np.random.default_rng(cfg.seed)
    result = LegacyFittedASCII([], learners, k)
    _meter_setup(transport, n, num_agents)
    best_val, stale = -1.0, 0

    reweight = (
        (lambda w, r, a: scores.ignorance_update_exact(w, r, a, k))
        if cfg.exact_reweight else scores.ignorance_update)

    stop = False
    for t in range(cfg.max_rounds):
        if cfg.variant == "random":
            order = list(rng.permutation(num_agents))
        else:
            order = list(range(num_agents))

        round_rec: dict = {"round": t, "alphas": [], "accs": []}

        if cfg.variant == "async":
            fits = []
            for m in order:
                key, sub = jax.random.split(key)
                params = learners[m].fit(sub, Xs[m], classes, w, k)
                r = learners[m].reward(params, Xs[m], classes)
                a, rbar = scores.model_weight(w, r, k, alpha_cap=cfg.alpha_cap)
                fits.append((m, params, r, a, rbar))
            w_next = w
            any_pos = False
            for m, params, r, a, rbar in fits:
                round_rec["alphas"].append(float(a))
                round_rec["accs"].append(float(rbar))
                if float(a) <= 0:
                    continue
                any_pos = True
                result.components.append(LegacyComponent(m, t, float(a), params))
                w_next = w_next * jnp.exp((a / num_agents) * (1.0 - r))
                _meter_hop(transport, m, (m + 1) % num_agents, n)
            w = w_next / jnp.maximum(jnp.sum(w_next), 1e-12)
            if not any_pos and cfg.stop_on_negative_alpha:
                stop = True
        else:
            u = jnp.ones((n,), jnp.float32)
            for j, m in enumerate(order):
                key, sub = jax.random.split(key)
                params = learners[m].fit(sub, Xs[m], classes, w, k)
                r = learners[m].reward(params, Xs[m], classes)
                if cfg.variant == "simple" or j == 0:
                    a, rbar = scores.model_weight(w, r, k, alpha_cap=cfg.alpha_cap)
                else:
                    a, rbar = scores.model_weight(w, r, k, u=u,
                                                  alpha_cap=cfg.alpha_cap)
                round_rec["alphas"].append(float(a))
                round_rec["accs"].append(float(rbar))
                if cfg.stop_on_negative_alpha and float(a) <= 0:
                    stop = True
                    break
                result.components.append(LegacyComponent(m, t, float(a), params))
                u = scores.upstream_factor_update(u, a, r, k)
                w = reweight(w, r, a)
                nxt = order[(j + 1) % num_agents]
                _meter_hop(transport, m, nxt, n)

        if Xs_val is not None:
            val_acc = float(jnp.mean(result.predict(Xs_val) == c_val))
            round_rec["val_acc"] = val_acc
            if val_acc > best_val + 1e-9:
                best_val, stale = val_acc, 0
            else:
                stale += 1
                if stale >= cfg.cv_patience:
                    stop = True
        result.history.append(round_rec)
        if stop:
            break
    return result
