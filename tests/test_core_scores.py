"""Unit + property tests for the ASCII score math (paper eqs. 1, 9-13)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import scores
from repro.core.encoding import encode_labels, decode_labels, margin

jax.config.update("jax_platform_name", "cpu")


class TestEncoding:
    def test_eq1_values(self):
        y = encode_labels(jnp.array([0, 2]), 4)
        np.testing.assert_allclose(y[0], [1, -1/3, -1/3, -1/3], rtol=1e-6)
        np.testing.assert_allclose(y[1], [-1/3, -1/3, 1, -1/3], rtol=1e-6)

    def test_rows_sum_to_zero(self):
        # the identifiability constraint f_1 + ... + f_K = 0 holds on codes
        y = encode_labels(jnp.arange(7) % 5, 5)
        np.testing.assert_allclose(jnp.sum(y, -1), 0.0, atol=1e-6)

    @given(st.integers(2, 12), st.integers(1, 50))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip(self, k, n):
        c = jnp.arange(n) % k
        assert (decode_labels(encode_labels(c, k)) == c).all()

    @given(st.integers(2, 10))
    @settings(max_examples=20, deadline=None)
    def test_margin_identities(self, k):
        """y^T g / K = 1/(K-1) if same class, -1/(K-1)^2 otherwise."""
        y = encode_labels(jnp.array([0]), k)
        g_same = encode_labels(jnp.array([0]), k)
        g_diff = encode_labels(jnp.array([1]), k)
        np.testing.assert_allclose(margin(y, g_same, k), 1.0 / (k - 1), rtol=1e-5)
        np.testing.assert_allclose(margin(y, g_diff, k), -1.0 / (k - 1) ** 2,
                                   rtol=1e-5)


class TestModelWeight:
    def test_eq9_head_agent(self):
        """alpha = log(rbar/(1-rbar)) + log(K-1) for uniform weights."""
        r = jnp.array([1., 1., 1., 0.])
        w = jnp.full((4,), 0.25)
        a, rbar = scores.head_agent_alpha(w, r, num_classes=3)
        np.testing.assert_allclose(rbar, 0.75, rtol=1e-6)
        np.testing.assert_allclose(a, np.log(3.) + np.log(2.), rtol=1e-5)

    def test_alpha_zero_at_random_guessing(self):
        """Stop criterion: rbar = 1/K <=> alpha = 0."""
        k = 5
        n = 100
        r = jnp.concatenate([jnp.ones(n // k), jnp.zeros(n - n // k)])
        w = jnp.full((n,), 1.0 / n)
        a, _ = scores.head_agent_alpha(w, r, num_classes=k)
        np.testing.assert_allclose(a, 0.0, atol=1e-5)

    def test_eq11_matches_numeric_minimizer(self):
        """The closed-form assistant alpha (eq. 11) minimizes the staged
        exponential loss (eq. 8), up to the paper's dropped constant
        (K-1)^2/K."""
        rng = np.random.default_rng(0)
        n, k = 64, 4
        w = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
        rA = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
        rB = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
        alphaA, _ = scores.head_agent_alpha(w, rA, k)
        wB = scores.ignorance_update(w, rA, alphaA)
        u = scores.upstream_factor_update(jnp.ones(n), alphaA * (k - 1) ** 2 / k,
                                          rA, k)
        # exact-scale alpha for the numeric check
        aB, _ = scores.model_weight(wB, rB, k, u=u, exact_scale=True)

        def staged_loss(alpha_b):
            termA = np.where(rA > 0, np.exp(-(alphaA * (k-1)**2/k) / (k - 1)),
                             np.exp((alphaA * (k-1)**2/k) / (k - 1) ** 2))
            termB = np.where(rB > 0, np.exp(-alpha_b / (k - 1)),
                             np.exp(alpha_b / (k - 1) ** 2))
            return float(jnp.sum(wB * termA * termB))

        grid = np.linspace(float(aB) - 2, float(aB) + 2, 2001)
        losses = [staged_loss(a) for a in grid]
        best = grid[int(np.argmin(losses))]
        np.testing.assert_allclose(float(aB), best, atol=2e-3)

    @given(st.integers(2, 8), st.integers(4, 64))
    @settings(max_examples=30, deadline=None)
    def test_alpha_monotone_in_accuracy(self, k, n):
        """More correct samples (under uniform w) => larger alpha."""
        w = jnp.full((n,), 1.0 / n)
        alphas = []
        for ncorr in range(1, n):
            r = jnp.concatenate([jnp.ones(ncorr), jnp.zeros(n - ncorr)])
            a, _ = scores.model_weight(w, r, k)
            alphas.append(float(a))
        assert all(a2 >= a1 - 1e-6 for a1, a2 in zip(alphas, alphas[1:]))


class TestIgnoranceUpdate:
    @given(st.integers(4, 128), st.floats(0.01, 5.0))
    @settings(max_examples=30, deadline=None)
    def test_normalized_and_in_unit_interval(self, n, alpha):
        """The interchange value is an 'ignorance' in [0, 1] summing to 1."""
        rng = np.random.default_rng(n)
        w = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
        r = jnp.asarray(rng.integers(0, 2, n), jnp.float32)
        w2 = scores.ignorance_update(w, r, jnp.asarray(alpha))
        assert np.isclose(float(jnp.sum(w2)), 1.0, atol=1e-5)
        assert float(jnp.min(w2)) >= 0.0 and float(jnp.max(w2)) <= 1.0

    def test_misclassified_gain_weight(self):
        w = jnp.full((4,), 0.25)
        r = jnp.array([1., 0., 1., 0.])
        w2 = scores.ignorance_update(w, r, jnp.asarray(1.0))
        assert float(w2[1]) > float(w2[0])
        np.testing.assert_allclose(w2[1] / w2[0], np.e, rtol=1e-5)

    def test_scale_invariance(self):
        """Downstream formulas are invariant to the global scale of w
        (paper initializes w = 1-vector; we keep it normalized)."""
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.random(16), jnp.float32)
        r = jnp.asarray(rng.integers(0, 2, 16), jnp.float32)
        a1, _ = scores.model_weight(w, r, 3)
        a2, _ = scores.model_weight(10.0 * w, r, 3)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)
        np.testing.assert_allclose(scores.ignorance_update(w, r, a1),
                                   scores.ignorance_update(10 * w, r, a1),
                                   rtol=1e-4)

    def test_zero_alpha_is_noop_up_to_normalization(self):
        w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
        r = jnp.asarray([1., 0., 1., 0.])
        np.testing.assert_allclose(scores.ignorance_update(w, r, jnp.asarray(0.0)),
                                   w, rtol=1e-6)


class TestUpstreamFactor:
    @given(st.integers(2, 9))
    @settings(max_examples=20, deadline=None)
    def test_matches_exponential_loss(self, k):
        """u-update equals exp(-alpha y^T g / K) via the margin identities."""
        alpha = 0.7
        u = jnp.ones((2,))
        r = jnp.array([1., 0.])
        u2 = scores.upstream_factor_update(u, jnp.asarray(alpha), r, k)
        np.testing.assert_allclose(u2[0], np.exp(-alpha / (k - 1)), rtol=1e-5)
        np.testing.assert_allclose(u2[1], np.exp(alpha / (k - 1) ** 2), rtol=1e-5)
