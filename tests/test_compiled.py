"""Compiled-backend pin: `backend="compiled"` (one lax.scan program per
session, core/compiled.py) must reproduce the eager engine bit for bit
under sequential scheduling — same components, alphas, params, history,
predictions, and metered message ledger — and the vmapped fleet must match
per-session compiled runs exactly."""
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compiled import (SessionPlan, compiled_session, fleet_run,
                                 plan_for)
from repro.core.engine import (MeteredTransport, Protocol, RandomScheduler,
                               SessionConfig, endpoints_for)
from repro.data.partition import train_test_split, vertical_split
from repro.data.synthetic import blob_fig3
from repro.learners.base import Learner, LearnerCore
from repro.learners.logistic import LogisticRegression
from repro.learners.mlp import MLP
from repro.learners.tree import DecisionTree


@pytest.fixture(scope="module")
def blob():
    key = jax.random.key(0)
    ds = blob_fig3(key, n=240)
    tr, te = train_test_split(0, 240)
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te], ds.num_classes)


LEARNERS = {
    "logistic": lambda: LogisticRegression(steps=60),
    "mlp": lambda: MLP(hidden=(16,), steps=40),
}


def _run_both(blob, learner_fn, **cfg_kw):
    Xtr, ctr, Xte, cte, k = blob
    learners = [learner_fn() for _ in Xtr]
    cfg = SessionConfig(num_classes=k, max_rounds=3, **cfg_kw)
    log_e, log_c = MeteredTransport(), MeteredTransport()
    eager = Protocol(cfg, transport=log_e).fit(
        jax.random.key(11), endpoints_for(learners, Xtr), ctr)
    comp = Protocol(cfg, transport=log_c, backend="compiled").fit(
        jax.random.key(11), endpoints_for(learners, Xtr), ctr)
    return eager, comp, log_e, log_c, Xte


def _assert_identical(eager, comp, Xte):
    assert [(c.agent, c.round) for c in eager.components] == \
           [(c.agent, c.round) for c in comp.components]
    np.testing.assert_array_equal(
        np.asarray([c.alpha for c in eager.components]),
        np.asarray([c.alpha for c in comp.components]))
    for ce, cc in zip(eager.components, comp.components):
        for le, lc in zip(jax.tree.leaves(ce.params),
                          jax.tree.leaves(cc.params)):
            np.testing.assert_array_equal(np.asarray(le), np.asarray(lc))
    assert eager.history == comp.history
    np.testing.assert_array_equal(np.asarray(eager.predict(Xte)),
                                  np.asarray(comp.predict(Xte)))


@pytest.mark.parametrize("name", list(LEARNERS))
def test_compiled_matches_eager(blob, name):
    eager, comp, log_e, log_c, Xte = _run_both(blob, LEARNERS[name])
    _assert_identical(eager, comp, Xte)
    # byte-identical Fig.-4 accounting, entry for entry
    assert log_e.log.entries == log_c.log.entries


def test_compiled_matches_eager_simple_variant(blob):
    """upstream=False (ASCII-Simple alphas) pins too."""
    eager, comp, _, _, Xte = _run_both(blob, LEARNERS["logistic"],
                                       upstream=False)
    _assert_identical(eager, comp, Xte)


def test_compiled_matches_eager_exact_reweight(blob):
    eager, comp, _, _, Xte = _run_both(blob, LEARNERS["logistic"],
                                       exact_reweight=True)
    _assert_identical(eager, comp, Xte)


# --------------------------------------------------- early-stop (line 8) pin
@dataclass(frozen=True)
class _ConstCore(LearnerCore):
    """Always predicts class 0 — its weighted accuracy ~1/K drives alpha
    negative and trips Algorithm 1's line-8 stop."""
    num_classes: int

    def init(self, key, shapes):
        return {"z": jnp.zeros(())}

    def fit(self, params, key, X, onehot, w):
        return params

    def logits(self, params, X):
        base = jnp.zeros((X.shape[0], self.num_classes)).at[:, 0].set(1.0)
        return base + params["z"]


@dataclass(frozen=True)
class _ConstLearner(Learner):
    num_classes: int
    functional = True

    def core(self, num_classes):
        return _ConstCore(num_classes)

    def fit(self, key, X, classes, w, num_classes):
        core = self.core(num_classes)
        return core.fit(core.init(key, X.shape[1:]), key, X,
                        jax.nn.one_hot(classes, num_classes), w)

    def predict(self, params, X):
        return jnp.argmax(_ConstCore(self.num_classes).logits(params, X),
                          axis=-1)


def test_compiled_matches_eager_early_stop(blob):
    """The alpha<=0 stop (and the masked tail after it) pins bit for bit."""
    Xtr, ctr, Xte, cte, k = blob
    learners = [LogisticRegression(steps=60), _ConstLearner(k),
                LogisticRegression(steps=60)]
    cfg = SessionConfig(num_classes=k, max_rounds=3)
    eager = Protocol(cfg).fit(jax.random.key(5),
                              endpoints_for(learners, Xtr[:3]), ctr)
    comp = Protocol(cfg, backend="compiled").fit(
        jax.random.key(5), endpoints_for(learners, Xtr[:3]), ctr)
    # the constant agent must actually have tripped the stop mid-round
    assert eager.num_rounds == 1
    assert len(eager.history[0]["alphas"]) == 2   # head + triggering agent
    _assert_identical(eager, comp, Xte[:3])


# ------------------------------------------------------------------ the fleet
def test_fleet_matches_single_sessions(blob):
    Xtr, ctr, _, _, k = blob
    plan = plan_for([LogisticRegression(steps=40) for _ in Xtr], k,
                    max_rounds=3)
    keys = jax.random.split(jax.random.key(0), 4)
    fleet = fleet_run(plan, keys, Xtr, ctr)
    assert fleet.alphas.shape == (4, 3, len(Xtr))
    for s in (0, 3):
        single = compiled_session(plan, keys[s], Xtr, ctr)
        np.testing.assert_array_equal(np.asarray(fleet.alphas[s]),
                                      np.asarray(single.alphas))
        np.testing.assert_array_equal(np.asarray(fleet.w[s]),
                                      np.asarray(single.w))


def test_fleet_data_batched(blob):
    """Per-cohort fleets: each session gets its own (Xs, classes)."""
    Xtr, ctr, _, _, k = blob
    S = 3
    Xs_b = [jnp.stack([x + 0.01 * s for s in range(S)]) for x in Xtr]
    classes_b = jnp.stack([ctr] * S)
    plan = plan_for([LogisticRegression(steps=30) for _ in Xtr], k,
                    max_rounds=2)
    fleet = fleet_run(plan, jax.random.split(jax.random.key(1), S),
                      Xs_b, classes_b, data_batched=True)
    assert fleet.alphas.shape == (S, 2, len(Xtr))
    assert bool(jnp.all(jnp.isfinite(fleet.alphas)))


# ------------------------------------------------------------------ contracts
@pytest.mark.parametrize("name", list(LEARNERS))
def test_core_composition_equals_eager_fit(blob, name):
    """The LearnerCore contract: fit(init(key), key, ...) == Learner.fit."""
    Xtr, ctr, _, _, k = blob
    learner = LEARNERS[name]()
    key = jax.random.key(9)
    w = jnp.full((ctr.shape[0],), 1.0 / ctr.shape[0])
    params_eager = learner.fit(key, Xtr[0], ctr, w, k)
    core = learner.core(k)
    onehot = jax.nn.one_hot(ctr, k)
    shapes = Xtr[0].shape[1:]
    # jit the composition like both engine backends do (op-by-op dispatch
    # fuses differently at the last ulp)
    fresh = jax.jit(lambda kk, X, oh, ww:
                    core.fit(core.init(kk, shapes), kk, X, oh, ww))
    params_core = fresh(key, Xtr[0], onehot, w)
    for le, lc in zip(jax.tree.leaves(params_eager),
                      jax.tree.leaves(params_core)):
        np.testing.assert_array_equal(np.asarray(le), np.asarray(lc))
    np.testing.assert_array_equal(
        np.asarray(learner.predict(params_eager, Xtr[0])),
        np.asarray(core.predict(params_core, Xtr[0])))


def test_compiled_rejects_eager_only_learners(blob):
    Xtr, ctr, _, _, k = blob
    cfg = SessionConfig(num_classes=k, max_rounds=2)
    eng = Protocol(cfg, backend="compiled")
    eps = endpoints_for([DecisionTree(depth=2) for _ in Xtr], Xtr)
    with pytest.raises(ValueError, match="LearnerCore"):
        eng.fit(jax.random.key(0), eps, ctr)


def test_compiled_rejects_nonsequential_scheduler(blob):
    Xtr, ctr, _, _, k = blob
    cfg = SessionConfig(num_classes=k, max_rounds=2)
    eng = Protocol(cfg, scheduler=RandomScheduler(0), backend="compiled")
    eps = endpoints_for([LogisticRegression(steps=10) for _ in Xtr], Xtr)
    with pytest.raises(ValueError, match="sequential"):
        eng.fit(jax.random.key(0), eps, ctr)


def test_unknown_backend_rejected(blob):
    _, _, _, _, k = blob
    with pytest.raises(ValueError, match="backend"):
        Protocol(SessionConfig(num_classes=k), backend="turbo")


# ============================================================= control sweeps
def test_control_sweep_controller_matches_static(blob):
    """PR 9: controller thresholds/beta become traced operands — one vmapped
    program sweeps N (cuts, beta) configs, each row bit-equal to a static
    per-config compile, and the whole sweep traces exactly once."""
    from repro.comm.codecs import Fp16Codec, QuantCodec
    from repro.control import AdaptiveController
    from repro.core import compiled
    Xtr, ctr, _, _, k = blob
    learners = [LogisticRegression(steps=30) for _ in Xtr]
    ladder = (Fp16Codec(), QuantCodec(bits=4))
    configs = [((0.5,), 0.0), ((0.1,), 0.0), ((0.9,), 0.5), ((0.3,), 0.9)]
    mk = lambda cut, beta: plan_for(
        learners, k, max_rounds=2,
        controller=AdaptiveController(ladder=ladder, thresholds=cut,
                                      beta=beta))
    plan = mk(*configs[0])
    key = jax.random.key(0)
    compiled.TRACE_COUNTS.clear()
    sweep = compiled.control_sweep_run(
        plan, jnp.stack([key] * len(configs)), Xtr, ctr,
        cuts=[c for c, _ in configs], betas=[b for _, b in configs])
    assert compiled.TRACE_COUNTS == {"control_sweep": 1}
    for row, (cut, beta) in enumerate(configs):
        single = compiled_session(mk(cut, beta), key, Xtr, ctr)
        np.testing.assert_array_equal(np.asarray(sweep.alphas[row]),
                                      np.asarray(single.alphas))
        np.testing.assert_array_equal(np.asarray(sweep.w[row]),
                                      np.asarray(single.w))
        np.testing.assert_array_equal(np.asarray(sweep.codec_idx[row]),
                                      np.asarray(single.codec_idx))


def test_control_sweep_budget_caps_match_static(blob):
    """Budget caps sweep as traced operands too — including a ``None``
    (uncapped) entry, lowered as the int32 sentinel — each row bit-equal to
    the statically-capped compile, one trace for the lot."""
    from repro.comm import BudgetSpec
    from repro.comm.codecs import QuantCodec
    from repro.core import compiled
    Xtr, ctr, _, _, k = blob
    learners = [LogisticRegression(steps=30) for _ in Xtr]
    ladder = (QuantCodec(bits=8), QuantCodec(bits=4))
    caps = [40_000, 20_000, 12_000, None]
    mk = lambda cap: plan_for(learners, k, max_rounds=3,
                              budget=BudgetSpec(session_bits=cap,
                                                ladder=ladder))
    plan = mk(caps[0])
    key = jax.random.key(0)
    compiled.TRACE_COUNTS.clear()
    sweep = compiled.control_sweep_run(plan, jnp.stack([key] * len(caps)),
                                       Xtr, ctr, session_bits=caps)
    assert compiled.TRACE_COUNTS == {"control_sweep": 1}
    for row, cap in enumerate(caps):
        single = compiled_session(mk(cap), key, Xtr, ctr)
        for field in ("alphas", "w", "sent", "codec_idx", "exhausted"):
            np.testing.assert_array_equal(
                np.asarray(getattr(sweep, field)[row]),
                np.asarray(getattr(single, field)))


def test_control_sweep_needs_a_control_plane(blob):
    Xtr, ctr, _, _, k = blob
    from repro.core import compiled
    plan = plan_for([LogisticRegression(steps=10) for _ in Xtr], k,
                    max_rounds=2)
    with pytest.raises(ValueError, match="neither"):
        compiled.control_sweep_run(plan, jnp.stack([jax.random.key(0)]),
                                   Xtr, ctr)
