"""Data substrate: vertical partition, collation, surrogates, pipeline."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synthetic
from repro.data.partition import collate, train_test_split, vertical_split
from repro.data.pipeline import batched_indices, lm_batches


def test_vertical_split_roundtrip(key):
    X = jax.random.normal(key, (10, 9))
    parts = vertical_split(X, (2, 3, 4))
    assert [p.shape[1] for p in parts] == [2, 3, 4]
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(parts, 1)),
                                  np.asarray(X))


def test_collate_intersects_ids(key):
    X1 = jnp.arange(12.0).reshape(4, 3)
    X2 = jnp.arange(8.0).reshape(4, 2)
    ids1 = np.array([3, 1, 2, 9])
    ids2 = np.array([2, 9, 5, 1])
    common, (a, b) = collate([ids1, ids2], [X1, X2])
    assert common.tolist() == [1, 2, 9]
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(X1)[np.array([1, 2, 3])])
    np.testing.assert_array_equal(np.asarray(b),
                                  np.asarray(X2)[np.array([3, 0, 1])])


def test_surrogates_match_paper_dims(key):
    mimic = synthetic.mimic_surrogate(key, n=500)
    assert mimic.X.shape[1] == 16 and mimic.num_classes == 2
    assert mimic.splits == (3, 13)
    qsar = synthetic.qsar_surrogate(key)
    assert qsar.X.shape == (1055, 41) and qsar.splits == (20, 21)
    wine = synthetic.wine_surrogate(key)
    assert wine.X.shape == (1599, 11) and wine.num_classes == 6
    blob = synthetic.blob_fig6(key, n=100)
    assert blob.num_classes == 20 and len(blob.splits) == 20
    fashion = synthetic.fashion_surrogate(key, n=50)
    assert fashion.X.shape[1] == 28 * 28 and sum(fashion.splits) == 784


def test_train_test_split_disjoint():
    tr, te = train_test_split(0, 100, 0.7)
    assert len(tr) == 70 and len(te) == 30
    assert not set(tr.tolist()) & set(te.tolist())


def test_batched_indices_cover_epoch():
    it = batched_indices(20, 8, seed=0)
    seen = np.concatenate([next(it), next(it)])
    assert len(set(seen.tolist())) == 16  # no repeats within an epoch


def test_lm_batches_deterministic(key):
    a = next(lm_batches(key, vocab_size=64, batch=2, seq_len=16))
    b = next(lm_batches(key, vocab_size=64, batch=2, seq_len=16))
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    assert int(a["tokens"].max()) < 64
