"""Benchmark orchestrator — one section per paper table/figure plus the
kernel and roofline reports.  Prints ``name,us_per_call,derived`` CSV lines
per section.  Use --full for paper-scale replication counts."""
from __future__ import annotations

import argparse
import time


def _section(name):
    print(f"\n# === {name} ===", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes/replications (slow)")
    args, _ = ap.parse_known_args()
    quick = not args.full

    t0 = time.time()
    _section("fig3_accuracy (ASCII vs Single vs Oracle)")
    from benchmarks import fig3_accuracy
    for r in fig3_accuracy.run(reps=5 if args.full else 2,
                               rounds=10 if args.full else 6, quick=quick):
        print(f"fig3_{r['dataset']}_{r['method']},"
              f"{0:.0f},final_acc={r['final_acc']:.4f}")

    _section("fig4_transmission (bits at 90%-oracle)")
    from benchmarks import fig4_transmission
    for r in fig4_transmission.run(quick=quick):
        print(f"fig4_{r['dataset']},{0:.0f},cost_ratio={r['cost_ratio']:.1f}x"
              f";ascii_bits={r['ascii_bits']};oracle_bits={r['oracle_bits']}")

    _section("comm frontier (accuracy vs encoded bits across wire codecs)")
    cf = fig4_transmission.frontier(quick=quick, out="BENCH_comm.json")
    for r in cf["rows"]:
        print(f"comm_{r['point']},{0:.0f},acc={r['acc']:.4f};"
              f"interchange_bits={r['interchange_bits']};"
              f"ratio_vs_fp32={r['bits_ratio_vs_fp32']:.2f}x")
    print("comm_frontier,0,written=BENCH_comm.json")

    _section("fig6_variants (ASCII vs Simple/Random/Ensemble/Async)")
    from benchmarks import fig6_variants
    for r in fig6_variants.run(reps=3 if args.full else 1,
                               rounds=8 if args.full else 5, quick=quick):
        print(f"fig6_{r['dataset']}_{r['method']},"
              f"{0:.0f},final_acc={r['final_acc']:.4f}")

    _section("fleet (eager loop vs compiled session vs vmapped fleet)")
    from benchmarks import fleet_bench
    fr = fleet_bench.run(sessions=16 if args.full else 8,
                         rounds=6 if args.full else 4,
                         steps=150 if args.full else 80,
                         out="BENCH_fleet.json")
    for mode in ("eager", "compiled", "fleet"):
        print(f"fleet_{mode},{fr[mode]['seconds'] * 1e6:.0f},"
              f"sessions_per_sec={fr[mode]['sessions_per_sec']:.2f}")
    print(f"fleet_speedup,0,fleet_vs_eager="
          f"{fr['speedup_fleet_vs_eager']:.1f}x (BENCH_fleet.json)")

    _section("serve (continuous batching vs per-request dispatch)")
    from benchmarks import serve_bench
    sr = serve_bench.run(sessions=8, requests=128 if args.full else 48,
                         steps=80 if args.full else 40,
                         verify=True, out="BENCH_serve.json")
    for mode in ("sequential", "batched"):
        print(f"serve_{mode},{sr[mode]['seconds'] * 1e6:.0f},"
              f"qps={sr[mode]['qps']:.1f};p50_ms={sr[mode]['p50_ms']:.2f};"
              f"p99_ms={sr[mode]['p99_ms']:.2f}")
    print(f"serve_speedup,0,batched_vs_sequential="
          f"{sr['speedup_batched_vs_sequential']:.2f}x;"
          f"verified={sr['verified_bit_identical']} (BENCH_serve.json)")

    _section("telemetry (instrumented vs dark, bit-identity + overhead)")
    from benchmarks import telemetry_bench
    tb = telemetry_bench.run(repeats=5 if args.full else 3,
                             out="BENCH_telemetry.json")
    print(f"telemetry_instrumented,"
          f"{tb['instrumented']['seconds'] * 1e6:.0f},"
          f"overhead={tb['overhead_ratio']:.3f}x;"
          f"bit_identical={tb['bit_identical']};"
          f"spans={tb['spans']} (BENCH_telemetry.json)")

    _section("kernels (Pallas interpret vs jnp oracle)")
    from benchmarks import kernels_bench
    for r in kernels_bench.run():
        print(f"kernel_{r['kernel']},{r['us_pallas_interp']:.0f},"
              f"max_err={r['max_err']:.2e}")

    _section("roofline (from dry-run artifacts)")
    from benchmarks import roofline
    rows = roofline.load()
    if not rows:
        print("roofline,0,no artifacts (run repro.launch.dryrun first)")
    else:
        for line in roofline.table(rows):
            print(line)

    print(f"\n# total bench wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
