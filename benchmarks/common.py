"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import (ASCIIConfig, fit, fit_ensemble_adaboost,
                                 fit_single_agent_adaboost)
from repro.core.transport import TransportLog, oracle_bits
from repro.data.partition import train_test_split, vertical_split


def split_dataset(ds, seed: int):
    tr, te = train_test_split(seed, ds.X.shape[0])
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], ds.classes[tr],
            [x[te] for x in Xs], ds.classes[te])


def acc(pred, classes) -> float:
    return float(jnp.mean(pred == classes))


def curve_vs_rounds(fitted, Xte, cte, max_rounds: int) -> list[float]:
    """Test accuracy after each assistance round (Fig. 3/6 x-axis)."""
    out = []
    for t in range(max_rounds):
        if t >= fitted.num_rounds:
            out.append(out[-1] if out else float("nan"))
            continue
        out.append(acc(fitted.predict(Xte, max_round=t), cte))
    return out


def run_three_way(key, ds, learners, cfg: ASCIIConfig, seed: int,
                  oracle_learner=None):
    """ASCII vs Single (agent A only) vs Oracle (pulled data) — Fig. 3."""
    Xtr, ctr, Xte, cte = split_dataset(ds, seed)
    k1, k2, k3 = jax.random.split(key, 3)
    ascii_fit = fit(k1, Xtr, ctr, learners, cfg)
    single_fit = fit_single_agent_adaboost(k2, Xtr[0], ctr, learners[0], cfg)
    oracle_learner = oracle_learner or learners[0]
    oracle_fit = fit_single_agent_adaboost(
        k3, jnp.concatenate(Xtr, 1), ctr, oracle_learner, cfg)
    return {
        "ascii": curve_vs_rounds(ascii_fit, Xte, cte, cfg.max_rounds),
        "single": curve_vs_rounds(single_fit, [Xte[0]], cte, cfg.max_rounds),
        "oracle": curve_vs_rounds(oracle_fit, [jnp.concatenate(Xte, 1)], cte,
                                  cfg.max_rounds),
    }


def timed(fn, *args, reps: int = 1, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6   # us
