"""Paper Fig. 6: ASCII vs ASCII-Random vs ASCII-Simple vs Ensemble-AdaBoost.

(a) 20-class blobs, 20 agents x 1 feature, logistic regression;
(b) wine(-surrogate), 11 agents x 1 feature, decision trees.
Also runs the beyond-paper ASCII-Async variant (the paper's open problem on
asynchronous interchange) for comparison."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import acc, curve_vs_rounds, split_dataset
from repro.core.engine import (Protocol, SessionConfig, endpoints_for,
                               variant_setup)
from repro.core.protocol import ASCIIConfig, fit_ensemble_adaboost
from repro.data import synthetic
from repro.learners.logistic import LogisticRegression
from repro.learners.tree import DecisionTree


def run(reps: int = 2, rounds: int = 6, quick: bool = True) -> list[dict]:
    key = jax.random.key(13)
    wine = synthetic.wine_surrogate(jax.random.fold_in(key, 1))
    wine = synthetic.Dataset("wine", wine.X, wine.classes, wine.num_classes,
                             tuple([1] * 11))
    cases = {
        "blob20": (synthetic.blob_fig6(jax.random.fold_in(key, 0),
                                       n=600 if quick else 1000),
                   lambda: LogisticRegression(steps=150)),
        "wine": (wine, lambda: DecisionTree(depth=3, num_thresholds=8)),
    }
    variants = ["ascii", "simple", "random", "async"]
    rows = []
    for name, (ds, mk) in cases.items():
        for variant in variants + ["ensemble_ada"]:
            finals, curves = [], []
            for rep in range(reps):
                Xtr, ctr, Xte, cte = split_dataset(ds, rep)
                k = jax.random.fold_in(key, hash((name, variant, rep)) % 2**31)
                learners = [mk() for _ in ds.splits]
                if variant == "ensemble_ada":
                    cfg = ASCIIConfig(num_classes=ds.num_classes,
                                      max_rounds=rounds)
                    fitted = fit_ensemble_adaboost(k, Xtr, ctr, learners, cfg)
                    finals.append(acc(fitted.predict(Xte), cte))
                    curves.append([acc(fitted.predict(Xte, max_round=t), cte)
                                   for t in range(rounds)])
                else:
                    # engine API: the variant string is just a scheduler +
                    # alpha-policy pair
                    scheduler, upstream = variant_setup(variant)
                    cfg6 = SessionConfig(num_classes=ds.num_classes,
                                         max_rounds=rounds, upstream=upstream)
                    fitted = Protocol(cfg6, scheduler=scheduler).fit(
                        k, endpoints_for(learners, Xtr), ctr)
                    finals.append(acc(fitted.predict(Xte), cte))
                    curves.append(curve_vs_rounds(fitted, Xte, cte, rounds))
            arr = np.asarray(curves, np.float64)
            rows.append({"figure": "fig6", "dataset": name, "method": variant,
                         "final_acc": float(np.nanmean(finals)),
                         "curve": [round(float(x), 4)
                                   for x in np.nanmean(arr, 0)]})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(args.reps, args.rounds, quick=not args.full):
        print(f"{r['dataset']},{r['method']},{r['final_acc']:.4f},{r['curve']}")


if __name__ == "__main__":
    main()
