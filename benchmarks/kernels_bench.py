"""Kernel benchmarks: Pallas (interpret on CPU) vs pure-jnp reference —
allclose + relative wall time.  On TPU the same harness times the compiled
kernels; on this box wall-times of interpret mode are NOT performance
numbers, only correctness gates (the roofline table carries the perf
story)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops, ref


def run() -> list[dict]:
    key = jax.random.key(0)
    rows = []

    t, v = 512, 2048
    logits = jax.random.normal(key, (t, v))
    labels = jax.random.randint(key, (t,), 0, v)
    w = jax.random.uniform(key, (t,))
    out_k, us_k = timed(lambda: ops.weighted_ce(logits, labels, w))
    (out_r, _), us_r = timed(lambda: ref.weighted_ce(logits, labels, w))
    rows.append({"kernel": "weighted_ce", "shape": f"{t}x{v}",
                 "max_err": float(jnp.max(jnp.abs(out_k - out_r))),
                 "us_pallas_interp": us_k, "us_ref": us_r})

    b, h, kv, s, d = 1, 4, 2, 512, 64
    q = jax.random.normal(key, (b, h, s, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, kv, s, d))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (b, kv, s, d))
    for window in (None, 128):
        o_k, us_k = timed(lambda: ops.flash_attention(q, k, vv, window=window))
        o_r, us_r = timed(lambda: ref.flash_attention(q, k, vv, window=window))
        rows.append({"kernel": f"flash_attention(w={window})",
                     "shape": f"{b}x{h}x{s}x{d}",
                     "max_err": float(jnp.max(jnp.abs(o_k - o_r))),
                     "us_pallas_interp": us_k, "us_ref": us_r})

    # flash-decode: one token vs a long (fp / int8) cache
    from repro.models.attention import quantize_kv
    b2, h2, kv2, s2, d2 = 1, 4, 2, 1024, 64
    qd = jax.random.normal(key, (b2, h2, d2))
    kd = jax.random.normal(jax.random.fold_in(key, 3), (b2, kv2, s2, d2))
    vd = jax.random.normal(jax.random.fold_in(key, 4), (b2, kv2, s2, d2))
    pos = jnp.asarray(900, jnp.int32)
    o_k, us_k = timed(lambda: ops.flash_decode(qd, kd, vd, pos))
    o_r, us_r = timed(lambda: ref.flash_decode(qd, kd, vd, pos))
    rows.append({"kernel": "flash_decode(fp)", "shape": f"{b2}x{h2}x{s2}x{d2}",
                 "max_err": float(jnp.max(jnp.abs(o_k - o_r))),
                 "us_pallas_interp": us_k, "us_ref": us_r})
    kq, ks = quantize_kv(kd); vq, vs = quantize_kv(vd)
    o_k, us_k = timed(lambda: ops.flash_decode(qd, kq, vq, pos,
                                               k_scale=ks, v_scale=vs))
    o_r, us_r = timed(lambda: ref.flash_decode(qd, kq, vq, pos,
                                               k_scale=ks, v_scale=vs))
    rows.append({"kernel": "flash_decode(int8)",
                 "shape": f"{b2}x{h2}x{s2}x{d2}",
                 "max_err": float(jnp.max(jnp.abs(o_k - o_r))),
                 "us_pallas_interp": us_k, "us_ref": us_r})

    n = 8192
    wv = jax.random.dirichlet(key, jnp.ones(n))
    r = (jax.random.uniform(key, (n,)) > 0.5).astype(jnp.float32)
    o_k, us_k = timed(lambda: ops.ignorance_update(wv, r, jnp.asarray(1.1)))
    o_r, us_r = timed(lambda: ref.ignorance_update(wv, r, jnp.asarray(1.1)))
    rows.append({"kernel": "ignorance_update", "shape": f"{n}",
                 "max_err": float(jnp.max(jnp.abs(o_k - o_r))),
                 "us_pallas_interp": us_k, "us_ref": us_r})
    return rows


def main():
    for r in run():
        print(f"{r['kernel']},{r['shape']},err={r['max_err']:.2e},"
              f"us_interp={r['us_pallas_interp']:.0f},us_ref={r['us_ref']:.0f}")


if __name__ == "__main__":
    main()
