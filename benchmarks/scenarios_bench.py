"""Protocol x scenario frontier: ASCII vs FedAvg vs Assisted Learning
under adversarial-reality knobs, on the same wire.

Every (protocol, scenario) cell runs through the identical eager engine
loop and MeteredTransport ledger — GradientMsg / ResidualMsg / ignorance
traffic all priced by the same ``wire_bits`` rule — so the accuracy,
byte, and epsilon columns are directly comparable across protocols:

  * protocols — ``ascii`` (the paper's ignorance interchange), ``fedavg``
    (federated averaging over a homogeneous roster), ``al`` (assisted
    residual-fitting rounds).  All via :mod:`repro.scenarios.protocols`.
  * scenarios — ``clean``, ``noniid`` (Dirichlet label skew), ``churn``
    (stragglers + permanent dropout): the :data:`repro.scenarios.PRESETS`
    entries the CLI shares.
  * dp rows   — the same grid under per-release Gaussian DP, composed by
    the RDP accountant (subsampled-RDP amplification on the ``subsample``
    scenario) — the epsilon column of the frontier.

Emits ``BENCH_scenarios.json`` with one row per cell.  ``--check``
asserts the schema plus two invariants the CI bench-smoke gates on:
every protocol books nonzero training bits through the shared ledger,
and ASCII beats (or ties) FedAvg on the clean vertical-partition cell —
feature-split data is exactly where logit-averaged local models lose to
the interchange.

  PYTHONPATH=src python benchmarks/scenarios_bench.py --rounds 4 --check
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.comm import GaussianMechanism
from repro.control import make_accountant
from repro.core.engine import (MeteredTransport, Protocol, SessionConfig,
                               endpoints_for)
from repro.data import synthetic
from repro.data.partition import train_test_split, vertical_split
from repro.learners.logistic import LogisticRegression
from repro.scenarios import PRESETS, make_variant

SCENARIOS = ("clean", "noniid", "churn")
PROTOCOL_NAMES = ("ascii", "fedavg", "al")


def _cohort(seed: int, n: int):
    """The Fig. 3 vertical partition (4 agents x 2 features, 10 classes):
    homogeneous blocks, so every protocol — including FedAvg's shared-shape
    roster — runs on the identical split."""
    ds = synthetic.blob_fig3(jax.random.key(seed), n=n)
    tr, te = train_test_split(seed, ds.X.shape[0])
    Xs = vertical_split(ds.X, ds.splits)
    return ([x[tr] for x in Xs], [x[te] for x in Xs],
            ds.classes[tr], ds.classes[te], ds.num_classes)


def run_cell(protocol: str, scenario_name: str, *, rounds: int, steps: int,
             n: int, dp_epsilon: float = 0.0, seed: int = 0) -> dict:
    """One frontier cell: fit `protocol` under `scenario_name`, return the
    accuracy / train-bits / epsilon row."""
    Xtr, Xte, ctr, cte, k = _cohort(seed, n)
    scenario = PRESETS[scenario_name]
    privacy = (GaussianMechanism(epsilon=dp_epsilon,
                                 nonneg=(protocol == "ascii"))
               if dp_epsilon > 0 else None)
    accountant = (make_accountant("rdp", q=scenario.subsample)
                  if privacy is not None else None)
    transport = MeteredTransport(privacy=privacy, accountant=accountant)
    engine = Protocol(SessionConfig(num_classes=k, max_rounds=rounds),
                      transport=transport, variant=make_variant(protocol),
                      scenario=None if scenario.trivial else scenario)
    endpoints = endpoints_for([LogisticRegression(steps=steps)
                               for _ in Xtr], Xtr)
    t0 = time.perf_counter()
    fitted = engine.fit(jax.random.key(seed + 1), endpoints, ctr)
    seconds = time.perf_counter() - t0
    report = (transport.accountant.report(privacy)
              if accountant is not None else {})
    return {
        "protocol": protocol,
        "scenario": scenario_name,
        "dp_epsilon": dp_epsilon,
        "acc": float(jnp.mean(fitted.predict(Xte) == cte)),
        "train_bits": int(transport.total_bits),
        # worst-case agent under composition; 0.0 when the channel is clean
        "epsilon": max((float(v["epsilon"]) for v in report.values()),
                       default=0.0),
        "rounds_run": int(fitted.num_rounds),
        "seconds": seconds,
    }


def check(result: dict) -> None:
    """Schema + invariant gate (the CI bench-smoke assertions)."""
    rows = result["rows"]
    keys = {"protocol", "scenario", "dp_epsilon", "acc", "train_bits",
            "epsilon", "rounds_run", "seconds"}
    for r in rows:
        missing = keys - set(r)
        assert not missing, f"row {r} missing {sorted(missing)}"
    cells = {(r["protocol"], r["scenario"], r["dp_epsilon"] > 0): r
             for r in rows}
    for p in PROTOCOL_NAMES:
        for s in SCENARIOS:
            assert (p, s, False) in cells, f"missing cell ({p}, {s})"
            assert cells[p, s, False]["train_bits"] > 0, \
                f"({p}, {s}) booked no wire bits through the shared ledger"
    # equal (uncapped fp32) wire rules, vertically split features: the
    # interchange must not lose to logit-averaged local models
    assert cells["ascii", "clean", False]["acc"] + 1e-9 >= \
        cells["fedavg", "clean", False]["acc"], \
        (f"ascii clean acc {cells['ascii', 'clean', False]['acc']:.3f} < "
         f"fedavg clean acc {cells['fedavg', 'clean', False]['acc']:.3f}")
    for r in rows:
        if r["dp_epsilon"] > 0:
            assert r["epsilon"] > 0.0, \
                f"DP row ({r['protocol']}, {r['scenario']}) composed eps=0"


def run(*, rounds: int = 4, steps: int = 80, n: int = 240,
        dp_epsilon: float = 2.0, out: str | None = "BENCH_scenarios.json"
        ) -> dict:
    rows = []
    for p in PROTOCOL_NAMES:
        for s in SCENARIOS:
            rows.append(run_cell(p, s, rounds=rounds, steps=steps, n=n))
    if dp_epsilon > 0:
        # the epsilon column: clean-channel DP plus the subsampled-RDP
        # amplification cell (q = 0.5 participation per round)
        for p in PROTOCOL_NAMES:
            for s in ("clean", "subsample"):
                rows.append(run_cell(p, s, rounds=rounds, steps=steps, n=n,
                                     dp_epsilon=dp_epsilon))
    result = {
        "config": {"rounds": rounds, "steps": steps, "n": n,
                   "dp_epsilon": dp_epsilon, "dataset": "blob3",
                   "learner": "logistic",
                   "backend": jax.default_backend()},
        "rows": rows,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--n", type=int, default=240)
    ap.add_argument("--dp-epsilon", type=float, default=2.0,
                    help="per-release epsilon for the DP rows (0 = skip)")
    ap.add_argument("--out", default="BENCH_scenarios.json")
    ap.add_argument("--check", action="store_true",
                    help="assert schema + ledger/accuracy invariants "
                         "(the CI bench-smoke gate)")
    args = ap.parse_args()
    res = run(rounds=args.rounds, steps=args.steps, n=args.n,
              dp_epsilon=args.dp_epsilon, out=args.out)
    for r in res["rows"]:
        dp = f",eps={r['epsilon']:.3f}" if r["dp_epsilon"] > 0 else ""
        print(f"{r['protocol']},{r['scenario']},acc={r['acc']:.3f},"
              f"bits={r['train_bits']}{dp}")
    if args.check:
        check(res)
        print(f"check: ok ({len(res['rows'])} rows)")
    print(f"written to {args.out}")


if __name__ == "__main__":
    main()
